//! Paged KV-cache pool test suite.
//!
//! The headline contract: a paged cache ([`Backend::run_prefill`] with
//! `CacheMode::Paged`) produces logits **bit-identical** to the flat
//! cache at the prefill and
//! at every decode step — across the full, masked, compact and
//! shared-expert layouts, at multiple thread counts, and through both
//! `run_decode` and `run_decode_batch`. Plus the pool semantics: prefix
//! sharing deduplicates identical prompts, forks copy-on-write without
//! perturbing the reader, and blocks always return to the free list. And
//! the serving side: memory-aware admission serializes a burst that the
//! budget cannot co-host (blocked-then-admitted, FIFO), a long-context
//! burst completes under a budget the flat accounting would blow through,
//! a disconnected client's sequence is evicted with its blocks released,
//! and a mixed workload leaves zero blocks behind.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hc_smoe::backend::native::{fork_paged_cache, NativeBackend};
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::generate::SamplingParams;
use hc_smoe::kvpool::{KvPool, PoolHandle, DEFAULT_BLOCK_TOKENS};
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::MASK_OFF;
use hc_smoe::serving::{
    reply_channel, serve, BatcherConfig, GenerateRequest, Request, ScoreRequest, ServeSpec,
    ServerHandle,
};
use hc_smoe::weights::Weights;

fn tiny_cfg(shared: bool) -> ModelCfg {
    ModelCfg {
        name: "kvpool".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 48,
        shared,
        m_shared: 16,
        cap_factor: 4.0,
        block_c: 4,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn big_pool(cfg: &ModelCfg) -> PoolHandle {
    PoolHandle::new(KvPool::for_model(cfg, 4 << 20, DEFAULT_BLOCK_TOKENS).unwrap())
}

/// Synthesize one artifact set per test process (server-side tests).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_kvpool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0xCAFE).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

/// Prefill + decode the same token stream through a flat and a paged
/// cache, asserting bitwise-equal logits at the prefill and every step —
/// via single-sequence decode, and again via `run_decode_batch_with` at an
/// explicit thread count (both flavours share one batch to also cover the
/// mixed flat+paged batch path).
fn assert_paged_matches_flat(
    cfg: &ModelCfg,
    w: &Weights,
    n_slots: usize,
    mask: &[f32],
    remap: Option<&[i32]>,
    prompt: &[i32],
    steps: usize,
) {
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(w, n_slots).unwrap();
    let pool = big_pool(cfg);

    let flat_opts = || {
        let mut o = PrefillOpts::new(mask);
        if let Some(rm) = remap {
            o = o.remap(rm);
        }
        o
    };
    let paged_opts = || flat_opts().paged(&pool, prompt.len() + steps);
    let prefill = |opts: PrefillOpts<'_>| {
        let (cache, logits) = backend.run_prefill(state.as_ref(), prompt, opts).unwrap();
        (cache.expect("fresh prefill returns a cache"), logits)
    };

    let (mut flat, flat_logits) = prefill(flat_opts());
    let (mut paged, paged_logits) = prefill(paged_opts());
    assert_eq!(bits(&flat_logits), bits(&paged_logits), "prefill logits differ");
    assert_eq!(flat.seq_len(), paged.seq_len());

    // a second flat+paged pair decodes through ONE mixed batch call
    let (mut flat_b, _) = prefill(flat_opts());
    let (mut paged_b, _) = prefill(paged_opts());

    let v = cfg.vocab;
    for i in 0..steps {
        let tok = ((7 + i * 5) % v) as i32;
        let f = backend
            .run_decode(state.as_ref(), flat.as_mut(), tok, mask, remap)
            .unwrap();
        let p = backend
            .run_decode(state.as_ref(), paged.as_mut(), tok, mask, remap)
            .unwrap();
        assert_eq!(bits(&f), bits(&p), "decode step {i} differs (paged vs flat)");

        let rows = {
            let mut refs: Vec<&mut dyn KvCache> = vec![flat_b.as_mut(), paged_b.as_mut()];
            backend
                .run_decode_batch_with(state.as_ref(), &mut refs, &[tok, tok], mask, remap, 3)
                .unwrap()
        };
        assert_eq!(bits(&rows[0]), bits(&f), "mixed batch flat row differs at step {i}");
        assert_eq!(bits(&rows[1]), bits(&f), "mixed batch paged row differs at step {i}");
    }
    assert_eq!(paged.seq_len(), prompt.len() + steps);
}

#[test]
fn paged_matches_flat_full_layout() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 11);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    // prompt crosses a block boundary mid-decode (16-token blocks)
    let prompt: Vec<i32> = (0..13).map(|i| ((3 + i * 5) % cfg.vocab) as i32).collect();
    assert_paged_matches_flat(&cfg, &w, cfg.n_exp, &mask, None, &prompt, 8);
}

#[test]
fn paged_matches_flat_masked_layout() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 13);
    let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    mask[2] = MASK_OFF;
    mask[cfg.n_exp + 1] = MASK_OFF;
    let prompt: Vec<i32> = (0..5).map(|i| ((2 + i * 7) % cfg.vocab) as i32).collect();
    assert_paged_matches_flat(&cfg, &w, cfg.n_exp, &mask, None, &prompt, 6);
}

#[test]
fn paged_matches_flat_shared_expert_layout() {
    let cfg = tiny_cfg(true);
    let w = Weights::synthesize(&cfg, 17);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let prompt: Vec<i32> = (0..6).map(|i| ((9 + i * 3) % cfg.vocab) as i32).collect();
    assert_paged_matches_flat(&cfg, &w, cfg.n_exp, &mask, None, &prompt, 6);
}

#[test]
fn paged_matches_flat_compact_layout() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 19);
    let r = 2usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).unwrap();
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let prompt: Vec<i32> = (0..7).map(|i| ((4 + i * 5) % cfg.vocab) as i32).collect();
    assert_paged_matches_flat(&cfg, &cw, r, &mask, Some(&remap), &prompt, 8);
}

#[test]
fn identical_prompts_share_full_blocks() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 23);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let pool = big_pool(&cfg);
    let bt = DEFAULT_BLOCK_TOKENS;
    // 2 full blocks + a 3-token tail
    let prompt: Vec<i32> = (0..2 * bt + 3).map(|i| ((1 + i * 3) % cfg.vocab) as i32).collect();

    let (a, _) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask).paged(&pool, prompt.len()))
        .unwrap();
    let mut a = a.expect("fresh prefill returns a cache");
    assert_eq!(pool.stats().in_use, 3);
    let (b, _) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask).paged(&pool, prompt.len()))
        .unwrap();
    let mut b = b.expect("fresh prefill returns a cache");
    // the two full prompt blocks deduplicate; only b's tail is new
    assert_eq!(pool.stats().in_use, 4, "identical prefix must share storage");
    assert_eq!(pool.stats().shared, 2);

    // a different router mask must NOT alias (different variant fingerprint)
    let mut masked = mask.clone();
    masked[1] = MASK_OFF;
    let (c, _) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&masked).paged(&pool, prompt.len()))
        .unwrap();
    let c = c.expect("fresh prefill returns a cache");
    assert_eq!(pool.stats().in_use, 7, "masked variant must not share with unmasked");

    // both sharers decode on, bit-identical to independent flat caches
    let (fa, _) = backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let (fb, _) = backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let mut fa = fa.expect("fresh prefill returns a cache");
    let mut fb = fb.expect("fresh prefill returns a cache");
    for i in 0..5 {
        let ta = ((2 + i * 5) % cfg.vocab) as i32;
        let tb = ((3 + i * 7) % cfg.vocab) as i32;
        let pa = backend.run_decode(state.as_ref(), a.as_mut(), ta, &mask, None).unwrap();
        let ra = backend.run_decode(state.as_ref(), fa.as_mut(), ta, &mask, None).unwrap();
        assert_eq!(bits(&pa), bits(&ra), "sharer A diverged at step {i}");
        let pb = backend.run_decode(state.as_ref(), b.as_mut(), tb, &mask, None).unwrap();
        let rb = backend.run_decode(state.as_ref(), fb.as_mut(), tb, &mask, None).unwrap();
        assert_eq!(bits(&pb), bits(&rb), "sharer B diverged at step {i}");
    }

    drop(a);
    drop(b);
    drop(c);
    let s = pool.stats();
    assert_eq!(s.in_use, 0, "every block must return to the free list");
    assert_eq!(s.reserved, 0, "every reservation must be returned");
}

#[test]
fn fork_copy_on_write_diverges_bit_identically() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 29);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let pool = big_pool(&cfg);
    let prompt: Vec<i32> = (0..9).map(|i| ((5 + i * 4) % cfg.vocab) as i32).collect();

    let (orig, _) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask).paged(&pool, cfg.t_max))
        .unwrap();
    let mut orig = orig.expect("fresh prefill returns a cache");
    let mut fork = fork_paged_cache(orig.as_ref()).unwrap();
    assert_eq!(fork.seq_len(), orig.seq_len());
    let before = pool.stats();
    assert_eq!(before.shared, 1, "fork shares the (partial) tail block");

    // flat references for both continuations
    let (f_orig, _) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let (f_fork, _) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let mut f_orig = f_orig.expect("fresh prefill returns a cache");
    let mut f_fork = f_fork.expect("fresh prefill returns a cache");
    for i in 0..6 {
        let ta = ((2 + i * 3) % cfg.vocab) as i32;
        let tb = ((11 + i * 5) % cfg.vocab) as i32; // different stream: forces divergence
        let pa = backend.run_decode(state.as_ref(), orig.as_mut(), ta, &mask, None).unwrap();
        let ra = backend.run_decode(state.as_ref(), f_orig.as_mut(), ta, &mask, None).unwrap();
        assert_eq!(bits(&pa), bits(&ra), "original diverged from flat at step {i}");
        let pb = backend.run_decode(state.as_ref(), fork.as_mut(), tb, &mask, None).unwrap();
        let rb = backend.run_decode(state.as_ref(), f_fork.as_mut(), tb, &mask, None).unwrap();
        assert_eq!(bits(&pb), bits(&rb), "fork diverged from flat at step {i}");
    }
    // the first divergent append copied the shared tail exactly once
    assert!(pool.stats().in_use > before.in_use, "COW must allocate a private tail");
    drop(orig);
    drop(fork);
    assert_eq!(pool.stats().in_use, 0);
}

#[test]
fn intra_batch_cow_sharers_need_one_block_not_two() {
    // Two sequences sharing one partial tail decode in ONE batch with only
    // one free block: the first sharer copies (releasing its reference),
    // the second then owns the tail exclusively and writes in place — the
    // feasibility check must demand 1 block, not reject a feasible batch
    // by counting one per sharer.
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 37);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    // exactly 2 blocks: 1 for the shared prompt, 1 spare for the COW
    let pool = PoolHandle::new(
        KvPool::new(cfg.n_layer, cfg.d, DEFAULT_BLOCK_TOKENS, 2).unwrap(),
    );
    let prompt: Vec<i32> = (0..5).map(|i| ((6 + i * 5) % cfg.vocab) as i32).collect();
    let (parent, _) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask).paged(&pool, prompt.len()))
        .unwrap();
    let mut parent = parent.expect("fresh prefill returns a cache");
    let mut fork = fork_paged_cache(parent.as_ref()).unwrap();
    assert_eq!(pool.stats().in_use, 1);

    // flat references for bit-identity through the constrained batch
    let (f_parent, _) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let (f_fork, _) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let mut f_parent = f_parent.expect("fresh prefill returns a cache");
    let mut f_fork = f_fork.expect("fresh prefill returns a cache");
    let toks = [3i32, 9];
    let rows = {
        let mut refs: Vec<&mut dyn KvCache> = vec![parent.as_mut(), fork.as_mut()];
        backend
            .run_decode_batch(state.as_ref(), &mut refs, &toks, &mask, None)
            .unwrap()
    };
    let rp = backend.run_decode(state.as_ref(), f_parent.as_mut(), toks[0], &mask, None).unwrap();
    let rf = backend.run_decode(state.as_ref(), f_fork.as_mut(), toks[1], &mask, None).unwrap();
    assert_eq!(bits(&rows[0]), bits(&rp), "parent row diverged under COW pressure");
    assert_eq!(bits(&rows[1]), bits(&rf), "fork row diverged under COW pressure");
    assert_eq!(pool.stats().in_use, 2, "exactly one COW block was allocated");
    drop(parent);
    drop(fork);
    assert_eq!(pool.stats().in_use, 0);
}

// ---------------------------------------------------------------------------
// Serving-side tests (memory-aware admission, eviction, leak-freedom)
// ---------------------------------------------------------------------------

/// Serve qwensim with an explicit pool budget in *blocks*.
fn serve_with_blocks(a: &Artifacts, cfg: &ModelCfg, blocks: usize) -> ServerHandle {
    serve(
        ServeSpec {
            kv_budget_bytes: Some(blocks * cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS)),
            ..ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap()
}

/// Poll a metrics predicate with a deadline (the executor publishes pool
/// gauges once per loop iteration).
fn wait_for(handle: &ServerHandle, what: &str, pred: impl Fn(&ServerHandle) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !pred(handle) {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn admission_blocks_then_admits_in_fifo_order() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = &ctx.cfg;
    // 4-block budget; every request below needs 3 blocks, so at most ONE
    // can hold a reservation at a time — admissions strictly serialize
    let handle = serve_with_blocks(&a, cfg, 4);
    let prompt: Vec<i32> = (0..20).map(|i| ((2 + i * 3) % cfg.vocab) as i32).collect();
    let (reply, rx) = reply_channel();
    let tx = handle.sender();
    for max_new in [13usize, 14, 15] {
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::greedy(max_new, None))
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    drop(reply);
    // one shared reply channel: arrival order IS the executor's completion
    // order — blocked requests must be admitted strictly FIFO
    let lens: Vec<usize> = (0..3).map(|_| rx.recv().unwrap().unwrap().tokens.len()).collect();
    assert_eq!(lens, vec![13, 14, 15], "admission must be blocked-then-admitted FIFO");
    let snap = handle.metrics.snapshot();
    assert!(
        snap.kv_blocks_peak <= 4,
        "peak {} blocks exceeded the 4-block budget",
        snap.kv_blocks_peak
    );
    wait_for(&handle, "blocks to drain", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    handle.shutdown().unwrap();
}

#[test]
fn long_context_burst_completes_under_budget_flat_accounting_would_blow() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let budget_blocks = 8usize;
    let budget_bytes = budget_blocks * cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS);
    let n_req = 6usize;
    let prompt_len = cfg.t_max - 16; // 48 tokens
    let max_new = 16usize; // worst case exactly t_max resident tokens
    // the flat accounting for the burst exceeds the pool budget — without
    // admission control this workload needs 6 unbounded caches at once
    assert!(
        n_req * cfg.kv_cache_bytes(prompt_len + max_new) > budget_bytes,
        "test premise broken: the budget must be smaller than the flat burst"
    );

    let handle = serve_with_blocks(&a, &cfg, budget_blocks);
    let tx = handle.sender();
    let (reply, rx) = reply_channel();
    for r in 0..n_req {
        // distinct prompts so prefix sharing cannot hide the pressure
        let prompt: Vec<i32> =
            (0..prompt_len).map(|i| ((1 + r * 7 + i * 3) % cfg.vocab) as i32).collect();
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::greedy(max_new, None))
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    drop(reply);
    for _ in 0..n_req {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.tokens.len(), max_new);
    }
    let snap = handle.metrics.snapshot();
    // the pool metrics prove the burst ran inside the budget (no OOM
    // reliance): the high-water mark never passed the block budget
    assert!(snap.kv_blocks_peak as usize <= budget_blocks);
    assert!(snap.kv_blocks_peak > 0);
    wait_for(&handle, "blocks to drain", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    handle.shutdown().unwrap();
}

#[test]
fn disconnected_client_is_evicted_and_blocks_released() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let handle = serve_with_blocks(&a, &cfg, 64);
    let tx = handle.sender();

    // deterministic queue-side eviction: the reply channel is already
    // closed when the request reaches the executor
    {
        let (reply, rx) = reply_channel::<anyhow::Result<hc_smoe::generate::Generated>>();
        drop(rx);
        tx.send(Request::Generate(
            GenerateRequest::new(&[1, 4, 20], SamplingParams::greedy(40, None)).reply_to(reply),
        ))
        .unwrap();
    }
    wait_for(&handle, "queued eviction", |h| {
        h.metrics.snapshot().gen_disconnects >= 1
    });

    // mid-decode eviction: wait until the sequence is actively decoding,
    // then drop the receiver — the executor re-checks the channel at every
    // step boundary, so the sequence leaves long before max_tokens
    let steps_before = handle.metrics.snapshot().decode_steps;
    let (reply, rx) = reply_channel();
    tx.send(Request::Generate(
        GenerateRequest::new(&[2, 5, 21, 7], SamplingParams::greedy(1_000_000, None))
            .reply_to(reply),
    ))
    .unwrap();
    wait_for(&handle, "decode to start", |h| {
        h.metrics.snapshot().decode_steps > steps_before
    });
    drop(rx);
    wait_for(&handle, "mid-decode eviction or natural finish", |h| {
        let s = h.metrics.snapshot();
        s.gen_disconnects >= 2 || s.kv_blocks_in_use == 0
    });
    wait_for(&handle, "blocks to drain", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });

    // the executor is healthy afterwards: a live request completes
    let out = handle
        .generate(&[3, 9, 27], SamplingParams::greedy(4, None))
        .unwrap();
    assert_eq!(out.tokens.len(), 4);
    handle.shutdown().unwrap();
}

#[test]
fn mixed_workload_leaves_no_block_behind() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    let handle = serve_with_blocks(&a, &cfg, 32);
    let tx = handle.sender();
    let (reply, rx) = reply_channel();

    // 8 requests: 5 generations (one with a pre-dropped client), 3 scores
    let mut gen_sent = 0usize;
    for r in 0..5 {
        let prompt: Vec<i32> =
            (0..6 + r).map(|i| ((3 + r * 5 + i * 2) % cfg.vocab) as i32).collect();
        if r == 2 {
            let (dead, dead_rx) = reply_channel();
            drop(dead_rx);
            tx.send(Request::Generate(
                GenerateRequest::new(&prompt, SamplingParams::greedy(12, None)).reply_to(dead),
            ))
            .unwrap();
        } else {
            gen_sent += 1;
            tx.send(Request::Generate(
                GenerateRequest::new(
                    &prompt,
                    SamplingParams::top_k(4, 0.8, 7 + r as u64, 8 + r, None),
                )
                .reply_to(reply.clone()),
            ))
            .unwrap();
        }
    }
    drop(reply);
    for _ in 0..3 {
        let scores = handle.score_item(&[1, 4, 20], &[vec![7], vec![8]]).unwrap();
        assert_eq!(scores.len(), 2);
    }
    for _ in 0..gen_sent {
        rx.recv().unwrap().unwrap();
    }
    wait_for(&handle, "no-block-leak after mixed workload", |h| {
        let s = h.metrics.snapshot();
        s.kv_blocks_in_use == 0 && s.gen_disconnects >= 1
    });
    let snap = handle.metrics.snapshot();
    assert_eq!(snap.gen_requests as usize, gen_sent, "evicted request never admitted");
    assert!(snap.kv_blocks_peak > 0, "the workload must have used the pool");
    handle.shutdown().unwrap();
}

#[test]
fn paged_serving_matches_offline_flat_generation() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let handle = serve_with_blocks(&a, &ctx.cfg, 128);
    let prompt = [1i32, 4, 20, 3, 5];
    for seed in [5u64, 6] {
        let params = SamplingParams::top_k(8, 0.8, seed, 12, None);
        let served = handle.generate(&prompt, params.clone()).unwrap();
        let offline = hc_smoe::generate::generate(&ctx, &model, &prompt, params).unwrap();
        // the server decodes from the paged pool, offline from the flat
        // cache — bit-identity makes the token streams equal
        assert_eq!(served.tokens, offline.tokens, "seed {seed}");
        assert_eq!(served.finish, offline.finish, "seed {seed}");
    }
    handle.shutdown().unwrap();
}

#[test]
fn empty_score_and_bad_params_still_answered_under_pool() {
    // regression guard: the admission rework must not break the immediate
    // answers for degenerate requests
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let handle = serve_with_blocks(&a, &ctx.cfg, 16);
    let (reply, rx) = std::sync::mpsc::channel();
    handle
        .sender()
        .send(Request::Score(ScoreRequest { rows: Vec::new(), reply, enqueued: Instant::now() }))
        .unwrap();
    assert!(rx.recv().unwrap().is_empty());
    let err = handle.generate(&[1, 2], SamplingParams::top_k(0, 0.8, 1, 4, None));
    assert!(err.is_err(), "k = 0 must be rejected");
    handle.shutdown().unwrap();
}
