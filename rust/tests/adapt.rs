//! Adaptive-compression test suite: live routing stats, background
//! recompression, and atomic variant hot-swap (`ServeSpec::adapt`).
//!
//! The headline contracts:
//!
//! * the background rebuild is **reproducible**: the hot-swapped variant's
//!   fingerprint equals an offline `variant::recompress` on the same
//!   routing window, and a post-swap request's token stream is
//!   bit-identical to an offline run on that offline-rebuilt variant;
//! * a swap never touches in-flight work: a Batch stream that is admitted
//!   before the swap, preempted by an Interactive storm, and resumed
//!   *after* the swap still re-prefills on its pinned (now retired)
//!   variant and finishes bit-identical to an uninterrupted offline run
//!   on the original model;
//! * a swap storm under preemption leaks zero KV blocks;
//! * the window knob validates at startup like every other runtime knob.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hc_smoe::config::Artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::generate::{generate, Generated, SamplingParams};
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::Method;
use hc_smoe::serving::{
    serve, AdaptSpec, BatcherConfig, GenerateRequest, Priority, ServeSpec, ServerHandle,
};
use hc_smoe::similarity::Metric;
use hc_smoe::variant;

fn hc_method() -> Method {
    Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    }
}

/// Synthesize one artifact set per test process.
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_adapt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        hc_smoe::bench_support::synthesize_artifacts(&p, 0xADA7).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

fn adapt_spec(r: usize, window_tokens: Option<u64>) -> AdaptSpec {
    AdaptSpec {
        method: hc_method(),
        r,
        domain: "general".into(),
        quantize: false,
        window_tokens,
        min_tokens: Some(0),
    }
}

/// Poll a metrics predicate with a deadline (the executor runs its
/// adapt tick once per loop iteration).
fn wait_for(handle: &ServerHandle, what: &str, pred: impl Fn(&ServerHandle) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !pred(handle) {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The whole adaptive loop is reproducible offline: run one request
/// against a fresh model to learn its exact routing window, predict the
/// recompressed variant with an offline [`variant::recompress`] on that
/// window, then serve with `window_tokens` equal to the request's routed
/// tokens — the background rebuild must land *exactly* the predicted
/// fingerprint, and a post-swap request must emit the offline-predicted
/// variant's token stream bit for bit.
#[test]
fn swap_lands_the_offline_predicted_variant_and_new_requests_run_it() {
    let a = arts();
    let root = a.root.to_string_lossy().into_owned();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let r = ctx.cfg.n_exp / 2;

    // offline request 1 on a FRESH original model: its routing stats are
    // exactly the live window the server will see (the served ops are
    // bit-identical, so the dispatch counts are too)
    let model = ctx.load_original().unwrap();
    let prompt1 = [1i32, 4, 20, 3, 7, 2];
    let params1 = SamplingParams::greedy(12, None);
    let offline1 = generate(&ctx, &model, &prompt1, params1.clone()).unwrap();
    let win = ctx.routing_stats(&model).expect("native backend reports routing stats");
    assert!(win.tokens > 0, "the offline run must have routed tokens");
    assert!(win.dispatch_entropy() > 0.0, "k=2 routing spreads over >1 expert");

    // offline prediction of the background rebuild on that exact window
    let cm = variant::recompress(&root, "qwensim", &hc_method(), r, "general", false, &win.counts)
        .unwrap();
    let expected_fp = cm.weights.content_hash();
    let recompressed = cm.load(&ctx).unwrap();
    let prompt2 = [2i32, 9, 31, 5];
    let params2 = SamplingParams::greedy(10, None);
    let offline2 = generate(&ctx, &recompressed, &prompt2, params2.clone()).unwrap();

    // serve adaptively with the window sized to fire right after request 1
    let handle = serve(
        ServeSpec {
            adapt: Some(adapt_spec(r, Some(win.tokens))),
            ..ServeSpec::for_tests(&root, "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let fp0 = handle.metrics.snapshot().active_variant;
    assert_ne!(fp0, expected_fp, "recompression must change the weight content");

    let served1 = handle.generate(&prompt1, params1).unwrap();
    assert_eq!(served1.tokens, offline1.tokens, "pre-swap stream must match the original");

    wait_for(&handle, "the first hot swap", |h| h.metrics.snapshot().swaps >= 1);
    let snap = handle.metrics.snapshot();
    assert_eq!(
        snap.active_variant, expected_fp,
        "the swap must land exactly the offline-predicted recompressed variant"
    );
    assert!(snap.recompress_s > 0.0, "background rebuild wall-clock must be metered");

    let served2 = handle.generate(&prompt2, params2).unwrap();
    assert_eq!(
        served2.tokens, offline2.tokens,
        "a post-swap request must provably run the new fingerprint's weights"
    );
    assert_eq!(served2.finish, offline2.finish);
    handle.shutdown().unwrap();
}

/// Swap storm under preemption. A Batch stream is admitted (and pinned)
/// on the original variant, then starved by a continuous Interactive
/// storm on a pool it cannot share — the storm's traffic fills the
/// routing window and a hot swap lands while the Batch stream is still
/// in flight. When the storm drains, the stream resumes — re-prefilling
/// its resident tokens on the pinned, now-retired variant — and must
/// finish bit-identical to an uninterrupted offline run on the original
/// model. Afterwards the pool must be empty: zero leaked KV blocks.
#[test]
fn swap_under_preemption_keeps_pinned_streams_bit_identical_and_leaks_nothing() {
    let a = arts();
    let root = a.root.to_string_lossy().into_owned();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    let model = ctx.load_original().unwrap();

    // the Batch stream reserves the whole 4-block pool (prompt 4 +
    // t_max-bounded decode = 64 tokens = 4 blocks), so every Interactive
    // arrival can only be admitted by preempting it; the routing window
    // (80) exceeds anything the Batch stream can route alone (<= t_max =
    // 64 tokens), so only storm traffic can trigger the recompression —
    // guaranteeing the swap lands while the stream is swapped out
    let bprompt = [2i32, 5, 21, 7];
    let bparams = SamplingParams::greedy(1_000_000, None); // t_max-bounded
    let boffline = generate(&ctx, &model, &bprompt, bparams.clone()).unwrap();
    let iprompt = [1i32, 4, 20];
    let iparams = SamplingParams::greedy(2, None);

    let handle = serve(
        ServeSpec {
            kv_budget_bytes: Some(4 * cfg.kv_block_bytes(hc_smoe::kvpool::DEFAULT_BLOCK_TOKENS)),
            prefill_chunk: Some(4),
            adapt: Some(adapt_spec(cfg.n_exp / 2, Some(80))),
            ..ServeSpec::for_tests(&root, "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let fp0 = handle.metrics.snapshot().active_variant;

    // admit the Batch stream and spin until its prefill finishes (the
    // variant pin is taken at admission, but only an *active* sequence's
    // preemption carries it — a mid-prefill preemption requeues the
    // request afresh); spin rather than sleep so the storm begins within
    // a few decode steps of the stream going active
    let long_rx = handle
        .submit(GenerateRequest::new(&bprompt, bparams).priority(Priority::Batch))
        .unwrap()
        .expect("a fresh request owns its receiver");
    let blen = bprompt.len() as u64;
    let deadline = Instant::now() + Duration::from_secs(30);
    while handle.metrics.snapshot().prefill_tokens < blen {
        assert!(Instant::now() < deadline, "batch prefill never finished");
        std::thread::yield_now();
    }

    // Interactive storm: keep several shorts outstanding (spinning, no
    // sleeps) so the Interactive lane never empties — the Batch stream
    // stays swapped out (pinned, cache dropped) while the storm's routed
    // tokens fill the window and the background rebuild lands
    let mut outstanding = Vec::new();
    let mut served_shorts = 0usize;
    let deadline = Instant::now() + Duration::from_secs(60);
    while handle.metrics.snapshot().swaps == 0 {
        assert!(Instant::now() < deadline, "no hot swap within 60s of live traffic");
        while outstanding.len() < 8 {
            outstanding.push(
                handle
                    .submit(
                        GenerateRequest::new(&iprompt, iparams.clone())
                            .priority(Priority::Interactive),
                    )
                    .unwrap()
                    .expect("a fresh request owns its receiver"),
            );
        }
        // reap finished shorts: every stream must complete cleanly (their
        // tokens legitimately differ across the swap, so only success is
        // asserted)
        outstanding.retain(|rx| match rx.try_recv().unwrap() {
            Some(out) => {
                let g: Generated = out.unwrap();
                assert!(!g.tokens.is_empty());
                served_shorts += 1;
                false
            }
            None => true,
        });
        std::thread::yield_now();
    }

    // the swap landed while the Batch stream was still in flight
    assert!(
        long_rx.try_recv().unwrap().is_none(),
        "the batch stream must still be in flight when the swap lands \
         (the storm keeps its lane starved)"
    );

    // drain the storm, then let the Batch stream resume and finish: its
    // re-prefill runs on the pinned RETIRED variant, so the stream is
    // bit-identical to the uninterrupted offline run on the original
    for rx in outstanding {
        rx.recv().unwrap().unwrap();
        served_shorts += 1;
    }
    let long_out = long_rx.recv().unwrap().unwrap();
    assert_eq!(
        long_out.tokens, boffline.tokens,
        "a stream spanning the swap must stay bit-identical to its variant's offline run"
    );
    assert_eq!(long_out.finish, boffline.finish);

    wait_for(&handle, "zero KV blocks after the storm", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert!(snap.swaps >= 1, "the storm must have hot-swapped: {}", snap.swaps);
    assert!(snap.preemptions >= 1, "the storm must have preempted: {}", snap.preemptions);
    assert_ne!(snap.active_variant, fp0, "the active fingerprint must have changed");
    assert!(served_shorts >= 1, "the storm must have served interactive traffic");
}

/// `ServeSpec::adapt` validates its window like every other runtime knob:
/// an explicit zero is a startup error, not a silent default.
#[test]
fn zero_adapt_window_is_a_startup_error() {
    let a = arts();
    let handle = serve(
        ServeSpec {
            adapt: Some(adapt_spec(4, Some(0))),
            ..ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let err = handle.shutdown().unwrap_err();
    assert!(
        format!("{err:#}").contains("positive token count"),
        "startup validation must reject window_tokens=0: {err:#}"
    );
}
