//! Generation-path test suite: the KV-cached prefill/decode split, the
//! sampling/stop-condition loop, and the continuous-batching server —
//! all offline (synthesized weights/artifacts, native backend).
//!
//! The headline contract pinned here: prefill + repeated decode produce
//! logits **bit-identical** to the uncached full-sequence forward at
//! every position, on the full and compact expert layouts, under router
//! masks, at multiple thread counts. (The configs used keep capacity
//! dispatch drop-free — `cap_factor = 4.0` with top-k distinct experts
//! bounds every queue below capacity — which is the regime where the
//! equivalence is exact; see `SERVING.md`.)

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hc_smoe::backend::native::{forward_logits_with, NativeBackend};
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::eval::Evaluator;
use hc_smoe::generate::{generate, generate_compact, FinishReason, SamplingParams};
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{Method, Pipeline, MASK_OFF};
use hc_smoe::serving::{serve, BatcherConfig, RowSpec, ScoreRequest, ServeSpec};
use hc_smoe::similarity::Metric;
use hc_smoe::weights::Weights;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "gen".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 40,
        shared: false,
        m_shared: 16,
        // k=2 distinct experts per token bound any slot's queue by t (full
        // layout) / 2t (two experts folded per compact slot); cap_factor=4
        // puts capacity at 2t / 4t — structurally drop-free, so cached and
        // uncached dispatch agree exactly at every prefix length.
        cap_factor: 4.0,
        block_c: 4,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Synthesize one artifact set per test process (shared across tests).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_gen_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0x6E11).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

fn hc_method() -> Method {
    Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    }
}

#[test]
fn cached_decode_is_bit_identical_to_full_forward() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 11);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    // prune one expert per layer through the router mask so the masked
    // path is exercised incrementally too
    let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    mask[0] = MASK_OFF;
    mask[cfg.n_exp + 2] = MASK_OFF;
    let v = cfg.vocab;
    let prompt: Vec<i32> = (0..8).map(|i| ((3 + i * 5) % v) as i32).collect();
    let cont: Vec<i32> = (0..12).map(|i| ((7 + i * 11) % v) as i32).collect();

    let (cache, prefill_logits) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let mut cache = cache.expect("fresh prefill returns a cache");
    assert_eq!(cache.seq_len(), prompt.len());
    for threads in [1usize, 4] {
        let full = forward_logits_with(
            &cfg, &w, &prompt, 1, prompt.len(), &mask, None, cfg.n_exp, threads,
        )
        .unwrap();
        let last = &full.data()[(prompt.len() - 1) * v..];
        assert_eq!(
            bits(last),
            bits(&prefill_logits),
            "prefill logits differ from full forward (threads={threads})"
        );
    }
    let mut seq = prompt.clone();
    for &tok in &cont {
        let step = backend
            .run_decode(state.as_ref(), cache.as_mut(), tok, &mask, None)
            .unwrap();
        seq.push(tok);
        for threads in [1usize, 4] {
            let full = forward_logits_with(
                &cfg, &w, &seq, 1, seq.len(), &mask, None, cfg.n_exp, threads,
            )
            .unwrap();
            let last = &full.data()[(seq.len() - 1) * v..];
            assert_eq!(
                bits(last),
                bits(&step),
                "decode logits differ at position {} (threads={threads})",
                seq.len() - 1
            );
        }
    }
    assert_eq!(cache.seq_len(), prompt.len() + cont.len());
    // memory accounting: the cache holds exactly the K/V the formula says
    assert_eq!(cache.byte_size(), cfg.kv_cache_bytes(cache.seq_len()));
}

#[test]
fn cached_decode_is_bit_identical_on_compact_variant() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 23);
    let r = 2usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).unwrap();
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&cw, r).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let v = cfg.vocab;
    let prompt: Vec<i32> = (0..6).map(|i| ((5 + i * 3) % v) as i32).collect();
    let cont: Vec<i32> = (0..10).map(|i| ((2 + i * 9) % v) as i32).collect();

    let (cache, prefill_logits) = backend
        .run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask).remap(&remap))
        .unwrap();
    let mut cache = cache.expect("fresh prefill returns a cache");
    let full = forward_logits_with(
        &cfg, &cw, &prompt, 1, prompt.len(), &mask, Some(&remap), r, 1,
    )
    .unwrap();
    assert_eq!(bits(&full.data()[(prompt.len() - 1) * v..]), bits(&prefill_logits));
    let mut seq = prompt.clone();
    for &tok in &cont {
        let step = backend
            .run_decode(state.as_ref(), cache.as_mut(), tok, &mask, Some(&remap))
            .unwrap();
        seq.push(tok);
        for threads in [1usize, 3] {
            let full = forward_logits_with(
                &cfg, &cw, &seq, 1, seq.len(), &mask, Some(&remap), r, threads,
            )
            .unwrap();
            assert_eq!(
                bits(&full.data()[(seq.len() - 1) * v..]),
                bits(&step),
                "compact decode differs at position {} (threads={threads})",
                seq.len() - 1
            );
        }
    }
}

#[test]
fn greedy_generation_is_deterministic_and_matches_manual_argmax() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let prompt = [1i32, 4, 20, 3, 5];
    let a = generate(&ctx, &model, &prompt, SamplingParams::greedy(10, None)).unwrap();
    let b = generate(&ctx, &model, &prompt, SamplingParams::greedy(10, None)).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy generation must replay exactly");
    assert_eq!(a.tokens.len(), 10);
    assert_eq!(a.finish, FinishReason::MaxTokens);

    // cross-check against a hand-rolled prefill/decode argmax loop
    let argmax = |xs: &[f32]| -> i32 {
        let mut bi = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &x) in xs.iter().enumerate() {
            if x > bv {
                bv = x;
                bi = i;
            }
        }
        bi as i32
    };
    let (mut cache, mut logits) = ctx.prefill(&model, &prompt).unwrap();
    let mut manual = Vec::new();
    for _ in 0..10 {
        let tok = argmax(&logits);
        manual.push(tok);
        logits = ctx.decode(&model, cache.as_mut(), tok).unwrap();
    }
    assert_eq!(a.tokens, manual);
}

#[test]
fn eos_and_context_stop_conditions() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let prompt = [1i32, 4, 33, 3, 5];

    // EOS: pin it to whatever greedy emits first — generation must stop
    // right there, inclusively
    let probe = generate(&ctx, &model, &prompt, SamplingParams::greedy(1, None)).unwrap();
    let first = probe.tokens[0];
    let out = generate(&ctx, &model, &prompt, SamplingParams::greedy(16, Some(first))).unwrap();
    assert_eq!(out.tokens, vec![first]);
    assert_eq!(out.finish, FinishReason::Eos);

    // context limit: a prompt near t_max can only emit t_max - len + 1
    // tokens (the final sample has no room to be fed back)
    let t_max = ctx.cfg.t_max;
    let long: Vec<i32> = (0..t_max - 4).map(|i| ((16 + i * 3) % 90) as i32).collect();
    let out = generate(&ctx, &model, &long, SamplingParams::greedy(100, None)).unwrap();
    assert_eq!(out.finish, FinishReason::MaxContext);
    assert_eq!(out.tokens.len(), t_max - long.len() + 1);

    // a prompt longer than the window is rejected cleanly
    let too_long: Vec<i32> = vec![17; t_max + 1];
    assert!(generate(&ctx, &model, &too_long, SamplingParams::greedy(4, None)).is_err());
    // ... and so is an empty one (no position to predict from)
    assert!(generate(&ctx, &model, &[], SamplingParams::greedy(4, None)).is_err());
}

#[test]
fn sampled_generation_is_seed_deterministic_on_merged_and_compact() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let r = ctx.cfg.n_exp / 2;
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let merged = cm.load(&ctx).unwrap();
    let (cw, remap) = cm.to_compact(&ctx).unwrap();
    let compact = ctx.load_compact(r, &cw, remap, "compact").unwrap();
    let prompt = [1i32, 4, 25, 61, 3, 5];
    let params = SamplingParams::top_k(8, 0.8, 3, 12, None);

    let a = generate(&ctx, &merged, &prompt, params.clone()).unwrap();
    let b = generate(&ctx, &merged, &prompt, params.clone()).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must replay on the merged variant");
    assert!(a.tokens.iter().all(|&t| (t as usize) < ctx.cfg.vocab));

    let c = generate_compact(&ctx, &compact, &prompt, params.clone()).unwrap();
    let d = generate_compact(&ctx, &compact, &prompt, params).unwrap();
    assert_eq!(c.tokens, d.tokens, "same seed must replay on the compact variant");
    assert_eq!(c.tokens.len(), 12);
}

#[test]
fn degenerate_sampling_params_error_cleanly() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let prompt = [1i32, 4, 20];
    // k = 0 and non-positive temperatures are rejected before model work
    assert!(generate(&ctx, &model, &prompt, SamplingParams::top_k(0, 0.8, 1, 4, None)).is_err());
    assert!(generate(&ctx, &model, &prompt, SamplingParams::top_k(4, 0.0, 1, 4, None)).is_err());
    assert!(generate(&ctx, &model, &prompt, SamplingParams::top_k(4, -2.0, 1, 4, None)).is_err());
    assert!(
        generate(&ctx, &model, &prompt, SamplingParams::top_k(4, f32::NAN, 1, 4, None)).is_err()
    );
    // k beyond the vocabulary clamps deterministically instead of erroring
    let big = SamplingParams::top_k(10_000, 0.8, 1, 4, None);
    let out = generate(&ctx, &model, &prompt, big.clone()).unwrap();
    let again = generate(&ctx, &model, &prompt, big).unwrap();
    assert_eq!(out.tokens, again.tokens);
    assert_eq!(out.tokens.len(), 4);
    assert!(out.tokens.iter().all(|&t| (t as usize) < ctx.cfg.vocab));

    // the server answers the rejection and keeps serving afterwards
    let a = arts();
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    assert!(handle.generate(&[1, 4], SamplingParams::top_k(0, 0.8, 1, 4, None)).is_err());
    assert!(handle.generate(&[1, 4], SamplingParams::top_k(4, 0.0, 1, 4, None)).is_err());
    let ok = handle.generate(&[1, 4], SamplingParams::greedy(2, None)).unwrap();
    assert_eq!(ok.tokens.len(), 2);
    handle.shutdown().unwrap();
}

#[test]
fn server_mixed_load_matches_offline_results() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let bench = hc_smoe::data::Benchmark::load(a.benchmark("arc_e")).unwrap();
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
        BatcherConfig {
            max_rows: ctx.manifest.eval_b,
            max_wait: Duration::from_millis(2),
        },
    )
    .unwrap();

    let prompt = [1i32, 4, 20, 3, 5];
    let seeds = [1u64, 2, 3];
    let direct = {
        let ev = Evaluator::new(&ctx).unwrap();
        ev.score_benchmark(&model, &bench).unwrap()
    };
    let mut served = Vec::new();
    std::thread::scope(|s| {
        // generation clients join and leave the continuous batch at
        // different lengths...
        let mut joins = Vec::new();
        for (gi, &seed) in seeds.iter().enumerate() {
            let handle = &handle;
            let prompt = &prompt;
            joins.push(s.spawn(move || {
                let params = SamplingParams::top_k(8, 0.8, seed, 6 + 4 * gi, None);
                handle.generate(prompt, params).unwrap()
            }));
        }
        // ...while score traffic flows through the dynamic batcher
        for cl in 0..2usize {
            let handle = &handle;
            let bench = &bench;
            let direct = &direct;
            s.spawn(move || {
                for (ii, item) in bench.items.iter().enumerate().skip(cl * 6).take(6) {
                    let scores = handle.score_item(&item.prompt, &item.choices).unwrap();
                    let pred = scores
                        .iter()
                        .enumerate()
                        .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                        .unwrap()
                        .0;
                    assert_eq!(pred, direct.predictions[ii], "served item {ii} differs");
                }
            });
        }
        for j in joins {
            served.push(j.join().expect("generation client panicked"));
        }
    });

    // a served generation is bit-identical to the offline API with the
    // same seed: both run the same Session loop on the same weights
    for (gi, (&seed, out)) in seeds.iter().zip(&served).enumerate() {
        let params = SamplingParams::top_k(8, 0.8, seed, 6 + 4 * gi, None);
        let offline = generate(&ctx, &model, &prompt, params).unwrap();
        assert_eq!(out.tokens, offline.tokens, "seed {seed}");
        assert_eq!(out.finish, offline.finish, "seed {seed}");
    }
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert_eq!(snap.gen_requests, 3);
    // gen_tokens counts decode-step output only: each sequence's first
    // token comes from the prefill logits, so max_new_tokens - 1 per seq
    let expected_tokens: u64 = (0..3).map(|gi| 6 + 4 * gi as u64 - 1).sum();
    assert_eq!(snap.gen_tokens, expected_tokens, "every decode-step token is counted");
    assert_eq!(snap.prefill_tokens, 3 * prompt.len() as u64);
    assert!(snap.decode_s > 0.0 && snap.decode_tok_s() > 0.0);
    assert_eq!(snap.requests, 12);
}

#[test]
fn empty_prompt_rows_do_not_panic_the_executor() {
    let a = arts();
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "mixsim"),
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    // regression: a RowSpec with start == 0 (empty prompt) used to compute
    // `pos - 1` at pos == 0 and panic the executor thread
    let row = RowSpec { seq: vec![17, 23, 42], start: 0, end: 3 };
    let (reply, rx) = std::sync::mpsc::channel();
    handle
        .sender()
        .send(ScoreRequest { rows: vec![row], reply, enqueued: Instant::now() }.into())
        .unwrap();
    let scores = rx.recv().expect("executor must answer, not panic");
    assert_eq!(scores.len(), 1);
    assert!(scores[0].is_finite());

    // an invalid generate request is answered with an error, and the
    // executor keeps serving afterwards
    let err = handle.generate(&[], SamplingParams::greedy(4, None));
    assert!(err.is_err(), "empty prompt must be rejected, not crash");
    let ok = handle.generate(&[17, 23], SamplingParams::greedy(2, None)).unwrap();
    assert_eq!(ok.tokens.len(), 2);
    handle.shutdown().unwrap();
}
