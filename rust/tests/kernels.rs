//! Kernel parity/property sweep: the tiled GEMM microkernels must be
//! bit-identical to the canonical scalar `matmul_reference` across
//! randomized shapes (tile-multiple and not, m=1 decode rows, k=0/n=1
//! edges) and thread counts; the int8 path must round-trip within its
//! scale bound, re-quantize deterministically, and stay bit-identical
//! across threads. Plus HCWT v2 reader robustness (truncated/corrupt/
//! wrong-version quantized sections fail descriptively, v1 files stay
//! byte-exact) and the artifacts-gated quantized-vs-f32 eval delta.

use hc_smoe::clustering::Linkage;
use hc_smoe::config::Artifacts;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{quantize_expert_weights, Method, Pipeline};
use hc_smoe::quality::quantization_delta;
use hc_smoe::similarity::Metric;
use hc_smoe::tensor::{
    dequantize_rows_i8, matmul, matmul_blocked_with, matmul_q8_with, matmul_reference,
    quantize_rows_i8,
};
use hc_smoe::util::proptest::{check, ensure};
use hc_smoe::util::Rng;
use hc_smoe::weights::Weights;

/// Named acceptance bound for the quantized-variant eval test: the mean
/// absolute benchmark-accuracy delta between a merged model and its int8
/// sibling must stay within this.
const QUANT_ACC_TOLERANCE: f64 = 0.2;

fn randn(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

// -------------------------------------------------------------------------
// Tiled f32 GEMM == scalar reference, at any shape and thread count
// -------------------------------------------------------------------------

#[test]
fn prop_tiled_gemm_bit_identical_to_reference() {
    check("tiled-gemm-parity", 90, 60, |rng| {
        let m = 1 + rng.below(33); // covers m=1 decode rows
        let k = rng.below(40); // covers k=0
        let n = 1 + rng.below(70); // covers n=1
        let a = randn(rng, m * k);
        let b = randn(rng, k * n);
        let reference = matmul_reference(&a, &b, m, k, n);
        for threads in [1usize, 2, 3, 8] {
            let tiled = matmul_blocked_with(&a, &b, m, k, n, threads);
            ensure(
                bits_equal(&reference, &tiled),
                format!("({m},{k},{n}) threads={threads}: tiled != reference"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn tiled_gemm_parity_at_pinned_edge_shapes() {
    // the shapes the microkernel's edge handling must get right: exact
    // tile multiples, off-by-one in each dim, the m=1 decode row, the
    // k=0 and n=1 degenerate reductions, and a prefill-sized block
    let shapes = [
        (4usize, 16usize, 16usize), // exactly one full tile
        (8, 32, 32),                // tile multiples
        (5, 17, 17),                // +1 past the tile in m and n
        (3, 16, 15),                // edge columns only
        (1, 64, 64),                // decode row
        (1, 0, 1),                  // k=0: all-zero output
        (3, 7, 1),                  // n=1 column vector
        (16, 1, 16),                // k=1
        (13, 31, 157),              // the historical odd-size pin
        (64, 64, 256),              // prefill-sized block
    ];
    let mut rng = Rng::new(41);
    for &(m, k, n) in &shapes {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let reference = matmul_reference(&a, &b, m, k, n);
        let serial = matmul(&a, &b, m, k, n);
        assert!(bits_equal(&reference, &serial), "serial ({m},{k},{n})");
        for threads in [2usize, 5] {
            let par = matmul_blocked_with(&a, &b, m, k, n, threads);
            assert!(bits_equal(&reference, &par), "({m},{k},{n}) threads={threads}");
        }
    }
}

#[test]
fn prop_tiled_gemm_handles_sparse_inputs_like_reference() {
    // the reference skips zero A values; the tiled kernel does not —
    // pin the documented bit-equivalence of the two for finite inputs
    check("tiled-gemm-zero-skip", 91, 40, |rng| {
        let m = 1 + rng.below(12);
        let k = 1 + rng.below(24);
        let n = 1 + rng.below(40);
        let mut a = randn(rng, m * k);
        for v in a.iter_mut() {
            if rng.below(3) == 0 {
                *v = 0.0;
            }
        }
        let b = randn(rng, k * n);
        let reference = matmul_reference(&a, &b, m, k, n);
        let tiled = matmul(&a, &b, m, k, n);
        ensure(bits_equal(&reference, &tiled), format!("({m},{k},{n}): sparse parity"))
    });
}

// -------------------------------------------------------------------------
// Int8 quantization: round-trip bounds, determinism, thread bit-identity
// -------------------------------------------------------------------------

#[test]
fn prop_quantize_roundtrip_within_scale_bound() {
    check("quantize-roundtrip-bound", 92, 50, |rng| {
        let rows = 1 + rng.below(12);
        let cols = 1 + rng.below(60);
        let w = randn(rng, rows * cols);
        let (q, scales) = quantize_rows_i8(&w, rows, cols);
        let (q2, scales2) = quantize_rows_i8(&w, rows, cols);
        ensure(q == q2, "re-quantization changed int8 payload")?;
        ensure(
            scales.iter().zip(&scales2).all(|(a, b)| a.to_bits() == b.to_bits()),
            "re-quantization changed scales",
        )?;
        let dq = dequantize_rows_i8(&q, &scales, rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let err = (w[r * cols + c] - dq[r * cols + c]).abs();
                ensure(
                    err <= scales[r] * 0.5 + 1e-7,
                    format!("row {r} col {c}: err {err} > scale/2 {}", scales[r] * 0.5),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn quantize_all_zero_rows_roundtrip_exactly() {
    let w = vec![0.0f32; 3 * 8];
    let (q, scales) = quantize_rows_i8(&w, 3, 8);
    assert!(q.iter().all(|&x| x == 0));
    assert!(scales.iter().all(|&s| s == 1.0));
    assert_eq!(dequantize_rows_i8(&q, &scales, 3, 8), w);
}

#[test]
fn prop_q8_gemm_thread_bit_identity() {
    check("q8-gemm-thread-identity", 93, 40, |rng| {
        let m = 1 + rng.below(20);
        let k = 1 + rng.below(32);
        let n = 1 + rng.below(48);
        let a = randn(rng, m * k);
        let w = randn(rng, k * n);
        let (q, scales) = quantize_rows_i8(&w, k, n);
        let serial = matmul_q8_with(&a, &q, &scales, m, k, n, 1);
        for threads in [2usize, 3, 8] {
            let par = matmul_q8_with(&a, &q, &scales, m, k, n, threads);
            ensure(
                bits_equal(&serial, &par),
                format!("({m},{k},{n}) threads={threads}: q8 not bit-identical"),
            )?;
        }
        Ok(())
    });
}

// -------------------------------------------------------------------------
// HCWT v2 reader robustness
// -------------------------------------------------------------------------

fn quantized_bytes() -> Vec<u8> {
    let cfg = hc_smoe::config::ModelCfg {
        name: "qrobust".into(),
        n_layer: 2,
        d: 4,
        m: 4,
        n_exp: 3,
        k: 1,
        heads: 2,
        vocab: 11,
        t_max: 8,
        shared: false,
        m_shared: 4,
        cap_factor: 2.0,
        block_c: 4,
    };
    let w = quantize_expert_weights(&Weights::synthesize(&cfg, 7)).unwrap();
    let tmp = std::env::temp_dir().join(format!("hcwt_robust_{}.hcwt", std::process::id()));
    w.save(&tmp).unwrap();
    let bytes = std::fs::read(&tmp).unwrap();
    std::fs::remove_file(tmp).ok();
    bytes
}

#[test]
fn v2_truncations_fail_descriptively_at_every_length() {
    let bytes = quantized_bytes();
    assert_eq!(&bytes[4..8], &2u32.to_le_bytes());
    // every strict prefix must error (not panic, not succeed) — walk a
    // spread of cut points including section boundaries
    let cuts: Vec<usize> = (0..8)
        .map(|i| i * bytes.len() / 8)
        .chain([bytes.len() - 1, bytes.len() - 4])
        .collect();
    for cut in cuts {
        let err = Weights::from_bytes(&bytes[..cut])
            .expect_err(&format!("prefix of {cut} bytes must not parse"));
        let msg = err.to_string().to_lowercase();
        assert!(
            msg.contains("truncated") || msg.contains("magic") || msg.contains("remain"),
            "cut {cut}: undescriptive error {msg:?}"
        );
    }
}

#[test]
fn wrong_version_fails_descriptively() {
    let mut bytes = quantized_bytes();
    bytes[4..8].copy_from_slice(&3u32.to_le_bytes());
    let err = Weights::from_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("unsupported HCWT version 3"), "{err}");
}

#[test]
fn corrupt_quant_count_fails_without_huge_alloc() {
    // a v2 section claiming absurd sizes appended to a clean v1 file must
    // fail on the bounds check before any large allocation
    let cfg_small = hc_smoe::config::ModelCfg {
        name: "small".into(),
        n_layer: 1,
        d: 2,
        m: 2,
        n_exp: 2,
        k: 1,
        heads: 1,
        vocab: 5,
        t_max: 4,
        shared: false,
        m_shared: 2,
        cap_factor: 2.0,
        block_c: 2,
    };
    let w1 = Weights::synthesize(&cfg_small, 3);
    let tmp = std::env::temp_dir().join(format!("hcwt_corrupt_{}.hcwt", std::process::id()));
    w1.save(&tmp).unwrap();
    let mut v1 = std::fs::read(&tmp).unwrap();
    std::fs::remove_file(tmp).ok();
    // claim v2 with one quant tensor of absurd declared dims but no data
    v1[4..8].copy_from_slice(&2u32.to_le_bytes());
    v1.extend_from_slice(&1u32.to_le_bytes()); // nq = 1
    v1.extend_from_slice(&1u32.to_le_bytes()); // name_len
    v1.push(b'x');
    v1.extend_from_slice(&2u32.to_le_bytes()); // ndim = 2
    v1.extend_from_slice(&u32::MAX.to_le_bytes()); // dims[0] huge
    v1.extend_from_slice(&u32::MAX.to_le_bytes()); // dims[1] huge
    let err = Weights::from_bytes(&v1).unwrap_err().to_string();
    assert!(
        err.contains("remain") || err.contains("overflow"),
        "corrupt sizes must fail on the bounds check, got: {err}"
    );
    // arbitrary garbage must also error, never panic
    let garbage: Vec<u8> = (0..64u8).collect();
    assert!(Weights::from_bytes(&garbage).is_err());
}

#[test]
fn quantized_file_name_collision_is_rejected() {
    // craft v2 bytes whose quant section reuses an f32 tensor name
    let cfg = hc_smoe::config::ModelCfg {
        name: "collide".into(),
        n_layer: 1,
        d: 2,
        m: 2,
        n_exp: 2,
        k: 1,
        heads: 1,
        vocab: 5,
        t_max: 4,
        shared: false,
        m_shared: 2,
        cap_factor: 2.0,
        block_c: 2,
    };
    let w = Weights::synthesize(&cfg, 5);
    let tmp = std::env::temp_dir().join(format!("hcwt_collide_{}.hcwt", std::process::id()));
    w.save(&tmp).unwrap();
    let mut bytes = std::fs::read(&tmp).unwrap();
    std::fs::remove_file(tmp).ok();
    bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
    bytes.extend_from_slice(&1u32.to_le_bytes()); // nq = 1
    let name = b"embed"; // collides with the f32 embed tensor
    bytes.extend_from_slice(&(name.len() as u32).to_le_bytes());
    bytes.extend_from_slice(name);
    bytes.extend_from_slice(&1u32.to_le_bytes()); // ndim = 1
    bytes.extend_from_slice(&2u32.to_le_bytes()); // dims = [2]
    bytes.extend_from_slice(&1.0f32.to_le_bytes()); // 1 scale
    bytes.extend_from_slice(&[0u8, 0u8]); // 2 int8 values
    let err = Weights::from_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("collides"), "{err}");
}

// -------------------------------------------------------------------------
// Quantized-variant eval delta (artifacts-gated, like integration.rs)
// -------------------------------------------------------------------------

fn ctx() -> Option<ModelContext> {
    let arts = Artifacts::discover();
    if !arts.root.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(ModelContext::load(&arts, "mixsim").expect("load mixsim"))
}

#[test]
fn quantized_variant_eval_delta_within_tolerance() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    })
    .plan(&ctx, &stats, 4)
    .unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let pairs = quantization_delta(&ctx, &cm, &["arc_e", "boolq"]).unwrap();
    let mean_delta = pairs.iter().map(|(f, q)| (f - q).abs()).sum::<f64>() / pairs.len() as f64;
    assert!(
        mean_delta <= QUANT_ACC_TOLERANCE,
        "mean |f32 - int8| accuracy delta {mean_delta} exceeds {QUANT_ACC_TOLERANCE} ({pairs:?})"
    );
    // the int8 variant is also smaller on disk than its f32 source
    let qw = quantize_expert_weights(&cm.weights).unwrap();
    assert!(qw.byte_size() < cm.weights.byte_size());
    assert_eq!(qw.param_count(), cm.weights.param_count());
}
