//! Integration tests over the real AOT artifacts: the PJRT runtime, the
//! compression pipeline end to end, and the runtime identities the design
//! rests on. Requires `make artifacts` (skipped gracefully otherwise).

use hc_smoe::calib::CalibStats;
use hc_smoe::clustering::{KmeansInit, Linkage};
use hc_smoe::config::Artifacts;
use hc_smoe::data::TokenStream;
use hc_smoe::eval::Evaluator;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{Method, Pipeline, PlanKind};
use hc_smoe::similarity::Metric;

fn ctx() -> Option<ModelContext> {
    let arts = Artifacts::discover();
    if !arts.root.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(ModelContext::load(&arts, "mixsim").expect("load mixsim"))
}

fn hc_method() -> Method {
    Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    }
}

#[test]
fn logits_shape_and_finiteness() {
    let Some(ctx) = ctx() else { return };
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let model = ctx.load_original().unwrap();
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 200) as i32).collect();
    let logits = ctx.run_logits(&model, &ids).unwrap();
    assert_eq!(logits.shape(), &[b, t, ctx.cfg.vocab]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
}

#[test]
fn logits_deterministic_across_runs() {
    let Some(ctx) = ctx() else { return };
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let model = ctx.load_original().unwrap();
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 101) as i32).collect();
    let a = ctx.run_logits(&model, &ids).unwrap();
    let b2 = ctx.run_logits(&model, &ids).unwrap();
    assert_eq!(a.data(), b2.data());
}

#[test]
fn calibration_stats_are_consistent() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    assert_eq!(stats.n_layers(), ctx.cfg.n_layer);
    assert_eq!(stats.n_experts(), ctx.cfg.n_exp);
    for l in &stats.layers {
        // every token routes to exactly k experts
        let total: f32 = l.counts.iter().sum();
        assert!((total - (stats.n_tokens * ctx.cfg.k) as f32).abs() < 1.0);
        // mean outputs are finite and non-degenerate
        assert!(l.mean_out.data().iter().all(|x| x.is_finite()));
        assert!(l.mean_out.l2_norm() > 0.0);
    }
}

#[test]
fn merged_model_keeps_router_and_changes_experts() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, 4).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    // Fig. 3: router untouched
    for l in 0..ctx.cfg.n_layer {
        assert_eq!(
            ctx.base.router(l).unwrap().data(),
            cm.weights.router(l).unwrap().data(),
            "router must be unchanged"
        );
    }
    // all members of a group share identical expert weights
    let PlanKind::Merge { groups, .. } = &cm.plan.kind else { panic!("merge plan") };
    for (l, layer_groups) in groups.iter().enumerate() {
        for g in layer_groups {
            let first = cm.weights.expert(l, g[0]).unwrap();
            for &e in &g[1..] {
                let other = cm.weights.expert(l, e).unwrap();
                assert_eq!(first.wg.data(), other.wg.data());
            }
        }
        let covered: usize = layer_groups.iter().map(|g| g.len()).sum();
        assert_eq!(covered, ctx.cfg.n_exp, "partition covers all experts");
    }
    // merging must actually change outputs vs the original
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 150) as i32).collect();
    let orig = ctx.load_original().unwrap();
    let merged = cm.load(&ctx).unwrap();
    let a = ctx.run_logits(&orig, &ids).unwrap();
    let b2 = ctx.run_logits(&merged, &ids).unwrap();
    assert_ne!(a.data(), b2.data());
}

#[test]
fn r_equals_n_merge_is_identity() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, ctx.cfg.n_exp).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 120) as i32).collect();
    let orig = ctx.load_original().unwrap();
    let merged = cm.load(&ctx).unwrap();
    let a = ctx.run_logits(&orig, &ids).unwrap();
    let b2 = ctx.run_logits(&merged, &ids).unwrap();
    for (x, y) in a.data().iter().zip(b2.data()) {
        assert!((x - y).abs() < 1e-5, "identity merge must not change logits");
    }
}

#[test]
fn pruning_reroutes_to_survivors() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(Method::SPrune).plan(&ctx, &stats, 4).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let PlanKind::Prune { keep } = &cm.plan.kind else { panic!("prune plan") };
    // weights untouched; only the mask changes
    assert_eq!(
        ctx.base.expert(0, 0).unwrap().wg.data(),
        cm.weights.expert(0, 0).unwrap().wg.data()
    );
    let total: usize = keep.iter().map(|k| k.len()).sum();
    assert_eq!(total, 4 * ctx.cfg.n_layer, "dynamic budget preserves the average");
    for (l, kept) in keep.iter().enumerate() {
        for e in 0..ctx.cfg.n_exp {
            let masked = cm.mask[l * ctx.cfg.n_exp + e] < -1e20;
            assert_eq!(masked, !kept.contains(&e));
        }
    }
}

#[test]
fn compact_export_matches_duplicated_layout() {
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, 4).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let (cw, remap) = cm.to_compact(&ctx).unwrap();
    assert_eq!(cw.n_experts().unwrap(), 4);
    assert!(remap.iter().all(|&s| (0..4).contains(&s)));
    // Run both paths on the same batch. They are NOT bit-identical: each
    // path drops tokens at its own capacity hotspots (the full layout keeps
    // one queue per duplicated slot; compact folds a group into one queue
    // with 2x headroom). Functional equivalence is asserted at the
    // distribution level: high logit cosine similarity + top-1 agreement.
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 180) as i32).collect();
    let merged = cm.load(&ctx).unwrap();
    let full = ctx.run_logits(&merged, &ids).unwrap();
    let compact = ctx.load_compact(4, &cw, remap, "compact").unwrap();
    let comp = ctx.run_logits_compact(&compact, &ids).unwrap();
    let v = full.shape()[2];
    let mut cos_sum = 0f64;
    let mut top1_agree = 0usize;
    let rows = b * t;
    for i in 0..rows {
        let rf = &full.data()[i * v..(i + 1) * v];
        let rc = &comp.data()[i * v..(i + 1) * v];
        cos_sum += hc_smoe::tensor::cosine_sim(rf, rc) as f64;
        let am = |r: &[f32]| {
            r.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        if am(rf) == am(rc) {
            top1_agree += 1;
        }
    }
    let cos = cos_sum / rows as f64;
    let agree = top1_agree as f64 / rows as f64;
    assert!(cos > 0.82, "compact/full logit cosine only {cos:.4}");
    assert!(agree > 0.78, "compact/full top-1 agreement only {agree:.4}");
}

#[test]
fn evaluator_beats_chance_on_learned_task_and_respects_bounds() {
    let Some(ctx) = ctx() else { return };
    let ev = Evaluator::new(&ctx).unwrap();
    let model = ctx.load_original().unwrap();
    let acc = ev.accuracy(&model, "hella").unwrap();
    assert!(acc > 0.4, "original model must beat chance on hella: {acc}");
    for task in ["arc_e", "boolq"] {
        let a = ev.accuracy(&model, task).unwrap();
        assert!((0.0..=1.0).contains(&a));
    }
}

#[test]
fn perplexity_is_sane_and_degrades_under_heavy_merge() {
    let Some(ctx) = ctx() else { return };
    let ev = Evaluator::new(&ctx).unwrap();
    let stream = TokenStream::load(ctx.arts.calib_tokens_path("ppl_heldout")).unwrap();
    let orig = ctx.load_original().unwrap();
    let p0 = ev.perplexity(&orig, &stream).unwrap();
    assert!(p0 > 1.0 && p0 < 200.0, "original ppl {p0}");
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, 2).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let merged = cm.load(&ctx).unwrap();
    let p1 = ev.perplexity(&merged, &stream).unwrap();
    assert!(p1 > p0, "75% merge must not improve ppl: {p0} -> {p1}");
}

#[test]
fn kmeans_rnd_differs_from_hc_somewhere() {
    // the instability argument: with enough seeds K-rnd finds a different
    // partition than deterministic HC on at least one layer
    let Some(ctx) = ctx() else { return };
    let stats = ctx.calibrate("general").unwrap();
    let mut differs = false;
    for seed in 1..6u64 {
        let km = Pipeline::new(Method::KMeans {
            init: KmeansInit::Random { seed },
            metric: Metric::ExpertOutput,
            merge: MergeStrategy::Frequency,
        })
        .plan(&ctx, &stats, 4)
        .unwrap();
        let hc = Pipeline::new(hc_method()).plan(&ctx, &stats, 4).unwrap();
        let (PlanKind::Merge { groups: ga, .. }, PlanKind::Merge { groups: gb, .. }) =
            (&km.kind, &hc.kind)
        else {
            panic!()
        };
        if ga != gb {
            differs = true;
            break;
        }
    }
    assert!(differs, "expected at least one K-rnd seed to disagree with HC");
}

#[test]
fn calib_stats_differ_across_domains() {
    let Some(ctx) = ctx() else { return };
    let g = ctx.calibrate("general").unwrap();
    let m = ctx.calibrate("math").unwrap();
    let gc = &g.layers[0].counts;
    let mc = &m.layers[0].counts;
    assert_ne!(gc, mc, "domain shift must move routing frequencies");
}

#[test]
fn calib_stats_accumulate_across_batches() {
    let Some(ctx) = ctx() else { return };
    let ts = TokenStream::load(ctx.arts.calib_tokens_path("general")).unwrap();
    let full = CalibStats::collect(&ctx, &ts).unwrap();
    assert_eq!(full.n_tokens, ctx.manifest.calib_tokens());
}
