//! End-to-end integration on a synthesized artifact set: the full
//! calibrate → cluster → merge → evaluate → serve loop through the native
//! CPU backend, with zero Python, PJRT or pre-built artifacts. This is
//! the artifact-free twin of `tests/integration.rs` (which runs only
//! against real `make artifacts` output).

use std::path::PathBuf;
use std::time::Duration;

use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::config::Artifacts;
use hc_smoe::data::TokenStream;
use hc_smoe::eval::Evaluator;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{Method, Pipeline, PlanKind};
use hc_smoe::serving::{serve, BatcherConfig, ServeSpec};
use hc_smoe::similarity::Metric;

/// Synthesize one artifact set per test process (tests within a binary
/// share it; the directory is keyed by pid to avoid cross-run clashes).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_e2e_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0xE2E).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

fn hc_method() -> Method {
    Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    }
}

#[test]
fn native_backend_is_selected_and_runs_logits() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    assert_eq!(ctx.backend_name(), "native");
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let model = ctx.load_original().unwrap();
    let ids: Vec<i32> = (0..b * t).map(|i| (i % ctx.cfg.vocab) as i32).collect();
    let logits = ctx.run_logits(&model, &ids).unwrap();
    assert_eq!(logits.shape(), &[b, t, ctx.cfg.vocab]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
    // deterministic across runs
    let again = ctx.run_logits(&model, &ids).unwrap();
    assert_eq!(logits.data(), again.data());
}

#[test]
fn calibration_statistics_are_consistent() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    assert_eq!(stats.n_layers(), ctx.cfg.n_layer);
    assert_eq!(stats.n_experts(), ctx.cfg.n_exp);
    for l in &stats.layers {
        // every token routes to exactly k experts
        let total: f32 = l.counts.iter().sum();
        assert!(
            (total - (stats.n_tokens * ctx.cfg.k) as f32).abs() < 1.0,
            "counts {total} vs {}",
            stats.n_tokens * ctx.cfg.k
        );
        // full-softmax scores sum to the token count
        let psum: f32 = l.probs_sum.iter().sum();
        assert!((psum - stats.n_tokens as f32).abs() < 1.0, "probs_sum {psum}");
        // top-k gates sum to the token count (softmax over k per token)
        let gsum: f32 = l.gate_sum.iter().sum();
        assert!((gsum - stats.n_tokens as f32).abs() < 1.0, "gate_sum {gsum}");
        assert!(l.mean_out.data().iter().all(|x| x.is_finite()));
        assert!(l.mean_out.l2_norm() > 0.0);
        assert_eq!(l.rl_sub.shape(), &[ctx.manifest.t_sub, ctx.cfg.n_exp]);
        assert_eq!(l.act_sub.shape()[1], ctx.manifest.t_act);
    }
    // domain shift must move routing frequencies
    let math = ctx.calibrate("math").unwrap();
    assert_ne!(stats.layers[0].counts, math.layers[0].counts);
}

#[test]
fn full_compress_eval_pipeline_runs() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let r = ctx.cfg.n_exp / 2;
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    // Fig. 3: router untouched; members share identical merged weights
    for l in 0..ctx.cfg.n_layer {
        assert_eq!(
            ctx.base.router(l).unwrap().data(),
            cm.weights.router(l).unwrap().data()
        );
    }
    let PlanKind::Merge { groups, .. } = &cm.plan.kind else { panic!("merge plan") };
    for (l, layer_groups) in groups.iter().enumerate() {
        let covered: usize = layer_groups.iter().map(|g| g.len()).sum();
        assert_eq!(covered, ctx.cfg.n_exp);
        for g in layer_groups {
            let first = cm.weights.expert(l, g[0]).unwrap();
            for &e in &g[1..] {
                assert_eq!(first.wg.data(), cm.weights.expert(l, e).unwrap().wg.data());
            }
        }
    }
    // evaluation end to end
    let ev = Evaluator::new(&ctx).unwrap();
    let original = ctx.load_original().unwrap();
    let merged = cm.load(&ctx).unwrap();
    for task in ["arc_e", "boolq"] {
        let a = ev.accuracy(&merged, task).unwrap();
        assert!((0.0..=1.0).contains(&a), "{task}: {a}");
    }
    let stream = TokenStream::load(ctx.arts.calib_tokens_path("ppl_heldout")).unwrap();
    let p_orig = ev.perplexity(&original, &stream).unwrap();
    let p_merged = ev.perplexity(&merged, &stream).unwrap();
    assert!(p_orig.is_finite() && p_orig > 1.0, "ppl {p_orig}");
    assert!(p_merged.is_finite() && p_merged > 1.0, "ppl {p_merged}");
}

#[test]
fn identity_merge_preserves_logits_exactly() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method())
        .plan(&ctx, &stats, ctx.cfg.n_exp)
        .unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 90) as i32).collect();
    let a = ctx.run_logits(&ctx.load_original().unwrap(), &ids).unwrap();
    let b2 = ctx.run_logits(&cm.load(&ctx).unwrap(), &ids).unwrap();
    // r = n leaves every singleton cluster's weights bit-identical, and
    // the native forward is deterministic, so logits match exactly
    assert_eq!(a.data(), b2.data());
}

#[test]
fn pruning_masks_reroute_and_change_outputs() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let r = ctx.cfg.n_exp / 2;
    let plan = Pipeline::new(Method::SPrune).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let PlanKind::Prune { keep } = &cm.plan.kind else { panic!("prune plan") };
    let total: usize = keep.iter().map(|k| k.len()).sum();
    assert_eq!(total, r * ctx.cfg.n_layer);
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 77) as i32).collect();
    let orig = ctx.run_logits(&ctx.load_original().unwrap(), &ids).unwrap();
    let pruned = ctx.run_logits(&cm.load(&ctx).unwrap(), &ids).unwrap();
    assert_ne!(orig.data(), pruned.data());
    assert!(pruned.data().iter().all(|x| x.is_finite()));
}

#[test]
fn compact_variant_agrees_with_duplicated_layout() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let r = ctx.cfg.n_exp / 2;
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let (cw, remap) = cm.to_compact(&ctx).unwrap();
    assert_eq!(cw.n_experts().unwrap(), r);
    assert!(remap.iter().all(|&s| (s as usize) < r));
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 85) as i32).collect();
    let full = ctx.run_logits(&cm.load(&ctx).unwrap(), &ids).unwrap();
    let compact_model = ctx.load_compact(r, &cw, remap, "compact").unwrap();
    let comp = ctx.run_logits_compact(&compact_model, &ids).unwrap();
    // Same math, but each layout has its own capacity queues (full keeps
    // one per duplicated slot; compact folds a group into one queue), so
    // agreement is distributional, not bitwise.
    let v = full.shape()[2];
    let mut cos_sum = 0f64;
    for i in 0..b * t {
        let rf = &full.data()[i * v..(i + 1) * v];
        let rc = &comp.data()[i * v..(i + 1) * v];
        cos_sum += hc_smoe::tensor::cosine_sim(rf, rc) as f64;
    }
    let cos = cos_sum / (b * t) as f64;
    assert!(cos > 0.98, "compact/full logit cosine only {cos:.4}");
}

#[test]
fn dssim_shared_expert_model_runs() {
    let ctx = ModelContext::load(&arts(), "dssim").unwrap();
    assert!(ctx.cfg.shared);
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let model = ctx.load_original().unwrap();
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 60) as i32 + 16).collect();
    let logits = ctx.run_logits(&model, &ids).unwrap();
    assert!(logits.data().iter().all(|x| x.is_finite()));
    let stats = ctx.calibrate("general").unwrap();
    assert_eq!(stats.n_experts(), ctx.cfg.n_exp);
}

#[test]
fn serving_through_native_backend_matches_direct_scores() {
    let a = arts();
    let ctx = ModelContext::load(&a, "mixsim").unwrap();
    let bench = hc_smoe::data::Benchmark::load(a.benchmark("arc_e")).unwrap();
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "mixsim"),
        BatcherConfig {
            max_rows: ctx.manifest.eval_b,
            max_wait: Duration::from_millis(1),
        },
    )
    .unwrap();
    let ev = Evaluator::new(&ctx).unwrap();
    let model = ctx.load_original().unwrap();
    let direct = ev.score_benchmark(&model, &bench).unwrap();
    for (ii, item) in bench.items.iter().take(4).enumerate() {
        let scores = handle.score_item(&item.prompt, &item.choices).unwrap();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(pred, direct.predictions[ii], "item {ii} prediction differs");
    }
    handle.shutdown().unwrap();
}
