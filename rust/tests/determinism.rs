//! Determinism contract of the `parallel` subsystem: every parallel hot
//! path must produce **bit-identical** results to its serial reference at
//! any thread count (property tests over seeded random expert sets). This
//! is what lets the auto-dispatch heuristics pick thread counts freely
//! without perturbing a single table of the paper reproduction.

use hc_smoe::calib::synthetic::synthetic_grouped;
use hc_smoe::clustering::{
    fcm_with, hierarchical_with, kmeans_with, single_shot, KmeansInit, Linkage,
};
use hc_smoe::similarity::{
    distance_matrix, distance_matrix_serial, distance_matrix_with, features, Distance, Metric,
};
use hc_smoe::tensor::{corr_matrix_with, matmul, matmul_blocked_with};
use hc_smoe::util::proptest::{check, ensure};
use hc_smoe::util::Rng;
use hc_smoe::weights::Weights;

const THREAD_COUNTS: [usize; 4] = [2, 3, 4, 7];

fn random_feats(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn matrix_bits(m: &[Vec<f32>]) -> Vec<u32> {
    m.iter().flatten().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_distance_matrix_bit_identical_across_thread_counts() {
    check("par-distance-matrix", 11, 30, |rng| {
        let n = 2 + rng.below(63);
        let d = 1 + rng.below(48);
        let feats = random_feats(rng, n, d);
        for dist in [Distance::Euclidean, Distance::Cosine] {
            let serial = distance_matrix_serial(&feats, dist);
            for threads in THREAD_COUNTS {
                let par = distance_matrix_with(&feats, dist, threads);
                ensure(
                    matrix_bits(&serial) == matrix_bits(&par),
                    format!("distance matrix diverged at n={n} d={d} threads={threads}"),
                )?;
            }
            // the auto-dispatch entry point must agree with both
            let auto = distance_matrix(&feats, dist);
            ensure(
                matrix_bits(&serial) == matrix_bits(&auto),
                "auto-dispatched distance matrix diverged",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_hierarchical_identical_across_thread_counts() {
    check("par-hierarchical", 12, 30, |rng| {
        // span the PAR_MIN_CLUSTERS boundary so both scan paths are hit
        let n = 2 + rng.below(40);
        let r = 1 + rng.below(n);
        let feats = random_feats(rng, n, 4);
        let dist = distance_matrix_serial(&feats, Distance::Euclidean);
        for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
            let serial = hierarchical_with(&dist, r, linkage, 1);
            serial.validate().map_err(|e| e.to_string())?;
            for threads in THREAD_COUNTS {
                let par = hierarchical_with(&dist, r, linkage, threads);
                ensure(
                    serial == par,
                    format!("{linkage:?} clustering diverged at n={n} r={r} threads={threads}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kmeans_identical_across_thread_counts() {
    check("par-kmeans", 13, 25, |rng| {
        // small and large n: chunked sweeps see 1-, partial- and many-chunk splits
        let n = 2 + rng.below(80);
        let r = 1 + rng.below(n);
        let feats = random_feats(rng, n, 3);
        let seed = rng.next_u64();
        for init in [KmeansInit::Fixed, KmeansInit::Random { seed }] {
            let serial = kmeans_with(&feats, r, init, 50, 1);
            serial.validate().map_err(|e| e.to_string())?;
            for threads in THREAD_COUNTS {
                let par = kmeans_with(&feats, r, init, 50, threads);
                ensure(
                    serial == par,
                    format!("kmeans {init:?} diverged at n={n} r={r} threads={threads}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fcm_memberships_bit_identical_across_thread_counts() {
    check("par-fcm", 14, 20, |rng| {
        let n = 2 + rng.below(80);
        let r = 1 + rng.below(n.min(8));
        let feats = random_feats(rng, n, 3);
        let seed = rng.next_u64();
        let serial = fcm_with(&feats, r, 2.0, 15, seed, 1);
        for threads in THREAD_COUNTS {
            let par = fcm_with(&feats, r, 2.0, 15, seed, threads);
            ensure(
                matrix_bits(&serial.membership) == matrix_bits(&par.membership),
                format!("fcm memberships diverged at n={n} r={r} threads={threads}"),
            )?;
            ensure(
                matrix_bits(&serial.centers) == matrix_bits(&par.centers),
                format!("fcm centers diverged at n={n} r={r} threads={threads}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_matmul_and_corr_bit_identical_across_thread_counts() {
    check("par-matmul-corr", 15, 15, |rng| {
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(24);
        let n = 1 + rng.below(200);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let serial = matmul(&a, &b, m, k, n);
        for threads in THREAD_COUNTS {
            let par = matmul_blocked_with(&a, &b, m, k, n, threads);
            let same = serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits());
            ensure(same, format!("matmul diverged at {m}x{k}x{n} threads={threads}"))?;
        }
        let t = 1 + rng.below(32);
        let x: Vec<f32> = (0..m * t).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..k * t).map(|_| rng.normal() as f32).collect();
        let serial = corr_matrix_with(&x, &y, m, k, t, 1);
        for threads in THREAD_COUNTS {
            let par = corr_matrix_with(&x, &y, m, k, t, threads);
            let same = serial.iter().zip(&par).all(|(u, v)| u.to_bits() == v.to_bits());
            ensure(same, format!("corr diverged at {m}x{k}x{t} threads={threads}"))?;
        }
        Ok(())
    });
}

/// End-to-end slice of the paper pipeline on synthetic statistics: the
/// similarity → distance → clustering chain must produce identical expert
/// groupings serial vs parallel, for every metric the ablations sweep.
#[test]
fn pipeline_slice_identical_serial_vs_parallel() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) | 1);
        let n = 16 + rng.below(48);
        let d = 8 + rng.below(56);
        let groups: Vec<Vec<usize>> = (0..n / 2).map(|g| vec![2 * g, 2 * g + 1]).collect();
        let stats = synthetic_grouped(n, d, &groups, 0.05, seed + 1);
        let weights = Weights::new(Default::default());
        for metric in [Metric::ExpertOutput, Metric::RouterLogits] {
            let feats = features(metric, &weights, &stats, 0).unwrap();
            let serial_d = distance_matrix_serial(&feats, Distance::Euclidean);
            let par_d = distance_matrix_with(&feats, Distance::Euclidean, 4);
            assert_eq!(matrix_bits(&serial_d), matrix_bits(&par_d), "seed={seed}");
            let r = (n / 4).max(1);
            let serial_c = hierarchical_with(&serial_d, r, Linkage::Average, 1);
            let par_c = hierarchical_with(&par_d, r, Linkage::Average, 4);
            assert_eq!(serial_c, par_c, "seed={seed} metric={metric:?}");
            serial_c.validate().unwrap();
            // single_shot is serial-only; it must stay deterministic too
            let s1 = single_shot(&feats, &stats.counts, r);
            let s2 = single_shot(&feats, &stats.counts, r);
            assert_eq!(s1, s2);
        }
    }
}
