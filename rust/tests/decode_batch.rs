//! Batched continuous-decode test suite.
//!
//! The headline contract: [`Backend::run_decode_batch`] advances B
//! sequences one token in a single call and its per-sequence logits are
//! **bit-identical** to B standalone [`Backend::run_decode`] calls — and,
//! transitively, to the uncached full forward over each sequence's prefix.
//! Pinned here across the full and compact expert layouts, under router
//! masks, with the `dssim`-style shared expert, with mixed sequence
//! lengths in one batch, and with sequences joining/leaving mid-stream.
//! Plus the serving side: the executor actually batches decode under
//! concurrent load (B > 1), and the bounded admission budget keeps a
//! burst of long prompts from stalling an in-flight sequence (the
//! head-of-line regression).

use std::path::PathBuf;
use std::time::Duration;

use hc_smoe::backend::native::{forward_logits_with, NativeBackend};
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::eval::Evaluator;
use hc_smoe::generate::{generate, SamplingParams};
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::MASK_OFF;
use hc_smoe::serving::{reply_channel, serve, BatcherConfig, GenerateRequest, Request, ServeSpec};
use hc_smoe::weights::Weights;

fn tiny_cfg(shared: bool) -> ModelCfg {
    ModelCfg {
        name: "dbatch".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 48,
        shared,
        m_shared: 16,
        // k=2 distinct experts per token keeps every capacity queue below
        // cap_factor=4 capacity — structurally drop-free, so cached,
        // batched and uncached dispatch agree exactly at every prefix
        cap_factor: 4.0,
        block_c: 4,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Synthesize one artifact set per test process (server-side tests).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_dbatch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0xD8A7).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

/// Drive the same token streams through (a) per-sequence `run_decode`, (b)
/// one auto-gated `run_decode_batch` call per step, (c) the same batch at
/// an explicit worker count (`run_decode_batch_with`), and (d) the
/// uncached full forward at multiple thread counts, asserting bitwise
/// equality everywhere. `prompts` may have mixed lengths.
fn assert_batch_identity(
    cfg: &ModelCfg,
    w: &Weights,
    n_slots: usize,
    mask: &[f32],
    remap: Option<&[i32]>,
    prompts: &[Vec<i32>],
    steps: usize,
) {
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(w, n_slots).unwrap();
    let v = cfg.vocab;
    let feed = |s: usize, i: usize| -> i32 { ((3 + s * 11 + i * 7) % v) as i32 };

    let mut seq_caches: Vec<Box<dyn KvCache>> = Vec::new();
    let mut batch_caches: Vec<Box<dyn KvCache>> = Vec::new();
    let mut threaded_caches: Vec<Box<dyn KvCache>> = Vec::new();
    let mut seqs: Vec<Vec<i32>> = Vec::new();
    let prefill = |p: &[i32]| -> Box<dyn KvCache> {
        let mut opts = PrefillOpts::new(mask);
        if let Some(rm) = remap {
            opts = opts.remap(rm);
        }
        let (cache, _) = backend.run_prefill(state.as_ref(), p, opts).unwrap();
        cache.expect("fresh prefill returns a cache")
    };
    for p in prompts {
        seq_caches.push(prefill(p));
        batch_caches.push(prefill(p));
        threaded_caches.push(prefill(p));
        seqs.push(p.clone());
    }
    for i in 0..steps {
        let tokens: Vec<i32> = (0..prompts.len()).map(|s| feed(s, i)).collect();
        let rows = {
            let mut refs: Vec<&mut dyn KvCache> =
                batch_caches.iter_mut().map(|c| c.as_mut()).collect();
            backend
                .run_decode_batch(state.as_ref(), &mut refs, &tokens, mask, remap)
                .unwrap()
        };
        assert_eq!(rows.len(), prompts.len());
        // the explicit-thread-count entry point is bit-identical too (the
        // parallel determinism contract at the batch level)
        let rows_threaded = {
            let mut refs: Vec<&mut dyn KvCache> =
                threaded_caches.iter_mut().map(|c| c.as_mut()).collect();
            backend
                .run_decode_batch_with(state.as_ref(), &mut refs, &tokens, mask, remap, 3)
                .unwrap()
        };
        for (s, (row, trow)) in rows.iter().zip(&rows_threaded).enumerate() {
            assert_eq!(
                bits(row),
                bits(trow),
                "explicit-thread batch differs from auto-gated batch (seq {s}, step {i})"
            );
        }
        for (s, row) in rows.iter().enumerate() {
            let single = backend
                .run_decode(state.as_ref(), seq_caches[s].as_mut(), tokens[s], mask, remap)
                .unwrap();
            assert_eq!(
                bits(row),
                bits(&single),
                "batched row differs from sequential decode (seq {s}, step {i})"
            );
            seqs[s].push(tokens[s]);
            assert_eq!(batch_caches[s].seq_len(), seqs[s].len());
            for threads in [1usize, 4] {
                let full = forward_logits_with(
                    cfg,
                    w,
                    &seqs[s],
                    1,
                    seqs[s].len(),
                    mask,
                    remap,
                    n_slots,
                    threads,
                )
                .unwrap();
                assert_eq!(
                    bits(&full.data()[(seqs[s].len() - 1) * v..]),
                    bits(row),
                    "batched row differs from full forward (seq {s}, step {i}, threads {threads})"
                );
            }
        }
    }
}

#[test]
fn batched_matches_sequential_mixed_lengths_masked() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 31);
    // prune one expert per layer through the router mask so the masked
    // path is exercised under batching too
    let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    mask[1] = MASK_OFF;
    mask[cfg.n_exp + 3] = MASK_OFF;
    let v = cfg.vocab;
    // mixed lengths in one batch: 3, 5 and 8-token prompts
    let prompts: Vec<Vec<i32>> = [3usize, 5, 8]
        .iter()
        .map(|&len| (0..len).map(|i| ((2 + i * 5) % v) as i32).collect())
        .collect();
    assert_batch_identity(&cfg, &w, cfg.n_exp, &mask, None, &prompts, 8);
}

#[test]
fn batched_matches_sequential_with_shared_expert() {
    // the dssim-style always-on shared expert rides the batched path too
    let cfg = tiny_cfg(true);
    let w = Weights::synthesize(&cfg, 47);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let v = cfg.vocab;
    let prompts: Vec<Vec<i32>> = [4usize, 6]
        .iter()
        .map(|&len| (0..len).map(|i| ((7 + i * 3) % v) as i32).collect())
        .collect();
    assert_batch_identity(&cfg, &w, cfg.n_exp, &mask, None, &prompts, 6);
}

#[test]
fn batched_matches_sequential_on_compact_variant() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 59);
    let r = 2usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).unwrap();
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let v = cfg.vocab;
    let prompts: Vec<Vec<i32>> = [5usize, 2, 7]
        .iter()
        .map(|&len| (0..len).map(|i| ((9 + i * 4) % v) as i32).collect())
        .collect();
    assert_batch_identity(&cfg, &cw, r, &mask, Some(&remap), &prompts, 8);
}

/// Join/leave harness state: the batched set, an independently advanced
/// per-sequence reference set, and the logical ids of the live sequences.
struct Stream {
    batch: Vec<Box<dyn KvCache>>,
    reference: Vec<Box<dyn KvCache>>,
    ids: Vec<usize>,
}

fn stream_feed(v: usize, id: usize, i: usize) -> i32 {
    ((5 + id * 13 + i * 3) % v) as i32
}

fn stream_join(
    backend: &NativeBackend,
    state: &dyn hc_smoe::backend::ModelState,
    mask: &[f32],
    v: usize,
    id: usize,
    st: &mut Stream,
) {
    let p: Vec<i32> = (0..4 + id).map(|i| ((1 + id * 7 + i * 5) % v) as i32).collect();
    let prefill = || {
        let (cache, _) = backend.run_prefill(state, &p, PrefillOpts::new(mask)).unwrap();
        cache.expect("fresh prefill returns a cache")
    };
    st.batch.push(prefill());
    st.reference.push(prefill());
    st.ids.push(id);
}

/// One batched step over the live set, checked bitwise against the
/// per-sequence reference decode.
fn stream_advance(
    backend: &NativeBackend,
    state: &dyn hc_smoe::backend::ModelState,
    mask: &[f32],
    v: usize,
    step: usize,
    st: &mut Stream,
) {
    let tokens: Vec<i32> = st.ids.iter().map(|&id| stream_feed(v, id, step)).collect();
    let rows = {
        let mut refs: Vec<&mut dyn KvCache> =
            st.batch.iter_mut().map(|c| c.as_mut()).collect();
        backend
            .run_decode_batch(state, &mut refs, &tokens, mask, None)
            .unwrap()
    };
    for (s, row) in rows.iter().enumerate() {
        let single = backend
            .run_decode(state, st.reference[s].as_mut(), tokens[s], mask, None)
            .unwrap();
        assert_eq!(
            bits(row),
            bits(&single),
            "join/leave stream diverged (logical seq {}, step {step})",
            st.ids[s]
        );
    }
}

#[test]
fn sequences_join_and_leave_midstream() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 71);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let v = cfg.vocab;
    let mut st = Stream { batch: Vec::new(), reference: Vec::new(), ids: Vec::new() };

    stream_join(&backend, state.as_ref(), &mask, v, 0, &mut st);
    stream_join(&backend, state.as_ref(), &mask, v, 1, &mut st);
    for step in 0..3 {
        stream_advance(&backend, state.as_ref(), &mask, v, step, &mut st);
    }
    // a third sequence joins mid-stream on a step boundary...
    stream_join(&backend, state.as_ref(), &mask, v, 2, &mut st);
    for step in 3..6 {
        stream_advance(&backend, state.as_ref(), &mask, v, step, &mut st);
    }
    // ...and the middle sequence leaves while the others keep decoding
    st.batch.remove(1);
    st.reference.remove(1);
    st.ids.remove(1);
    for step in 6..9 {
        stream_advance(&backend, state.as_ref(), &mask, v, step, &mut st);
    }
    assert_eq!(st.ids, vec![0, 2]);
}

#[test]
fn empty_batches_and_bad_requests_leave_caches_untouched() {
    let cfg = tiny_cfg(false);
    let w = Weights::synthesize(&cfg, 83);
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];

    // an empty batch is a no-op, not an error
    let mut none: Vec<&mut dyn KvCache> = Vec::new();
    let rows = backend
        .run_decode_batch(state.as_ref(), &mut none, &[], &mask, None)
        .unwrap();
    assert!(rows.is_empty());

    let (ca, _) =
        backend.run_prefill(state.as_ref(), &[1, 2, 3], PrefillOpts::new(&mask)).unwrap();
    let (cb, _) = backend.run_prefill(state.as_ref(), &[4, 5], PrefillOpts::new(&mask)).unwrap();
    let mut ca = ca.expect("fresh prefill returns a cache");
    let mut cb = cb.expect("fresh prefill returns a cache");

    // token-count mismatch errors before any cache is touched
    {
        let mut refs: Vec<&mut dyn KvCache> = vec![ca.as_mut(), cb.as_mut()];
        assert!(backend
            .run_decode_batch(state.as_ref(), &mut refs, &[7], &mask, None)
            .is_err());
    }
    assert_eq!((ca.seq_len(), cb.seq_len()), (3, 2));

    // one out-of-vocab token poisons the whole request up front — the
    // *other* sequence must not be left half-advanced either
    {
        let mut refs: Vec<&mut dyn KvCache> = vec![ca.as_mut(), cb.as_mut()];
        assert!(backend
            .run_decode_batch(state.as_ref(), &mut refs, &[7, -1], &mask, None)
            .is_err());
    }
    assert_eq!((ca.seq_len(), cb.seq_len()), (3, 2));

    // a remap table pointing at a nonexistent slot is rejected up front
    // too (it used to fail mid-layer, after attention had already
    // appended K/V for the whole batch)
    {
        let bad_remap: Vec<i32> = vec![cfg.n_exp as i32; cfg.n_layer * cfg.n_exp];
        let mut refs: Vec<&mut dyn KvCache> = vec![ca.as_mut(), cb.as_mut()];
        assert!(backend
            .run_decode_batch(state.as_ref(), &mut refs, &[7, 8], &mask, Some(&bad_remap))
            .is_err());
    }
    assert_eq!((ca.seq_len(), cb.seq_len()), (3, 2));

    // and a well-formed follow-up still works on the same caches
    let mut refs: Vec<&mut dyn KvCache> = vec![ca.as_mut(), cb.as_mut()];
    let rows = backend
        .run_decode_batch(state.as_ref(), &mut refs, &[7, 8], &mask, None)
        .unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!((ca.seq_len(), cb.seq_len()), (4, 3));
}

#[test]
fn server_batches_decode_under_concurrent_mixed_load() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let bench = hc_smoe::data::Benchmark::load(a.benchmark("arc_e")).unwrap();
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
        BatcherConfig {
            max_rows: ctx.manifest.eval_b,
            max_wait: Duration::from_millis(2),
        },
    )
    .unwrap();

    let prompt = [1i32, 4, 20, 3, 5];
    let seeds = [1u64, 2, 3, 4];
    // submit every generation up front (they land while the executor is
    // still loading the model), so the decode set genuinely overlaps at
    // B > 1 and the batched step is what serves them
    let tx = handle.sender();
    let mut rxs = Vec::new();
    for (gi, &seed) in seeds.iter().enumerate() {
        let (reply, rx) = reply_channel();
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::top_k(8, 0.8, seed, 20 + gi, None))
                .reply_to(reply),
        ))
        .unwrap();
        rxs.push(rx);
    }
    // score traffic interleaves with the decoding batch
    let direct = {
        let ev = Evaluator::new(&ctx).unwrap();
        ev.score_benchmark(&model, &bench).unwrap()
    };
    for (ii, item) in bench.items.iter().enumerate().take(6) {
        let scores = handle.score_item(&item.prompt, &item.choices).unwrap();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(pred, direct.predictions[ii], "served item {ii} differs");
    }
    // batched serving replays the offline path bit for bit
    for ((gi, &seed), rx) in seeds.iter().enumerate().zip(&rxs) {
        let served = rx.recv().unwrap().unwrap();
        let offline = generate(
            &ctx,
            &model,
            &prompt,
            SamplingParams::top_k(8, 0.8, seed, 20 + gi, None),
        )
        .unwrap();
        assert_eq!(served.tokens, offline.tokens, "seed {seed}");
        assert_eq!(served.finish, offline.finish, "seed {seed}");
    }
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert_eq!(snap.gen_requests, 4);
    // every decoded token is still counted...
    let expected: u64 = (0..4).map(|gi| 20 + gi as u64 - 1).sum();
    assert_eq!(snap.gen_tokens, expected);
    // ...but in fewer batched iterations than tokens: the decode set ran
    // at B > 1 (all four requests were queued before the first step)
    assert!(snap.decode_steps > 0);
    assert!(
        snap.decode_steps < snap.gen_tokens,
        "decode never batched: {} steps for {} tokens",
        snap.decode_steps,
        snap.gen_tokens
    );
    assert!(snap.mean_decode_batch() > 1.0);
}

#[test]
fn long_prompt_admission_does_not_stall_active_decode() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let t_max = ctx.cfg.t_max;
    let handle = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();

    // ONE shared reply channel for every request: the executor sends
    // replies sequentially, so the order messages arrive here IS the
    // executor's completion order — the assertion below is on ordering,
    // not wall-clock, and cannot flake on a loaded runner.
    let tx = handle.sender();
    let (reply, rx) = reply_channel();

    // one in-flight sequence that needs 3 decode steps after admission...
    tx.send(Request::Generate(
        GenerateRequest::new(&[1, 4, 20, 3], SamplingParams::greedy(4, None))
            .reply_to(reply.clone()),
    ))
    .unwrap();
    // ...then a burst of near-t_max prompts that each finish at admission
    // (max_new_tokens = 1, so their entire cost is the prefill). Under the
    // old design the intake drain prefilled ALL of them synchronously
    // before the in-flight sequence could take another step.
    let n_long = 6usize;
    let long_prompt: Vec<i32> = (0..t_max - 1).map(|i| ((i * 3) % 60 + 1) as i32).collect();
    for _ in 0..n_long {
        tx.send(Request::Generate(
            GenerateRequest::new(&long_prompt, SamplingParams::greedy(1, None))
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    drop(reply);

    let order: Vec<usize> = (0..=n_long)
        .map(|_| rx.recv().unwrap().unwrap().tokens.len())
        .collect();
    assert_eq!(order.iter().filter(|&&len| len == 1).count(), n_long);
    let short_pos = order
        .iter()
        .position(|&len| len == 4)
        .expect("the in-flight sequence must be answered");
    // bounded admissions: the short sequence needs 3 decode steps and at
    // most one long prefill runs per step, so at most 3 long replies may
    // precede it. The old inline-drain design answered ALL six longs
    // first (short_pos == 6).
    assert!(
        short_pos <= 3,
        "{short_pos} long prefills ran before the in-flight sequence finished — \
         the admission budget regressed toward inline prefill"
    );
    handle.shutdown().unwrap();
}
