//! Multi-executor test suite: expert-parallel sharding, the replica
//! dispatcher, and the streaming HTTP front end.
//!
//! The headline contracts. (1) `NativeBackend::with_expert_shards(n)`
//! yields prefill logits, decode rows, and batched-decode rows
//! **bit-identical** to the serial backend at every shard count, flat
//! and paged — sharding partitions which thread computes an expert
//! block, never the combine order. (2) A generation served through the
//! [`Dispatcher`] (any replica count) is bit-identical to an offline
//! [`generate`] call, and a streamed request's token stream equals its
//! final reply exactly. (3) Placement is prefix-affine and lease
//! accounting returns to zero when requests retire. (4) The HTTP front
//! end streams the same tokens over chunked transfer encoding, rejects
//! connections over its cap with `503`, and drains gracefully — an
//! in-flight stream admitted before shutdown still ends with its
//! `done` line.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hc_smoe::backend::native::NativeBackend;
use hc_smoe::backend::{Backend, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::generate::{generate, SamplingParams};
use hc_smoe::kvpool::{KvPool, PoolHandle, DEFAULT_BLOCK_TOKENS};
use hc_smoe::model::ModelContext;
use hc_smoe::serving::net::serve_http;
use hc_smoe::serving::{BatcherConfig, Dispatcher, GenerateRequest, ServeSpec};
use hc_smoe::weights::Weights;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "shard".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 6,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 48,
        shared: false,
        m_shared: 16,
        cap_factor: 4.0,
        block_c: 4,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Synthesize one artifact set per test process.
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_dispatch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0xD15B).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

fn launch(a: &Artifacts, replicas: usize) -> Arc<Dispatcher> {
    Arc::new(
        Dispatcher::launch(
            ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
            BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
            Some(replicas),
        )
        .unwrap(),
    )
}

// ---------------------------------------------------------------------------
// Expert-parallel sharding bit-identity
// ---------------------------------------------------------------------------

/// Run prefill + decode + batched decode on a backend and return every
/// logits row produced (bit-comparable transcript of the whole path).
fn transcript(backend: &NativeBackend, cfg: &ModelCfg, w: &Weights, paged: bool) -> Vec<Vec<u32>> {
    let state = backend.load_model(w, cfg.n_exp).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let pool = PoolHandle::new(KvPool::for_model(cfg, 4 << 20, DEFAULT_BLOCK_TOKENS).unwrap());
    let prompt: Vec<i32> = (0..17).map(|i| ((3 + i * 5) % cfg.vocab) as i32).collect();
    let opts = if paged {
        PrefillOpts::new(&mask).paged(&pool, prompt.len() + 8)
    } else {
        PrefillOpts::new(&mask)
    };
    let mut out = Vec::new();
    let (cache, logits) = backend.run_prefill(state.as_ref(), &prompt, opts).unwrap();
    let mut cache = cache.expect("fresh prefill returns a cache");
    out.push(bits(&logits));
    for i in 0..4 {
        let tok = ((7 + i * 5) % cfg.vocab) as i32;
        let row = backend.run_decode(state.as_ref(), cache.as_mut(), tok, &mask, None).unwrap();
        out.push(bits(&row));
    }
    // second sequence so the batched step (the moe_verify path) sees a
    // real batch
    let opts2 = if paged {
        PrefillOpts::new(&mask).paged(&pool, prompt.len() + 8)
    } else {
        PrefillOpts::new(&mask)
    };
    let (cache2, _) =
        backend.run_prefill(state.as_ref(), &prompt[..9], opts2).unwrap();
    let mut cache2 = cache2.expect("fresh prefill returns a cache");
    let mut caches: Vec<&mut dyn hc_smoe::backend::KvCache> =
        vec![cache.as_mut(), cache2.as_mut()];
    let rows = backend
        .run_decode_batch(state.as_ref(), &mut caches, &[11, 23], &mask, None)
        .unwrap();
    for row in rows {
        out.push(bits(&row));
    }
    out
}

#[test]
fn expert_sharding_is_bit_identical_at_every_shard_count() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 61);
    for paged in [false, true] {
        let reference = transcript(&NativeBackend::new(cfg.clone()), &cfg, &w, paged);
        // 8 > n_exp exercises shards with zero experts assigned
        for shards in [2usize, 3, 8] {
            let sharded = transcript(
                &NativeBackend::new(cfg.clone()).with_expert_shards(shards),
                &cfg,
                &w,
                paged,
            );
            assert_eq!(
                reference, sharded,
                "shards={shards} paged={paged} diverged from the serial path"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatcher: bit-identity, streaming, placement, leases, drain
// ---------------------------------------------------------------------------

#[test]
fn dispatcher_generation_matches_offline_at_every_replica_count() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let prompt: Vec<i32> = (0..18).map(|i| (1 + i * 3) % 90).collect();
    let params = || SamplingParams::top_k(4, 0.8, 7, 8, None);
    let offline = generate(&ctx, &model, &prompt, params()).unwrap();
    for replicas in [1usize, 2, 3] {
        let d = launch(&a, replicas);
        for _ in 0..replicas + 1 {
            let served = d.generate(&prompt, params()).unwrap();
            assert_eq!(
                offline.tokens, served.tokens,
                "replicas={replicas}: dispatcher-served generation diverged from offline"
            );
        }
        d.shutdown().unwrap();
    }
}

#[test]
fn streamed_tokens_equal_final_reply() {
    let a = arts();
    let d = launch(&a, 2);
    let prompt: Vec<i32> = (0..16).map(|i| (2 + i * 5) % 90).collect();
    let (req, stream) =
        GenerateRequest::new(&prompt, SamplingParams::greedy(6, None)).streaming();
    let (_, reply) = d.submit(req).unwrap();
    let mut streamed = Vec::new();
    // the channel closes (recv errors) after the executor's final flush
    while let Ok(t) = stream.recv() {
        streamed.push(t);
    }
    let out = reply.unwrap().recv().unwrap().unwrap();
    assert_eq!(streamed, out.tokens, "live stream diverged from the final reply");
    d.shutdown().unwrap();
}

#[test]
fn shared_prefix_lands_on_one_replica_and_leases_release() {
    let a = arts();
    let d = launch(&a, 3);
    // identical first block (>= DEFAULT_BLOCK_TOKENS tokens) -> same
    // replica for every request, regardless of submission order
    let prefix: Vec<i32> = (0..DEFAULT_BLOCK_TOKENS as i32).map(|i| 3 + i).collect();
    let mut replies = Vec::new();
    let mut placed = Vec::new();
    for tail in [7i32, 11, 13, 17] {
        let mut prompt = prefix.clone();
        prompt.push(tail);
        let (idx, rx) = d
            .submit(GenerateRequest::new(&prompt, SamplingParams::greedy(4, None)))
            .unwrap();
        placed.push(idx);
        replies.push(rx.unwrap());
    }
    assert!(
        placed.iter().all(|&i| i == placed[0]),
        "prefix-affine requests scattered across replicas: {placed:?}"
    );
    // while in flight the target replica holds a non-zero lease estimate
    // (checked before the replies complete would race; check the sum
    // instead: leases release exactly when requests retire)
    for rx in replies {
        rx.recv().unwrap().unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let held: u64 = (0..3).map(|i| d.committed_blocks(i)).sum();
        if held == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "leases never released: {held} blocks held");
        std::thread::sleep(Duration::from_millis(2));
    }
    d.shutdown().unwrap();
}

#[test]
fn short_prompts_balance_toward_least_committed() {
    let a = arts();
    let d = launch(&a, 2);
    // prompts shorter than one block carry no affinity; with equal
    // commitment the tie-break is deterministic (lowest index), and the
    // still-held lease of the first request makes the second placement
    // prefer the other replica (a long max_new keeps the first request
    // in flight across the back-to-back submits)
    let (i0, r0) =
        d.submit(GenerateRequest::new(&[5, 6, 7], SamplingParams::greedy(40, None))).unwrap();
    let (i1, r1) =
        d.submit(GenerateRequest::new(&[8, 9, 10], SamplingParams::greedy(4, None))).unwrap();
    assert_eq!(i0, 0, "first placement must take the lowest index");
    assert_eq!(i1, 1, "second placement must spill to the idle replica");
    r0.unwrap().recv().unwrap().unwrap();
    r1.unwrap().recv().unwrap().unwrap();
    d.shutdown().unwrap();
}

#[test]
fn fleet_metrics_merge_across_replicas() {
    let a = arts();
    let d = launch(&a, 2);
    // overlapping submits (leases held) alternate short no-affinity
    // prompts across replicas deterministically, so both executors see
    // work; recv only after all four are placed
    let mut replies = Vec::new();
    for i in 0..4 {
        let (_, rx) = d
            .submit(GenerateRequest::new(
                &[(3 + i) as i32, 5, 9],
                SamplingParams::greedy(16, None),
            ))
            .unwrap();
        replies.push(rx.unwrap());
    }
    for rx in replies {
        rx.recv().unwrap().unwrap();
    }
    let per = d.metrics();
    let merged = d.merged();
    assert_eq!(per.len(), 2);
    assert_eq!(
        merged.gen_requests,
        per.iter().map(|s| s.gen_requests).sum::<u64>(),
        "merged counter must sum the replicas"
    );
    assert_eq!(merged.gen_requests, 4);
    assert!(
        merged.kv_blocks_total >= per[0].kv_blocks_total,
        "merged capacity must cover every replica pool"
    );
    assert!(per.iter().all(|s| s.gen_requests >= 1), "both replicas served traffic");
    d.shutdown().unwrap();
}

#[test]
fn shutdown_answers_every_inflight_request() {
    let a = arts();
    let d = launch(&a, 2);
    let prompt: Vec<i32> = (0..12).map(|i| (6 + i * 5) % 90).collect();
    let mut replies = Vec::new();
    for _ in 0..6 {
        let (_, rx) = d
            .submit(GenerateRequest::new(&prompt, SamplingParams::greedy(24, None)))
            .unwrap();
        replies.push(rx.unwrap());
    }
    d.shutdown().unwrap();
    // every reply arrives (finished or an explicit shutdown error) —
    // recv never hangs on an abandoned request
    for rx in replies {
        let _ = rx.recv().expect("reply channel must not dangle");
    }
    // post-shutdown submissions fail fast instead of queueing forever
    assert!(d
        .submit(GenerateRequest::new(&prompt, SamplingParams::greedy(2, None)))
        .is_err());
}

// ---------------------------------------------------------------------------
// HTTP front end
// ---------------------------------------------------------------------------

/// Minimal blocking HTTP client: send one request, read to EOF.
fn http_roundtrip(addr: std::net::SocketAddr, request: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post_generate(addr: std::net::SocketAddr, body: &str) -> String {
    http_roundtrip(
        addr,
        &format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Decode a chunked-transfer response body into its payload lines.
fn chunked_lines(response: &str) -> Vec<String> {
    let body = response.split_once("\r\n\r\n").expect("header/body split").1;
    let mut rest = body;
    let mut payload = String::new();
    loop {
        let (size_line, tail) = rest.split_once("\r\n").expect("chunk size line");
        let size = usize::from_str_radix(size_line.trim(), 16).expect("hex chunk size");
        if size == 0 {
            break;
        }
        payload.push_str(&tail[..size]);
        rest = &tail[size + 2..]; // skip the chunk's trailing \r\n
    }
    payload.lines().map(str::to_string).collect()
}

#[test]
fn http_stream_matches_offline_generate() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let prompt: Vec<i32> = (0..16).map(|i| (5 + i * 3) % 90).collect();
    let offline = generate(&ctx, &model, &prompt, SamplingParams::greedy(5, None)).unwrap();

    let server = serve_http(launch(&a, 2), "127.0.0.1:0", 16).unwrap();
    let prompt_str =
        prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");
    let response = post_generate(server.addr(), &format!("prompt={prompt_str}\nmax_new=5\n"));
    assert!(response.starts_with("HTTP/1.1 200"), "unexpected response: {response}");
    let lines = chunked_lines(&response);
    let (tokens, tail) = lines.split_at(lines.len() - 1);
    let streamed: Vec<i32> = tokens.iter().map(|l| l.parse().unwrap()).collect();
    assert_eq!(streamed, offline.tokens, "HTTP stream diverged from offline generate");
    assert!(tail[0].starts_with("done "), "stream must end with a done line: {tail:?}");
    server.shutdown().unwrap();
}

#[test]
fn http_health_metrics_and_404() {
    let a = arts();
    let server = serve_http(launch(&a, 1), "127.0.0.1:0", 16).unwrap();
    let health = http_roundtrip(server.addr(), "GET /health HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200") && health.ends_with("ok\n"));
    let metrics = http_roundtrip(server.addr(), "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(metrics.contains("fleet_gen_requests"), "missing fleet metrics: {metrics}");
    assert!(metrics.contains("replica0_kv_blocks_total"), "missing replica metrics");
    let missing = http_roundtrip(server.addr(), "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(missing.starts_with("HTTP/1.1 404"));
    let bad = post_generate(server.addr(), "max_new=3\n");
    assert!(bad.starts_with("HTTP/1.1 400"), "prompt-less body must 400: {bad}");
    server.shutdown().unwrap();
}

#[test]
fn http_over_capacity_gets_503() {
    let a = arts();
    let server = serve_http(launch(&a, 1), "127.0.0.1:0", 1).unwrap();
    // occupy the single slot with a connection that sends nothing (it
    // holds its worker until the read times out)
    let parked = TcpStream::connect(server.addr()).unwrap();
    // the accept loop must have registered the first connection before
    // the second arrives; poll until the overflow response appears
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let response =
            http_roundtrip(server.addr(), "GET /health HTTP/1.1\r\nHost: t\r\n\r\n");
        if response.starts_with("HTTP/1.1 503") {
            break;
        }
        assert!(Instant::now() < deadline, "overflow connection never saw 503");
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(parked);
    server.shutdown().unwrap();
}

#[test]
fn http_drain_completes_inflight_stream() {
    let a = arts();
    let server = serve_http(launch(&a, 1), "127.0.0.1:0", 16).unwrap();
    let addr = server.addr();
    let prompt_str =
        (0..16).map(|i| ((7 + i * 3) % 90).to_string()).collect::<Vec<_>>().join(" ");
    let client = std::thread::spawn(move || {
        post_generate(addr, &format!("prompt={prompt_str}\nmax_new=12\n"))
    });
    // give the request time to be admitted, then drain while it streams
    std::thread::sleep(Duration::from_millis(50));
    server.shutdown().unwrap();
    let response = client.join().unwrap();
    assert!(response.starts_with("HTTP/1.1 200"), "admitted stream was dropped: {response}");
    let lines = chunked_lines(&response);
    let last = lines.last().expect("drained stream still ends with a tail line");
    assert!(
        last.starts_with("done ") || last.starts_with("error "),
        "drained stream must end with an explicit tail, got {last:?}"
    );
}
