//! Scheduler test suite: chunked prefill, priority classes, preemption.
//!
//! The headline contracts: feeding a prompt through the unified
//! [`Backend::run_prefill`] entry point chunk by chunk (`resume_from`)
//! yields a cache and final logits **bit-identical** to one whole-prompt
//! prefill — across the flat, paged, masked and compact layouts — and a
//! Batch-class generation that is preempted (cache dropped, prefix
//! re-prefilled on resume) emits exactly the token stream of an
//! uninterrupted offline run. Plus the scheduler policy itself: an
//! Interactive request submitted after Batch work still completes first
//! (no priority inversion), chunked prefill bounds how many prompt tokens
//! can stall consecutive decode steps (via the deterministic
//! `prefill_stall_tokens_max` gauge), a preemption storm leaves zero KV
//! blocks behind, shutdown answers every queued request with an explicit
//! error instead of hanging its client, and deadline misses are counted.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hc_smoe::backend::native::NativeBackend;
use hc_smoe::backend::{Backend, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::generate::{generate, SamplingParams};
use hc_smoe::kvpool::{KvPool, PoolHandle, DEFAULT_BLOCK_TOKENS};
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::MASK_OFF;
use hc_smoe::serving::{
    reply_channel, serve, BatcherConfig, GenerateRequest, Priority, Request, ServeSpec,
    ServerHandle,
};
use hc_smoe::weights::Weights;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "sched".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 48,
        shared: false,
        m_shared: 16,
        // k=2 distinct experts per token keeps every capacity queue below
        // cap_factor=4 capacity — structurally drop-free, so chunked and
        // whole-prompt dispatch agree exactly at every prefix
        cap_factor: 4.0,
        block_c: 4,
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Synthesize one artifact set per test process (server-side tests).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_sched_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0x5C4D).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

/// Serve qwensim with an explicit pool budget in *blocks* and an explicit
/// prefill chunk size.
fn serve_with(a: &Artifacts, cfg: &ModelCfg, blocks: usize, chunk: Option<usize>) -> ServerHandle {
    serve(
        ServeSpec {
            kv_budget_bytes: Some(blocks * cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS)),
            prefill_chunk: chunk,
            ..ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap()
}

/// Poll a metrics predicate with a deadline (the executor publishes pool
/// gauges once per loop iteration).
fn wait_for(handle: &ServerHandle, what: &str, pred: impl Fn(&ServerHandle) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !pred(handle) {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------------------
// Backend-level chunked-prefill bit-identity
// ---------------------------------------------------------------------------

/// Prefill `prompt` whole, then again in `chunk`-token pieces (first piece
/// fresh, the rest through `PrefillOpts::resume`), over both the flat and
/// the paged cache — asserting bitwise-equal final logits and bitwise-equal
/// decode continuations from every chunked cache.
fn assert_chunked_matches_whole(
    cfg: &ModelCfg,
    w: &Weights,
    n_slots: usize,
    mask: &[f32],
    remap: Option<&[i32]>,
    prompt: &[i32],
    steps: usize,
) {
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(w, n_slots).unwrap();
    let pool = PoolHandle::new(KvPool::for_model(cfg, 4 << 20, DEFAULT_BLOCK_TOKENS).unwrap());
    let base_opts = || {
        let mut o = PrefillOpts::new(mask);
        if let Some(rm) = remap {
            o = o.remap(rm);
        }
        o
    };

    // reference: whole-prompt flat prefill + its decode continuation
    let (wcache, wlogits) = backend.run_prefill(state.as_ref(), prompt, base_opts()).unwrap();
    let mut wcache = wcache.expect("fresh prefill returns a cache");
    let tok = |i: usize| ((7 + i * 5) % cfg.vocab) as i32;
    let ref_rows: Vec<Vec<f32>> = (0..steps)
        .map(|i| backend.run_decode(state.as_ref(), wcache.as_mut(), tok(i), mask, remap).unwrap())
        .collect();

    for chunk in [1usize, 3, 5, prompt.len()] {
        for paged in [false, true] {
            let first = chunk.min(prompt.len());
            let opts = if paged {
                base_opts().paged(&pool, prompt.len() + steps)
            } else {
                base_opts()
            };
            let (cache, mut logits) =
                backend.run_prefill(state.as_ref(), &prompt[..first], opts).unwrap();
            let mut cache = cache.expect("fresh prefill returns a cache");
            let mut done = first;
            while done < prompt.len() {
                let take = chunk.min(prompt.len() - done);
                let (none, l) = backend
                    .run_prefill(
                        state.as_ref(),
                        &prompt[done..done + take],
                        base_opts().resume(cache.as_mut()),
                    )
                    .unwrap();
                assert!(none.is_none(), "a resumed prefill extends the given cache");
                logits = l;
                done += take;
            }
            assert_eq!(cache.seq_len(), prompt.len(), "chunk={chunk} paged={paged}");
            assert_eq!(
                bits(&logits),
                bits(&wlogits),
                "chunk={chunk} paged={paged}: chunked prefill logits differ from whole-prompt"
            );
            for (i, rrow) in ref_rows.iter().enumerate() {
                let row = backend
                    .run_decode(state.as_ref(), cache.as_mut(), tok(i), mask, remap)
                    .unwrap();
                assert_eq!(
                    bits(&row),
                    bits(rrow),
                    "chunk={chunk} paged={paged}: decode step {i} diverged after chunked prefill"
                );
            }
        }
    }
}

#[test]
fn chunked_matches_whole_full_layout() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 41);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    // 13 tokens: irregular tails at chunk sizes 3 and 5
    let prompt: Vec<i32> = (0..13).map(|i| ((3 + i * 5) % cfg.vocab) as i32).collect();
    assert_chunked_matches_whole(&cfg, &w, cfg.n_exp, &mask, None, &prompt, 5);
}

#[test]
fn chunked_matches_whole_masked_layout() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 43);
    let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    mask[2] = MASK_OFF;
    mask[cfg.n_exp + 1] = MASK_OFF;
    let prompt: Vec<i32> = (0..9).map(|i| ((2 + i * 7) % cfg.vocab) as i32).collect();
    assert_chunked_matches_whole(&cfg, &w, cfg.n_exp, &mask, None, &prompt, 4);
}

#[test]
fn chunked_matches_whole_compact_layout() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 47);
    let r = 2usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).unwrap();
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let prompt: Vec<i32> = (0..11).map(|i| ((9 + i * 4) % cfg.vocab) as i32).collect();
    assert_chunked_matches_whole(&cfg, &cw, r, &mask, Some(&remap), &prompt, 4);
}

#[test]
fn model_layer_prefill_resume_matches_whole() {
    // the exact wrapper pair the serving executor drives
    // (ModelContext::prefill_paged for the first chunk, prefill_resume for
    // the rest) agrees bit-for-bit with one whole-prompt prefill
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let pool = ctx.kv_pool(4 << 20).unwrap();
    let prompt: Vec<i32> = (0..10).map(|i| ((5 + i * 3) % ctx.cfg.vocab) as i32).collect();

    let (_, whole_logits) = ctx.prefill(&model, &prompt).unwrap();
    let (mut cache, mut logits) =
        ctx.prefill_paged(&model, &prompt[..3], &pool, prompt.len()).unwrap();
    for chunk in prompt[3..].chunks(3) {
        logits = ctx.prefill_resume(&model, chunk, cache.as_mut()).unwrap();
    }
    assert_eq!(cache.seq_len(), prompt.len());
    assert_eq!(bits(&logits), bits(&whole_logits), "model-layer chunked prefill diverged");
}

// ---------------------------------------------------------------------------
// Scheduler policy (priority, preemption, stall bound, shutdown, deadlines)
// ---------------------------------------------------------------------------

#[test]
fn interactive_submitted_last_completes_first() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let handle = serve_with(&a, &cfg, 64, None);
    let tx = handle.sender();
    // ONE shared reply channel: replies arrive in the executor's
    // completion order, so the assertion is on ordering, not wall-clock
    let (reply, rx) = reply_channel();
    let prompt = [1i32, 4, 20, 3];
    // three Batch generations first, the Interactive one LAST — token
    // counts identify the replies
    for max_new in [6usize, 7, 8] {
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::greedy(max_new, None))
                .priority(Priority::Batch)
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    tx.send(Request::Generate(
        GenerateRequest::new(&prompt, SamplingParams::greedy(2, None))
            .priority(Priority::Interactive)
            .reply_to(reply.clone()),
    ))
    .unwrap();
    drop(reply);

    let order: Vec<usize> = (0..4).map(|_| rx.recv().unwrap().unwrap().tokens.len()).collect();
    assert_eq!(
        order,
        vec![2, 6, 7, 8],
        "Interactive must complete before earlier-submitted Batch work \
         (and Batch must stay FIFO)"
    );
    handle.shutdown().unwrap();
}

#[test]
fn preemption_storm_resumes_bit_identically_and_leaks_no_blocks() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let cfg = ctx.cfg.clone();

    // 4-block pool; a Batch generation reserving the full context window
    // (prompt 4 + max_new clamped to t_max = 4 blocks) owns the whole pool
    // for its entire active life, so an Interactive arrival (1 block) can
    // only be admitted by preempting it
    let handle = serve_with(&a, &cfg, 4, None);
    let bprompt = [2i32, 5, 21, 7];
    let bparams = SamplingParams::greedy(1_000_000, None); // t_max-bounded
    let iprompt = [1i32, 4, 20];
    let iparams = SamplingParams::greedy(2, None);
    let boffline = generate(&ctx, &model, &bprompt, bparams.clone()).unwrap();
    let ioffline = generate(&ctx, &model, &iprompt, iparams.clone()).unwrap();

    // Keep colliding Interactive arrivals with a resident Batch stream
    // until three preemptions happened. Each round: start a Batch job,
    // wait until it holds pool blocks (or finished unobserved — the tiny
    // model decodes fast), then push an Interactive request through it.
    // EVERY Batch stream — preempted and re-prefilled or not — must equal
    // the uninterrupted offline run bit for bit.
    let mut rounds = 0usize;
    while handle.metrics.snapshot().preemptions < 3 {
        rounds += 1;
        assert!(rounds <= 50, "no preemption after 50 collision rounds");
        let rx = handle
            .submit(
                GenerateRequest::new(&bprompt, bparams.clone()).priority(Priority::Batch),
            )
            .unwrap()
            .expect("a fresh request owns its receiver");
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut batch_out = None;
        loop {
            if let Some(r) = rx.try_recv().unwrap() {
                batch_out = Some(r); // finished before we could collide
                break;
            }
            if handle.metrics.snapshot().kv_blocks_in_use >= 1 {
                break; // resident: its 4-block reservation is held
            }
            assert!(Instant::now() < deadline, "batch job neither resident nor finished");
            std::thread::yield_now();
        }
        let out = match batch_out {
            Some(out) => out.unwrap(),
            None => {
                let served = handle
                    .generate_opts(&iprompt, iparams.clone(), Priority::Interactive, None)
                    .unwrap();
                assert_eq!(served.tokens, ioffline.tokens, "interactive stream diverged");
                rx.recv().unwrap().unwrap()
            }
        };
        assert_eq!(
            out.tokens, boffline.tokens,
            "preempted/resumed batch stream diverged from the offline run (round {rounds})"
        );
        assert_eq!(out.finish, boffline.finish);
    }

    wait_for(&handle, "zero blocks after the preemption storm", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert!(snap.preemptions >= 3, "storm must have preempted: {}", snap.preemptions);
    assert!(snap.itl_p50_ms > 0.0, "interactive decode gaps must feed the ITL histogram");
}

#[test]
fn chunked_prefill_bounds_the_decode_stall() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let long_len = cfg.t_max - 16; // 48-token Batch prompts
    // (chunk, expected observed stall): chunked, at most one 4-token chunk
    // lands between consecutive decode steps; unchunked, a whole 48-token
    // prompt does. The gauge is deterministic — no wall-clock involved.
    for (chunk, expect_stall, expect_chunked) in [(Some(4usize), 4u64, true), (None, 48, false)] {
        let handle = serve_with(&a, &cfg, 64, chunk);
        let tx = handle.sender();
        let (reply, rx) = reply_channel();
        // one long-running Interactive decode joins first (submitted while
        // the executor still loads the model)...
        tx.send(Request::Generate(
            GenerateRequest::new(&[1, 4, 20, 3], SamplingParams::greedy(40, None))
                .reply_to(reply.clone()),
        ))
        .unwrap();
        // ...then two long Batch prompts whose prefills must interleave
        // with its decode steps
        for j in 0..2 {
            let prompt: Vec<i32> =
                (0..long_len).map(|i| ((2 + j * 7 + i * 3) % cfg.vocab) as i32).collect();
            tx.send(Request::Generate(
                GenerateRequest::new(&prompt, SamplingParams::greedy(4, None))
                    .priority(Priority::Batch)
                    .reply_to(reply.clone()),
            ))
            .unwrap();
        }
        drop(reply);
        let mut lens: Vec<usize> =
            (0..3).map(|_| rx.recv().unwrap().unwrap().tokens.len()).collect();
        lens.sort_unstable();
        assert_eq!(lens, vec![4, 4, 40]);
        let snap = handle.metrics.snapshot();
        handle.shutdown().unwrap();
        assert_eq!(
            snap.prefill_stall_tokens_max, expect_stall,
            "chunk={chunk:?}: observed stall bound"
        );
        assert_eq!(
            snap.chunked_prefills > 0,
            expect_chunked,
            "chunk={chunk:?}: chunked_prefills = {}",
            snap.chunked_prefills
        );
        assert_eq!(snap.preemptions, 0, "the 64-block pool co-hosts everything");
    }
}

#[test]
fn shutdown_answers_every_queued_generation() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    // 4-block pool, 5 full-window requests: at most one is ever admitted,
    // the rest sit in the scheduler lane — shutdown() must answer them all
    let handle = serve_with(&a, &cfg, 4, None);
    let tx = handle.sender();
    let (reply, rx) = reply_channel();
    let prompt: Vec<i32> = (0..cfg.t_max - 16).map(|i| ((i * 3) % cfg.vocab) as i32).collect();
    for _ in 0..5 {
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::greedy(16, None))
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    drop(reply);
    handle.shutdown().unwrap();
    // every request got SOME reply (the old design hung queued clients
    // forever); unfinished ones carry an explicit shutdown error
    let mut replies = 0usize;
    let mut errs = 0usize;
    while let Ok(r) = rx.recv() {
        replies += 1;
        if let Err(e) = r {
            errs += 1;
            let msg = format!("{e:#}");
            assert!(msg.contains("shutting down"), "unexpected error: {msg}");
        }
    }
    assert_eq!(replies, 5, "shutdown must answer every queued generation");
    assert!(errs >= 1, "a 5-deep queue cannot drain before the stop flag is seen");
}

#[test]
fn deadline_misses_are_counted() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let handle = serve_with(&a, &cfg, 64, None);
    // a zero deadline is always missed...
    let out = handle
        .generate_opts(
            &[1, 4, 20],
            SamplingParams::greedy(3, None),
            Priority::Interactive,
            Some(Duration::ZERO),
        )
        .unwrap();
    assert_eq!(out.tokens.len(), 3, "a missed deadline never cancels the request");
    assert_eq!(handle.metrics.snapshot().deadline_misses, 1);
    // ...a generous one never is, and no-deadline requests don't count
    handle
        .generate_opts(
            &[2, 5, 21],
            SamplingParams::greedy(3, None),
            Priority::Batch,
            Some(Duration::from_secs(3600)),
        )
        .unwrap();
    handle.generate(&[3, 9, 27], SamplingParams::greedy(2, None)).unwrap();
    assert_eq!(handle.metrics.snapshot().deadline_misses, 1);
    handle.shutdown().unwrap();
}

#[test]
fn zero_prefill_chunk_is_a_startup_error() {
    let a = arts();
    let handle = serve(
        ServeSpec {
            prefill_chunk: Some(0),
            ..ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let err = handle.shutdown().unwrap_err();
    assert!(
        format!("{err:#}").contains("positive token count"),
        "startup validation must reject prefill_chunk=0: {err:#}"
    );
}
