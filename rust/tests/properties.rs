//! Cross-module property tests over the coordinator's invariants
//! (hand-rolled runner; proptest is unavailable offline). These run on
//! synthetic stats/weights — no artifacts required.

use hc_smoe::backend::native::NativeBackend;
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::calib::{CalibStats, LayerStats};
use hc_smoe::config::ModelCfg;
use hc_smoe::kvpool::{KvPool, PagedSeq, PoolHandle};
use hc_smoe::pipeline::MASK_OFF;
use hc_smoe::clustering::{fcm, hierarchical, kmeans, single_shot, KmeansInit, Linkage};
use hc_smoe::merging::{merge_cluster, FixDomFeature, MergeStrategy};
use hc_smoe::pruning::{f_prune, layer_output_deviation, o_prune, s_prune};
use hc_smoe::similarity::{distance_matrix, Distance};
use hc_smoe::tensor::Tensor;
use hc_smoe::util::proptest::{check, ensure};
use hc_smoe::util::Rng;

fn random_layer(rng: &mut Rng, n: usize, d: usize, m: usize, t_sub: usize) -> LayerStats {
    let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    };
    let mean = mk(rng, n * d);
    let counts: Vec<f32> = (0..n).map(|_| 1.0 + rng.below(50) as f32).collect();
    LayerStats {
        mean_out: Tensor::new(vec![n, d], mean).unwrap(),
        probs_sum: counts.clone(),
        gate_sum: counts.clone(),
        counts,
        rl_sub: Tensor::new(vec![t_sub, n], mk(rng, t_sub * n)).unwrap(),
        raw_sub: Tensor::new(vec![n, t_sub, d], mk(rng, n * t_sub * d)).unwrap(),
        act_sub: Tensor::new(vec![n, 8, m], mk(rng, n * 8 * m)).unwrap(),
        hid_sub: Tensor::new(vec![t_sub, d], mk(rng, t_sub * d)).unwrap(),
    }
}

fn random_stats(rng: &mut Rng, nl: usize, n: usize) -> CalibStats {
    CalibStats {
        domain: "prop".into(),
        layers: (0..nl).map(|_| random_layer(rng, n, 6, 5, 12)).collect(),
        n_tokens: 128,
    }
}

#[test]
fn prop_every_clusterer_yields_valid_partitions() {
    check("all-clusterers-partition", 100, 40, |rng| {
        let n = 3 + rng.below(13);
        let r = 1 + rng.below(n);
        let feats: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..5).map(|_| rng.normal() as f32).collect())
            .collect();
        let freqs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 10.0).collect();
        let d = distance_matrix(&feats, Distance::Euclidean);
        for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
            hierarchical(&d, r, linkage).validate().map_err(|e| e.to_string())?;
        }
        kmeans(&feats, r, KmeansInit::Random { seed: rng.next_u64() }, 30)
            .validate()
            .map_err(|e| e.to_string())?;
        single_shot(&feats, &freqs, r).validate().map_err(|e| e.to_string())?;
        let f = fcm(&feats, r, 2.0, 20, rng.next_u64());
        for row in &f.membership {
            let s: f32 = row.iter().sum();
            ensure((s - 1.0).abs() < 1e-3, format!("membership row sums to {s}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_merge_strategies_preserve_shape_and_finiteness() {
    check("merge-shape-finite", 200, 25, |rng| {
        let n = 4;
        let (d, m) = (6, 5);
        let layer = random_layer(rng, n, d, m, 12);
        let mut map = std::collections::BTreeMap::new();
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        map.insert(
            "layer00.exp.wg".to_string(),
            Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
        );
        map.insert(
            "layer00.exp.wu".to_string(),
            Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
        );
        map.insert(
            "layer00.exp.wd".to_string(),
            Tensor::new(vec![n, m, d], mk(rng, n * m * d)).unwrap(),
        );
        let w = hc_smoe::weights::Weights::new(map);
        let members = vec![0usize, 2, 3];
        for strategy in [
            MergeStrategy::Average,
            MergeStrategy::Frequency,
            MergeStrategy::FixDom(FixDomFeature::Act),
            MergeStrategy::FixDom(FixDomFeature::Weight),
            MergeStrategy::ZipIt(FixDomFeature::Weight),
        ] {
            let e = merge_cluster(&w, &layer, 0, &members, strategy)
                .map_err(|e| e.to_string())?;
            ensure(e.wg.shape() == [d, m], "wg shape")?;
            ensure(e.wd.shape() == [m, d], "wd shape")?;
            ensure(
                e.wg.data().iter().all(|x| x.is_finite()),
                format!("{strategy:?} produced non-finite weights"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_average_merge_is_convex_combination() {
    // every element of the average-merged expert lies within the min/max
    // envelope of its members
    check("merge-convex", 300, 25, |rng| {
        let n = 3;
        let (d, m) = (4, 3);
        let layer = random_layer(rng, n, d, m, 8);
        let mut map = std::collections::BTreeMap::new();
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        for key in ["exp.wg", "exp.wu"] {
            map.insert(
                format!("layer00.{key}"),
                Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
            );
        }
        map.insert(
            "layer00.exp.wd".to_string(),
            Tensor::new(vec![n, m, d], mk(rng, n * m * d)).unwrap(),
        );
        let w = hc_smoe::weights::Weights::new(map);
        let members = vec![0usize, 1, 2];
        for strategy in [MergeStrategy::Average, MergeStrategy::Frequency] {
            let merged = merge_cluster(&w, &layer, 0, &members, strategy)
                .map_err(|e| e.to_string())?;
            let experts: Vec<_> = members
                .iter()
                .map(|&e| w.expert(0, e).unwrap())
                .collect();
            for i in 0..d * m {
                let vals: Vec<f32> = experts.iter().map(|e| e.wg.data()[i]).collect();
                let lo = vals.iter().cloned().fold(f32::MAX, f32::min) - 1e-4;
                let hi = vals.iter().cloned().fold(f32::MIN, f32::max) + 1e-4;
                let x = merged.wg.data()[i];
                ensure(x >= lo && x <= hi, format!("{x} outside [{lo}, {hi}]"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pruning_budgets_and_validity() {
    check("prune-budgets", 400, 30, |rng| {
        let nl = 1 + rng.below(4);
        let n = 4 + rng.below(10);
        let k = 2;
        let r = k + rng.below(n - k);
        let stats = random_stats(rng, nl, n);
        for p in [s_prune(&stats, r, k), f_prune(&stats, r, k)] {
            p.validate(n, k).map_err(|e| e.to_string())?;
            let total: usize = p.keep.iter().map(|x| x.len()).sum();
            ensure(total == r * nl, format!("budget {total} != {}", r * nl))?;
        }
        let p = o_prune(&stats, r, k, 50, rng.next_u64());
        p.validate(n, k).map_err(|e| e.to_string())?;
        ensure(p.keep.iter().all(|x| x.len() == r), "o-prune is static-r")?;
        Ok(())
    });
}

#[test]
fn prop_keeping_all_experts_has_zero_deviation() {
    check("full-subset-zero-dev", 500, 20, |rng| {
        let n = 3 + rng.below(6);
        let layer = random_layer(rng, n, 5, 4, 10);
        let all: Vec<usize> = (0..n).collect();
        let dev = layer_output_deviation(&layer, &all, 2);
        ensure(dev < 1e-9, format!("full subset deviation {dev}"))
    });
}

/// Randomized multi-position-verify invariant: for ANY tiny model
/// (random layout — full, masked, or compact — random prompts, random
/// ragged draft runs, random explicit thread count), one
/// `run_verify_batch_with` forward returns logits bit-identical to
/// feeding the same tokens through sequential `run_decode` calls, and
/// its checkpoints carry the right lengths. This is the contract the
/// speculative decoder's exactness proof stands on.
#[test]
fn prop_verify_equals_sequential_decodes() {
    check("verify-eq-sequential", 700, 25, |rng| {
        let cfg = ModelCfg {
            name: "prop".into(),
            n_layer: 1 + rng.below(2),
            d: 8,
            m: 8,
            n_exp: 4,
            k: 2,
            heads: 2,
            vocab: 32,
            t_max: 32,
            shared: rng.below(2) == 0,
            m_shared: 8,
            // drop-free capacity regime: the exact-equivalence precondition
            cap_factor: 4.0,
            block_c: 4,
        };
        let w = hc_smoe::weights::Weights::synthesize(&cfg, rng.next_u64());
        // layout: 0 = full, 1 = masked, 2 = compact r=2
        let layout = rng.below(3);
        let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
        let (weights, n_slots, remap) = match layout {
            1 => {
                // mask off up to n_exp - k experts per layer (keep top-k
                // routable)
                for l in 0..cfg.n_layer {
                    for _ in 0..rng.below(cfg.n_exp - cfg.k + 1) {
                        mask[l * cfg.n_exp + rng.below(cfg.n_exp)] = MASK_OFF;
                    }
                }
                (w.clone(), cfg.n_exp, None)
            }
            2 => {
                let r = 2usize;
                let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
                let cw = w.to_compact(&cfg, &keep).map_err(|e| e.to_string())?;
                let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
                    .map(|i| ((i % cfg.n_exp) % r) as i32)
                    .collect();
                (cw, r, Some(remap))
            }
            _ => (w.clone(), cfg.n_exp, None),
        };
        let backend = NativeBackend::new(cfg.clone());
        let state = backend.load_model(&weights, n_slots).map_err(|e| e.to_string())?;
        let base_opts = || {
            let mut o = PrefillOpts::new(&mask);
            if let Some(rm) = remap.as_deref() {
                o = o.remap(rm);
            }
            o
        };

        let bsz = 1 + rng.below(3);
        let prompts: Vec<Vec<i32>> = (0..bsz)
            .map(|_| (0..2 + rng.below(7)).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();
        let runs: Vec<Vec<i32>> = (0..bsz)
            .map(|_| (0..1 + rng.below(5)).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();

        // reference: per-sequence sequential decodes
        let mut ref_rows: Vec<Vec<Vec<f32>>> = Vec::new();
        for (p, run) in prompts.iter().zip(&runs) {
            let (cache, _) = backend
                .run_prefill(state.as_ref(), p, base_opts())
                .map_err(|e| e.to_string())?;
            let mut cache = cache.expect("fresh prefill returns a cache");
            let mut rows = Vec::new();
            for &t in run {
                rows.push(
                    backend
                        .run_decode(state.as_ref(), cache.as_mut(), t, &mask, remap.as_deref())
                        .map_err(|e| e.to_string())?,
                );
            }
            ref_rows.push(rows);
        }

        // one batched verify forward at a random explicit thread count
        let threads = [1usize, 2, 4][rng.below(3)];
        let mut caches: Vec<Box<dyn KvCache>> = Vec::new();
        for p in &prompts {
            let (cache, _) = backend
                .run_prefill(state.as_ref(), p, base_opts())
                .map_err(|e| e.to_string())?;
            caches.push(cache.expect("fresh prefill returns a cache"));
        }
        let outs = {
            let mut refs: Vec<&mut dyn KvCache> =
                caches.iter_mut().map(|c| c.as_mut()).collect();
            let toks: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            backend
                .run_verify_batch_with(
                    state.as_ref(),
                    &mut refs,
                    &toks,
                    &mask,
                    remap.as_deref(),
                    threads,
                )
                .map_err(|e| e.to_string())?
        };
        for (s, out) in outs.iter().enumerate() {
            ensure(out.logits.len() == runs[s].len(), "one logits row per fed token")?;
            for (i, (row, rrow)) in out.logits.iter().zip(&ref_rows[s]).enumerate() {
                let same = row.len() == rrow.len()
                    && row
                        .iter()
                        .zip(rrow)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                ensure(
                    same,
                    format!(
                        "layout={layout} threads={threads} seq={s} pos={i}: \
                         verify row != sequential decode"
                    ),
                )?;
                ensure(
                    out.checkpoints[i].len() == prompts[s].len() + i + 1,
                    format!("seq={s} pos={i}: checkpoint length"),
                )?;
            }
            ensure(
                caches[s].seq_len() == prompts[s].len() + runs[s].len(),
                "verify advances the cache over the whole run",
            )?;
        }
        Ok(())
    });
}

/// Randomized paged-sequence lifecycle stress: arbitrary interleavings
/// of reserve/append/truncate/fork/drop must keep the pool's O(1)
/// counters (`stats()`) equal to a ground-truth O(total_blocks) scan of
/// per-block refcounts, with reservations exactly the sum of what the
/// live sequences still hold.
#[test]
fn prop_kvpool_stats_match_debug_scan() {
    check("kvpool-stats-scan", 800, 40, |rng| {
        let total = 12 + rng.below(20);
        let pool = PoolHandle::new(KvPool::new(2, 4, 4, total).unwrap());
        let mut seqs: Vec<PagedSeq> = Vec::new();

        let scan_check = |pool: &PoolHandle, seqs: &[PagedSeq], op: &str| {
            let st = pool.stats();
            let p = pool.borrow();
            let scanned_in_use = (0..st.total_blocks).filter(|&b| p.refs(b) > 0).count();
            let scanned_shared = (0..st.total_blocks).filter(|&b| p.refs(b) > 1).count();
            let live_reserved: usize = seqs.iter().map(|s| s.reserved_remaining()).sum();
            ensure(
                st.in_use == scanned_in_use,
                format!("{op}: in_use {} != scanned {scanned_in_use}", st.in_use),
            )?;
            ensure(
                st.shared == scanned_shared,
                format!("{op}: shared {} != scanned {scanned_shared}", st.shared),
            )?;
            ensure(
                st.reserved == live_reserved,
                format!("{op}: reserved {} != live sum {live_reserved}", st.reserved),
            )?;
            ensure(
                st.in_use + st.reserved <= st.total_blocks,
                format!("{op}: committed {} over budget", st.in_use + st.reserved),
            )?;
            ensure(st.peak_in_use >= st.in_use, format!("{op}: peak below in_use"))
        };

        for _ in 0..60 {
            let op = rng.below(5);
            match op {
                // spawn with a random reservation (may be refused — fine)
                0 if seqs.len() < 6 => {
                    let reserve = rng.below(4);
                    if let Ok(s) = PagedSeq::new(&pool, reserve) {
                        seqs.push(s);
                    }
                }
                // append one token position (COW/fresh-block allocation is
                // best-effort; a refusal must leave the counters intact)
                1 if !seqs.is_empty() => {
                    let i = rng.below(seqs.len());
                    if seqs[i].prepare_append().is_ok() {
                        seqs[i].commit_append();
                    }
                }
                // truncate to a random earlier length (the speculative
                // rollback primitive)
                2 if !seqs.is_empty() => {
                    let i = rng.below(seqs.len());
                    let to = rng.below(seqs[i].seq_len() + 1);
                    seqs[i].truncate_to(to).map_err(|e| e.to_string())?;
                    ensure(seqs[i].seq_len() == to, "truncate_to lands exactly")?;
                }
                // fork (shares every block by reference)
                3 if !seqs.is_empty() && seqs.len() < 6 => {
                    let i = rng.below(seqs.len());
                    let f = seqs[i].fork();
                    ensure(f.seq_len() == seqs[i].seq_len(), "fork preserves length")?;
                    seqs.push(f);
                }
                // drop (releases blocks and any leftover reservation)
                4 if !seqs.is_empty() => {
                    let i = rng.below(seqs.len());
                    seqs.swap_remove(i);
                }
                _ => {}
            }
            scan_check(&pool, &seqs, &format!("op {op}"))?;
        }
        seqs.clear();
        let st = pool.stats();
        ensure(st.in_use == 0, format!("{} blocks leaked", st.in_use))?;
        ensure(st.reserved == 0, format!("{} reservations leaked", st.reserved))
    });
}

#[test]
fn prop_deviation_monotone_under_superset_of_top_experts() {
    // dropping more experts can only keep-or-raise the best achievable
    // deviation: best subset of size r+1 <= best subset of size r... checked
    // via exhaustive enumeration on small n
    check("deviation-monotone", 600, 10, |rng| {
        let n = 5;
        let layer = random_layer(rng, n, 4, 3, 8);
        let stats = CalibStats { domain: "p".into(), layers: vec![layer], n_tokens: 8 };
        let best_r = |r: usize| -> f64 {
            let p = o_prune(&stats, r, 2, 100_000, 1);
            layer_output_deviation(&stats.layers[0], &p.keep[0], 2)
        };
        let d3 = best_r(3);
        let d4 = best_r(4);
        ensure(d4 <= d3 + 1e-9, format!("larger budget worse: {d4} > {d3}"))
    });
}
