//! Cross-module property tests over the coordinator's invariants
//! (hand-rolled runner; proptest is unavailable offline). These run on
//! synthetic stats — no artifacts required.

use hc_smoe::calib::{CalibStats, LayerStats};
use hc_smoe::clustering::{fcm, hierarchical, kmeans, single_shot, KmeansInit, Linkage};
use hc_smoe::merging::{merge_cluster, FixDomFeature, MergeStrategy};
use hc_smoe::pruning::{f_prune, layer_output_deviation, o_prune, s_prune};
use hc_smoe::similarity::{distance_matrix, Distance};
use hc_smoe::tensor::Tensor;
use hc_smoe::util::proptest::{check, ensure};
use hc_smoe::util::Rng;

fn random_layer(rng: &mut Rng, n: usize, d: usize, m: usize, t_sub: usize) -> LayerStats {
    let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    };
    let mean = mk(rng, n * d);
    let counts: Vec<f32> = (0..n).map(|_| 1.0 + rng.below(50) as f32).collect();
    LayerStats {
        mean_out: Tensor::new(vec![n, d], mean).unwrap(),
        probs_sum: counts.clone(),
        gate_sum: counts.clone(),
        counts,
        rl_sub: Tensor::new(vec![t_sub, n], mk(rng, t_sub * n)).unwrap(),
        raw_sub: Tensor::new(vec![n, t_sub, d], mk(rng, n * t_sub * d)).unwrap(),
        act_sub: Tensor::new(vec![n, 8, m], mk(rng, n * 8 * m)).unwrap(),
        hid_sub: Tensor::new(vec![t_sub, d], mk(rng, t_sub * d)).unwrap(),
    }
}

fn random_stats(rng: &mut Rng, nl: usize, n: usize) -> CalibStats {
    CalibStats {
        domain: "prop".into(),
        layers: (0..nl).map(|_| random_layer(rng, n, 6, 5, 12)).collect(),
        n_tokens: 128,
    }
}

#[test]
fn prop_every_clusterer_yields_valid_partitions() {
    check("all-clusterers-partition", 100, 40, |rng| {
        let n = 3 + rng.below(13);
        let r = 1 + rng.below(n);
        let feats: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..5).map(|_| rng.normal() as f32).collect())
            .collect();
        let freqs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 10.0).collect();
        let d = distance_matrix(&feats, Distance::Euclidean);
        for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
            hierarchical(&d, r, linkage).validate().map_err(|e| e.to_string())?;
        }
        kmeans(&feats, r, KmeansInit::Random { seed: rng.next_u64() }, 30)
            .validate()
            .map_err(|e| e.to_string())?;
        single_shot(&feats, &freqs, r).validate().map_err(|e| e.to_string())?;
        let f = fcm(&feats, r, 2.0, 20, rng.next_u64());
        for row in &f.membership {
            let s: f32 = row.iter().sum();
            ensure((s - 1.0).abs() < 1e-3, format!("membership row sums to {s}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_merge_strategies_preserve_shape_and_finiteness() {
    check("merge-shape-finite", 200, 25, |rng| {
        let n = 4;
        let (d, m) = (6, 5);
        let layer = random_layer(rng, n, d, m, 12);
        let mut map = std::collections::BTreeMap::new();
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        map.insert(
            "layer00.exp.wg".to_string(),
            Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
        );
        map.insert(
            "layer00.exp.wu".to_string(),
            Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
        );
        map.insert(
            "layer00.exp.wd".to_string(),
            Tensor::new(vec![n, m, d], mk(rng, n * m * d)).unwrap(),
        );
        let w = hc_smoe::weights::Weights::new(map);
        let members = vec![0usize, 2, 3];
        for strategy in [
            MergeStrategy::Average,
            MergeStrategy::Frequency,
            MergeStrategy::FixDom(FixDomFeature::Act),
            MergeStrategy::FixDom(FixDomFeature::Weight),
            MergeStrategy::ZipIt(FixDomFeature::Weight),
        ] {
            let e = merge_cluster(&w, &layer, 0, &members, strategy)
                .map_err(|e| e.to_string())?;
            ensure(e.wg.shape() == [d, m], "wg shape")?;
            ensure(e.wd.shape() == [m, d], "wd shape")?;
            ensure(
                e.wg.data().iter().all(|x| x.is_finite()),
                format!("{strategy:?} produced non-finite weights"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_average_merge_is_convex_combination() {
    // every element of the average-merged expert lies within the min/max
    // envelope of its members
    check("merge-convex", 300, 25, |rng| {
        let n = 3;
        let (d, m) = (4, 3);
        let layer = random_layer(rng, n, d, m, 8);
        let mut map = std::collections::BTreeMap::new();
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal() as f32).collect()
        };
        for key in ["exp.wg", "exp.wu"] {
            map.insert(
                format!("layer00.{key}"),
                Tensor::new(vec![n, d, m], mk(rng, n * d * m)).unwrap(),
            );
        }
        map.insert(
            "layer00.exp.wd".to_string(),
            Tensor::new(vec![n, m, d], mk(rng, n * m * d)).unwrap(),
        );
        let w = hc_smoe::weights::Weights::new(map);
        let members = vec![0usize, 1, 2];
        for strategy in [MergeStrategy::Average, MergeStrategy::Frequency] {
            let merged = merge_cluster(&w, &layer, 0, &members, strategy)
                .map_err(|e| e.to_string())?;
            let experts: Vec<_> = members
                .iter()
                .map(|&e| w.expert(0, e).unwrap())
                .collect();
            for i in 0..d * m {
                let vals: Vec<f32> = experts.iter().map(|e| e.wg.data()[i]).collect();
                let lo = vals.iter().cloned().fold(f32::MAX, f32::min) - 1e-4;
                let hi = vals.iter().cloned().fold(f32::MIN, f32::max) + 1e-4;
                let x = merged.wg.data()[i];
                ensure(x >= lo && x <= hi, format!("{x} outside [{lo}, {hi}]"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pruning_budgets_and_validity() {
    check("prune-budgets", 400, 30, |rng| {
        let nl = 1 + rng.below(4);
        let n = 4 + rng.below(10);
        let k = 2;
        let r = k + rng.below(n - k);
        let stats = random_stats(rng, nl, n);
        for p in [s_prune(&stats, r, k), f_prune(&stats, r, k)] {
            p.validate(n, k).map_err(|e| e.to_string())?;
            let total: usize = p.keep.iter().map(|x| x.len()).sum();
            ensure(total == r * nl, format!("budget {total} != {}", r * nl))?;
        }
        let p = o_prune(&stats, r, k, 50, rng.next_u64());
        p.validate(n, k).map_err(|e| e.to_string())?;
        ensure(p.keep.iter().all(|x| x.len() == r), "o-prune is static-r")?;
        Ok(())
    });
}

#[test]
fn prop_keeping_all_experts_has_zero_deviation() {
    check("full-subset-zero-dev", 500, 20, |rng| {
        let n = 3 + rng.below(6);
        let layer = random_layer(rng, n, 5, 4, 10);
        let all: Vec<usize> = (0..n).collect();
        let dev = layer_output_deviation(&layer, &all, 2);
        ensure(dev < 1e-9, format!("full subset deviation {dev}"))
    });
}

#[test]
fn prop_deviation_monotone_under_superset_of_top_experts() {
    // dropping more experts can only keep-or-raise the best achievable
    // deviation: best subset of size r+1 <= best subset of size r... checked
    // via exhaustive enumeration on small n
    check("deviation-monotone", 600, 10, |rng| {
        let n = 5;
        let layer = random_layer(rng, n, 4, 3, 8);
        let stats = CalibStats { domain: "p".into(), layers: vec![layer], n_tokens: 8 };
        let best_r = |r: usize| -> f64 {
            let p = o_prune(&stats, r, 2, 100_000, 1);
            layer_output_deviation(&stats.layers[0], &p.keep[0], 2)
        };
        let d3 = best_r(3);
        let d4 = best_r(4);
        ensure(d4 <= d3 + 1e-9, format!("larger budget worse: {d4} > {d3}"))
    });
}
