//! Speculative-decoding test suite: draft-k/verify-1 with the compact
//! merged variant as the drafter, pinned **bit-identical** to plain
//! decoding — offline (synthesized artifacts, native backend).
//!
//! The headline contracts:
//!
//! * [`speculative`] / [`speculative_paged`] emit exactly the token
//!   stream (and finish reason) of plain [`generate`] with the same
//!   parameters, for every draft depth k ∈ {1, 2, 4, 8}, greedy and
//!   seeded top-k, on the full, merged-masked and shared-expert
//!   verifier layouts, over flat and paged caches.
//! * The multi-position verify forward is bit-identical to k sequential
//!   decode calls at explicit thread counts {1, 2, 4} on every layout.
//! * A rejection rollback leaves a cache functionally identical to a
//!   freshly prefilled prefix: same length, same resident bytes, and
//!   bit-identical logits for every subsequent decode step (the
//!   byte-level K/V comparison lives in `backend::native`'s unit tests,
//!   which can see the private buffers).
//! * The server interleaves speculative and plain sequences in one
//!   continuous batch with bit-identical streams, rejects malformed
//!   speculative requests at intake, and a speculative + preemption
//!   mixed workload leaks zero KV-pool blocks.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use hc_smoe::backend::native::NativeBackend;
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::bench_support::synthesize_artifacts;
use hc_smoe::clustering::Linkage;
use hc_smoe::config::{Artifacts, ModelCfg};
use hc_smoe::generate::{
    generate, speculative, speculative_paged, Generated, SamplingParams,
};
use hc_smoe::kvpool::DEFAULT_BLOCK_TOKENS;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::{CompactModel, LoadedModel, ModelContext};
use hc_smoe::pipeline::{Method, Pipeline, MASK_OFF};
use hc_smoe::serving::{
    reply_channel, serve, BatcherConfig, GenerateRequest, Priority, Request, ServeSpec,
    ServerHandle,
};
use hc_smoe::similarity::Metric;
use hc_smoe::weights::Weights;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Synthesize one artifact set per test process (shared across tests).
fn arts() -> Artifacts {
    static DIR: std::sync::OnceLock<PathBuf> = std::sync::OnceLock::new();
    let dir = DIR.get_or_init(|| {
        let p = std::env::temp_dir().join(format!("hcsmoe_spec_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        synthesize_artifacts(&p, 0x57EC).expect("synthesize artifacts");
        p
    });
    Artifacts::new(dir)
}

fn hc_method() -> Method {
    Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    }
}

/// Build (verifier, drafter) for a model: the original weights as the
/// full verifier plus the HC-merged compact variant as the drafter.
fn verifier_and_drafter(ctx: &ModelContext, r: usize) -> (LoadedModel, CompactModel) {
    let full = ctx.load_original().unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(ctx, &stats, r).unwrap();
    let cm = plan.apply(ctx, &stats).unwrap();
    let (cw, remap) = cm.to_compact(ctx).unwrap();
    let drafter = ctx.load_compact(r, &cw, remap, "drafter").unwrap();
    (full, drafter)
}

/// Assert a speculative outcome IS the plain outcome, plus accounting
/// sanity: k = 1 never drafts, deeper k never accepts more than drafted.
fn assert_spec_matches(
    what: &str,
    k: usize,
    plain: &Generated,
    spec: &hc_smoe::generate::SpecOutcome,
) {
    assert_eq!(spec.gen.tokens, plain.tokens, "{what} k={k}: token stream diverged");
    assert_eq!(spec.gen.finish, plain.finish, "{what} k={k}: finish reason diverged");
    assert!(spec.accepted <= spec.drafted, "{what} k={k}: accounting inverted");
    assert!(spec.verify_steps >= 1, "{what} k={k}: no verify forward ran");
    if k == 1 {
        assert_eq!(spec.drafted, 0, "{what}: draft_k=1 proposes nothing beyond pending");
    }
    // each verify round emits at least one token, so rounds never exceed
    // the emitted count — and with k > 1 they should beat plain decode
    // whenever anything was accepted
    assert!(spec.verify_steps <= plain.tokens.len().max(1), "{what} k={k}");
    if spec.accepted > 0 {
        assert!(
            spec.verify_steps < plain.tokens.len(),
            "{what} k={k}: accepted drafts must save verify forwards"
        );
    }
    let rate = spec.acceptance_rate();
    assert!((0.0..=1.0).contains(&rate), "{what} k={k}: rate {rate} out of range");
}

// ---------------------------------------------------------------------------
// Offline driver pinning: speculative == plain, every layout/k/cache/strategy
// ---------------------------------------------------------------------------

/// The core pinning sweep for one model: k ∈ {1, 2, 4, 8} × {greedy,
/// seeded top-k} × {flat, paged} speculative runs against the plain
/// flat-cache reference.
fn pin_speculative_for(model_name: &str, r: usize) {
    let ctx = ModelContext::load(&arts(), model_name).unwrap();
    let (full, drafter) = verifier_and_drafter(&ctx, r);
    let v = ctx.cfg.vocab;
    let prompt: Vec<i32> = (0..7).map(|i| ((1 + i * 5) % v) as i32).collect();
    let param_sets = [
        SamplingParams::greedy(18, None),
        SamplingParams::top_k(8, 0.8, 7, 18, None),
    ];
    for params in &param_sets {
        let plain = generate(&ctx, &full, &prompt, params.clone()).unwrap();
        for k in [1usize, 2, 4, 8] {
            let spec =
                speculative(&ctx, &full, &drafter, &prompt, params.clone(), k).unwrap();
            assert_spec_matches(&format!("{model_name} flat"), k, &plain, &spec);

            let pool = ctx.kv_pool(8 << 20).unwrap();
            let reserve = prompt.len() + params.max_new_tokens;
            let paged = speculative_paged(
                &ctx, &full, &drafter, &prompt, params.clone(), k, &pool, reserve,
            )
            .unwrap();
            assert_spec_matches(&format!("{model_name} paged"), k, &plain, &paged);
            // both caches of the pair were dropped with the outcome — the
            // pool must be fully drained (leak-freedom, offline flavour)
            assert_eq!(
                pool.stats().in_use,
                0,
                "{model_name} k={k}: speculative pair leaked pool blocks"
            );
        }
    }
}

#[test]
fn speculative_matches_plain_full_layout() {
    // qwensim: 8 experts, full layout verifier
    pin_speculative_for("qwensim", 4);
}

#[test]
fn speculative_matches_plain_shared_expert_layout() {
    // dssim: shared-expert FFN on every layer, plus the routed experts
    pin_speculative_for("dssim", 4);
}

#[test]
fn speculative_matches_plain_masked_verifier() {
    // the verifier itself can be a merged (masked-layout) variant: the
    // drafter is then the compact form of the SAME plan, so acceptance
    // is perfect and the stream still pins against the masked plain run
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let r = 4usize;
    let stats = ctx.calibrate("general").unwrap();
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let merged = cm.load(&ctx).unwrap();
    let (cw, remap) = cm.to_compact(&ctx).unwrap();
    let drafter = ctx.load_compact(r, &cw, remap, "drafter").unwrap();
    let prompt = [1i32, 4, 25, 61, 3, 5];
    for params in [
        SamplingParams::greedy(16, None),
        SamplingParams::top_k(6, 0.7, 11, 16, None),
    ] {
        let plain = generate(&ctx, &merged, &prompt, params.clone()).unwrap();
        for k in [2usize, 4] {
            let spec =
                speculative(&ctx, &merged, &drafter, &prompt, params.clone(), k).unwrap();
            assert_spec_matches("masked verifier", k, &plain, &spec);
        }
    }
}

#[test]
fn speculative_with_quantized_drafter_stays_exact() {
    // the drafter only proposes; the f32 verifier decides — so an int8
    // compact drafter must leave the emitted stream bit-for-bit equal to
    // plain decoding, even though its own logits drift from the f32 ones
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let full = ctx.load_original().unwrap();
    let stats = ctx.calibrate("general").unwrap();
    let r = 4usize;
    let plan = Pipeline::new(hc_method()).plan(&ctx, &stats, r).unwrap();
    let cm = plan.apply(&ctx, &stats).unwrap();
    let (qw, remap) = cm.to_compact_quantized(&ctx).unwrap();
    assert!(qw.is_quantized(), "compact drafter weights must carry the int8 section");
    let drafter = ctx.load_compact(r, &qw, remap, "q8-drafter").unwrap();
    let v = ctx.cfg.vocab;
    let prompt: Vec<i32> = (0..7).map(|i| ((1 + i * 5) % v) as i32).collect();
    for params in [
        SamplingParams::greedy(18, None),
        SamplingParams::top_k(8, 0.8, 7, 18, None),
    ] {
        let plain = generate(&ctx, &full, &prompt, params.clone()).unwrap();
        for k in [2usize, 4] {
            let spec =
                speculative(&ctx, &full, &drafter, &prompt, params.clone(), k).unwrap();
            assert_spec_matches("quantized drafter", k, &plain, &spec);
        }
    }
}

#[test]
fn speculative_respects_stop_conditions() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let (full, drafter) = verifier_and_drafter(&ctx, 4);
    let prompt = [1i32, 4, 33, 3, 5];

    // EOS mid-run: pin it to a later greedy token, so the stop lands
    // inside a k=4 draft run and the tail must be discarded. (Compared
    // directly rather than via assert_spec_matches: if the pinned EOS
    // also happens to be the FIRST emitted token, zero verify rounds run
    // — the streams must still agree.)
    let probe = generate(&ctx, &full, &prompt, SamplingParams::greedy(6, None)).unwrap();
    let eos = *probe.tokens.iter().find(|&&t| t != probe.tokens[0]).unwrap_or(&probe.tokens[0]);
    let plain = generate(&ctx, &full, &prompt, SamplingParams::greedy(16, Some(eos))).unwrap();
    let spec =
        speculative(&ctx, &full, &drafter, &prompt, SamplingParams::greedy(16, Some(eos)), 4)
            .unwrap();
    assert_eq!(spec.gen.tokens, plain.tokens, "eos: token stream diverged");
    assert_eq!(spec.gen.finish, plain.finish, "eos: finish reason diverged");

    // context-window exhaustion: the drafter must clamp its run so
    // neither cache ever exceeds t_max
    let t_max = ctx.cfg.t_max;
    let long: Vec<i32> = (0..t_max - 5).map(|i| ((16 + i * 3) % 90) as i32).collect();
    let plain = generate(&ctx, &full, &long, SamplingParams::greedy(100, None)).unwrap();
    let spec =
        speculative(&ctx, &full, &drafter, &long, SamplingParams::greedy(100, None), 8).unwrap();
    assert_spec_matches("max-context", 8, &plain, &spec);

    // invalid inputs fail like plain generate does
    assert!(speculative(&ctx, &full, &drafter, &[], SamplingParams::greedy(4, None), 4).is_err());
    assert!(
        speculative(&ctx, &full, &drafter, &prompt, SamplingParams::greedy(4, None), 0).is_err(),
        "draft_k=0 must be rejected"
    );
}

// ---------------------------------------------------------------------------
// Backend-level: verify == k sequential decodes at explicit thread counts
// ---------------------------------------------------------------------------

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "spec".into(),
        n_layer: 2,
        d: 16,
        m: 16,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 48,
        t_max: 40,
        shared: false,
        m_shared: 16,
        // k=2 distinct experts bound every capacity queue below the
        // cap_factor=4 capacity — drop-free, the exact-equivalence regime
        cap_factor: 4.0,
        block_c: 4,
    }
}

/// One layout's check: a 2-sequence verify batch with ragged runs equals
/// the same tokens decoded one at a time, bitwise, at threads {1, 2, 4}.
fn assert_verify_matches_sequential(
    cfg: &ModelCfg,
    w: &Weights,
    n_slots: usize,
    mask: &[f32],
    remap: Option<&[i32]>,
) {
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(w, n_slots).unwrap();
    let v = cfg.vocab;
    let prompts: [Vec<i32>; 2] = [
        (0..6).map(|i| ((3 + i * 5) % v) as i32).collect(),
        (0..9).map(|i| ((7 + i * 11) % v) as i32).collect(),
    ];
    let runs: [Vec<i32>; 2] = [
        (0..4).map(|i| ((1 + i * 13) % v) as i32).collect(),
        (0..2).map(|i| ((5 + i * 17) % v) as i32).collect(),
    ];
    let base_opts = || {
        let mut o = PrefillOpts::new(mask);
        if let Some(rm) = remap {
            o = o.remap(rm);
        }
        o
    };

    // reference: sequential run_decode rows per sequence
    let mut ref_rows: Vec<Vec<Vec<f32>>> = Vec::new();
    for (p, run) in prompts.iter().zip(&runs) {
        let (cache, _) = backend.run_prefill(state.as_ref(), p, base_opts()).unwrap();
        let mut cache = cache.expect("fresh prefill returns a cache");
        let rows = run
            .iter()
            .map(|&t| {
                backend.run_decode(state.as_ref(), cache.as_mut(), t, mask, remap).unwrap()
            })
            .collect();
        ref_rows.push(rows);
    }

    for threads in [1usize, 2, 4] {
        let mut caches: Vec<Box<dyn KvCache>> = prompts
            .iter()
            .map(|p| {
                let (c, _) = backend.run_prefill(state.as_ref(), p, base_opts()).unwrap();
                c.expect("fresh prefill returns a cache")
            })
            .collect();
        let outs = {
            let mut refs: Vec<&mut dyn KvCache> =
                caches.iter_mut().map(|c| c.as_mut()).collect();
            let toks: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            backend
                .run_verify_batch_with(state.as_ref(), &mut refs, &toks, mask, remap, threads)
                .unwrap()
        };
        for (s, (out, rrows)) in outs.iter().zip(&ref_rows).enumerate() {
            assert_eq!(out.logits.len(), rrows.len());
            assert_eq!(out.checkpoints.len(), rrows.len());
            for (i, (row, rrow)) in out.logits.iter().zip(rrows).enumerate() {
                assert_eq!(
                    bits(row),
                    bits(rrow),
                    "threads={threads} seq={s} pos={i}: verify row != sequential decode"
                );
            }
            assert_eq!(
                out.checkpoints.last().unwrap().len(),
                prompts[s].len() + runs[s].len(),
                "threads={threads} seq={s}: final checkpoint length"
            );
        }
    }
}

#[test]
fn verify_matches_sequential_decode_full_layout_threads() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 61);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    assert_verify_matches_sequential(&cfg, &w, cfg.n_exp, &mask, None);
}

#[test]
fn verify_matches_sequential_decode_masked_layout_threads() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 67);
    let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    mask[1] = MASK_OFF;
    mask[cfg.n_exp + 3] = MASK_OFF;
    assert_verify_matches_sequential(&cfg, &w, cfg.n_exp, &mask, None);
}

#[test]
fn verify_matches_sequential_decode_compact_layout_threads() {
    let cfg = tiny_cfg();
    let w = Weights::synthesize(&cfg, 71);
    let r = 2usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).unwrap();
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    assert_verify_matches_sequential(&cfg, &cw, r, &mask, Some(&remap));
}

#[test]
fn verify_matches_sequential_decode_shared_expert_threads() {
    let cfg = ModelCfg { shared: true, ..tiny_cfg() };
    let w = Weights::synthesize(&cfg, 73);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    assert_verify_matches_sequential(&cfg, &w, cfg.n_exp, &mask, None);
}

// ---------------------------------------------------------------------------
// Rollback: a rejected run's cache is indistinguishable from a fresh prefix
// ---------------------------------------------------------------------------

/// After verifying a k-token run and rolling back to checkpoint `i`, the
/// cache must behave exactly like one freshly prefilled with
/// prompt + run[..=i]: same length, same resident bytes, bit-identical
/// logits on every subsequent decode. Exercised on flat + paged caches
/// of the full verifier AND (via snapshot/rollback) the compact drafter.
#[test]
fn rollback_restores_a_fresh_prefix_cache() {
    let ctx = ModelContext::load(&arts(), "qwensim").unwrap();
    let (full, drafter) = verifier_and_drafter(&ctx, 4);
    let v = ctx.cfg.vocab;
    let prompt: Vec<i32> = (0..8).map(|i| ((2 + i * 7) % v) as i32).collect();
    let run: Vec<i32> = (0..4).map(|i| ((9 + i * 13) % v) as i32).collect();
    let cont: Vec<i32> = (0..5).map(|i| ((4 + i * 19) % v) as i32).collect();
    let pool = ctx.kv_pool(8 << 20).unwrap();

    for paged in [false, true] {
        for keep in [1usize, 3] {
            // fresh-prefix reference: prompt + run[..keep], then `cont`
            let mut pref: Vec<i32> = prompt.clone();
            pref.extend_from_slice(&run[..keep]);
            let (mut fresh, _) = ctx.prefill(&full, &pref).unwrap();
            let ref_rows: Vec<Vec<f32>> = cont
                .iter()
                .map(|&t| ctx.decode(&full, fresh.as_mut(), t).unwrap())
                .collect();

            // speculative-shaped path: prefill prompt, verify the whole
            // run, roll back to checkpoint keep-1 (run[..keep] kept)
            let (mut cache, _) = if paged {
                ctx.prefill_paged(&full, &prompt, &pool, prompt.len() + run.len() + cont.len())
                    .unwrap()
            } else {
                ctx.prefill(&full, &prompt).unwrap()
            };
            let out = {
                let mut refs: [&mut dyn KvCache; 1] = [cache.as_mut()];
                ctx.verify(&full, &mut refs, &[run.as_slice()]).unwrap().pop().unwrap()
            };
            ctx.rollback_cache(cache.as_mut(), &out.checkpoints[keep - 1]).unwrap();
            assert_eq!(cache.seq_len(), pref.len(), "paged={paged} keep={keep}: length");
            if !paged {
                // flat resident bytes track seq_len exactly; paged ones
                // are whole-block granular, covered by the pool drain below
                assert_eq!(
                    cache.byte_size(),
                    ctx.cfg.kv_cache_bytes(pref.len()),
                    "paged={paged} keep={keep}: resident bytes"
                );
            }
            for (i, (&t, rrow)) in cont.iter().zip(&ref_rows).enumerate() {
                let row = ctx.decode(&full, cache.as_mut(), t).unwrap();
                assert_eq!(
                    bits(&row),
                    bits(rrow),
                    "paged={paged} keep={keep}: decode {i} diverged after rollback"
                );
            }
        }
    }
    drop(pool);

    // the drafter side: decode forward, snapshot at each step, roll back
    // two steps, and re-decode bit-identically (the spec_loop dsnaps path)
    let (mut dcache, _) = ctx.prefill_compact(&drafter, &prompt).unwrap();
    let snap0 = ctx.snapshot_cache(dcache.as_ref()).unwrap();
    let rows_a: Vec<Vec<f32>> = run
        .iter()
        .map(|&t| ctx.decode_compact(&drafter, dcache.as_mut(), t).unwrap())
        .collect();
    ctx.rollback_cache(dcache.as_mut(), &snap0).unwrap();
    assert_eq!(dcache.seq_len(), prompt.len());
    let rows_b: Vec<Vec<f32>> = run
        .iter()
        .map(|&t| ctx.decode_compact(&drafter, dcache.as_mut(), t).unwrap())
        .collect();
    for (i, (a, b)) in rows_a.iter().zip(&rows_b).enumerate() {
        assert_eq!(bits(a), bits(b), "drafter replay step {i} diverged after rollback");
    }
}

// ---------------------------------------------------------------------------
// Serving: drafter-paired sequences in the continuous batch
// ---------------------------------------------------------------------------

/// Serve qwensim with a drafter variant and an optional explicit pool
/// budget in *blocks*.
fn serve_with_drafter(a: &Artifacts, cfg: &ModelCfg, blocks: Option<usize>) -> ServerHandle {
    serve(
        ServeSpec {
            kv_budget_bytes: blocks.map(|b| b * cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS)),
            drafter: Some((hc_method(), 4, "general".into())),
            ..ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap()
}

/// Poll a metrics predicate with a deadline (the executor publishes pool
/// gauges once per loop iteration).
fn wait_for(handle: &ServerHandle, what: &str, pred: impl Fn(&ServerHandle) -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !pred(handle) {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn served_speculative_streams_match_offline_interleaved_with_plain() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let handle = serve_with_drafter(&a, &ctx.cfg, None);
    let prompt = [1i32, 4, 20, 3, 5];
    let seeds = [1u64, 2, 3, 4];

    let mut served = Vec::new();
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for (gi, &seed) in seeds.iter().enumerate() {
            let handle = &handle;
            let prompt = &prompt;
            joins.push(s.spawn(move || {
                let params = SamplingParams::top_k(8, 0.8, seed, 8 + 3 * gi, None);
                let mut req = GenerateRequest::new(prompt, params);
                if gi % 2 == 0 {
                    // even clients go speculative, odd ones stay plain —
                    // both kinds share the continuous batch
                    req = req.drafter(2 + gi);
                }
                let rx = handle.submit(req).unwrap().expect("fresh request owns rx");
                rx.recv().unwrap().unwrap()
            }));
        }
        for j in joins {
            served.push(j.join().expect("generation client panicked"));
        }
    });

    for (gi, (&seed, out)) in seeds.iter().zip(&served).enumerate() {
        let params = SamplingParams::top_k(8, 0.8, seed, 8 + 3 * gi, None);
        let offline = generate(&ctx, &model, &prompt, params).unwrap();
        assert_eq!(
            out.tokens, offline.tokens,
            "client {gi} (spec={}) diverged from offline",
            gi % 2 == 0
        );
        assert_eq!(out.finish, offline.finish, "client {gi}");
    }
    let snap = handle.metrics.snapshot();
    assert!(snap.spec_rounds > 0, "no speculative verify round was recorded");
    assert!(snap.spec_drafted > 0, "no draft token was recorded");
    let rate = snap.spec_acceptance_rate();
    assert!((0.0..=1.0).contains(&rate), "acceptance rate {rate} out of range");
    wait_for(&handle, "blocks to drain", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    handle.shutdown().unwrap();
}

#[test]
fn malformed_speculative_requests_are_answered_at_intake() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();

    // drafterless server: a speculative request is an intake error, and
    // the server keeps serving plain traffic afterwards
    let plain_server = serve(
        ServeSpec::for_tests(&a.root.to_string_lossy(), "qwensim"),
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let req = GenerateRequest::new(&[1, 4, 20], SamplingParams::greedy(4, None)).drafter(4);
    let rx = plain_server.submit(req).unwrap().unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    assert!(
        format!("{err:#}").contains("no drafter"),
        "want a no-drafter intake error, got: {err:#}"
    );
    let ok = plain_server.generate(&[1, 4, 20], SamplingParams::greedy(2, None)).unwrap();
    assert_eq!(ok.tokens.len(), 2);
    plain_server.shutdown().unwrap();

    // drafter-equipped server: draft_k = 0 is rejected up front
    let handle = serve_with_drafter(&a, &ctx.cfg, None);
    let req = GenerateRequest::new(&[1, 4, 20], SamplingParams::greedy(4, None)).drafter(0);
    let rx = handle.submit(req).unwrap().unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    assert!(
        format!("{err:#}").contains("draft_k >= 1"),
        "want a draft_k validation error, got: {err:#}"
    );
    let ok = handle.generate(&[1, 4, 20], SamplingParams::greedy(2, None)).unwrap();
    assert_eq!(ok.tokens.len(), 2);
    handle.shutdown().unwrap();
}

#[test]
fn speculative_preemption_mix_leaks_no_blocks() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let model = ctx.load_original().unwrap();
    let cfg = ctx.cfg.clone();

    // 8-block pool. A speculative Batch generation reserving the full
    // context window needs 4 blocks for EACH cache of its full/drafter
    // pair — it owns the whole pool while active, so an Interactive
    // arrival (1 block) can only land by preempting it. Preemption drops
    // both caches; resume re-prefills both and the stream must still
    // equal the uninterrupted offline run bit for bit.
    let handle = serve_with_drafter(&a, &cfg, Some(8));
    let bprompt = [2i32, 5, 21, 7];
    let bparams = SamplingParams::greedy(1_000_000, None); // t_max-bounded
    let iprompt = [1i32, 4, 20];
    let iparams = SamplingParams::greedy(2, None);
    let boffline = generate(&ctx, &model, &bprompt, bparams.clone()).unwrap();
    let ioffline = generate(&ctx, &model, &iprompt, iparams.clone()).unwrap();

    let mut rounds = 0usize;
    while handle.metrics.snapshot().preemptions < 2 {
        rounds += 1;
        assert!(rounds <= 50, "no preemption after 50 collision rounds");
        let rx = handle
            .submit(
                GenerateRequest::new(&bprompt, bparams.clone())
                    .priority(Priority::Batch)
                    .drafter(4),
            )
            .unwrap()
            .expect("a fresh request owns its receiver");
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut batch_out = None;
        loop {
            if let Some(r) = rx.try_recv().unwrap() {
                batch_out = Some(r); // finished before we could collide
                break;
            }
            if handle.metrics.snapshot().kv_blocks_in_use >= 1 {
                break; // resident: the pair's 8-block reservation is held
            }
            assert!(Instant::now() < deadline, "batch job neither resident nor finished");
            std::thread::yield_now();
        }
        let out = match batch_out {
            Some(out) => out.unwrap(),
            None => {
                let served = handle
                    .generate_opts(&iprompt, iparams.clone(), Priority::Interactive, None)
                    .unwrap();
                assert_eq!(served.tokens, ioffline.tokens, "interactive stream diverged");
                rx.recv().unwrap().unwrap()
            }
        };
        assert_eq!(
            out.tokens, boffline.tokens,
            "preempted/resumed speculative stream diverged (round {rounds})"
        );
        assert_eq!(out.finish, boffline.finish);
    }

    wait_for(&handle, "zero blocks after the speculative preemption mix", |h| {
        h.metrics.snapshot().kv_blocks_in_use == 0
    });
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert!(snap.preemptions >= 2, "mix must have preempted: {}", snap.preemptions);
    assert!(snap.spec_rounds > 0, "the Batch stream must actually have drafted");
}

// ---------------------------------------------------------------------------
// Shared reply channel: speculative and plain complete in executor order
// ---------------------------------------------------------------------------

#[test]
fn interleaved_spec_and_plain_respect_priority_order() {
    let a = arts();
    let ctx = ModelContext::load(&a, "qwensim").unwrap();
    let cfg = ctx.cfg.clone();
    drop(ctx);
    let handle = serve_with_drafter(&a, &cfg, None);
    let tx = handle.sender();
    let (reply, rx) = reply_channel();
    let prompt = [1i32, 4, 20, 3];
    // two speculative Batch generations first, then a plain Interactive
    // one — the Interactive request must still complete first even though
    // the Batch pair decodes through the speculative step path
    for max_new in [6usize, 7] {
        tx.send(Request::Generate(
            GenerateRequest::new(&prompt, SamplingParams::greedy(max_new, None))
                .priority(Priority::Batch)
                .drafter(3)
                .reply_to(reply.clone()),
        ))
        .unwrap();
    }
    tx.send(Request::Generate(
        GenerateRequest::new(&prompt, SamplingParams::greedy(2, None))
            .priority(Priority::Interactive)
            .reply_to(reply.clone()),
    ))
    .unwrap();
    drop(reply);
    let order: Vec<usize> = (0..3).map(|_| rx.recv().unwrap().unwrap().tokens.len()).collect();
    assert_eq!(
        order,
        vec![2, 6, 7],
        "Interactive must complete before speculative Batch work (FIFO within class)"
    );
    handle.shutdown().unwrap();
}
