//! Serving-layer and evaluator integration tests: the dynamic batcher must
//! be a *transparent* transport — scores through the server equal scores
//! computed directly through the Evaluator — plus batching/shutdown
//! semantics and eval-harness edge cases. Requires `make artifacts`.

use std::time::Duration;

use hc_smoe::config::Artifacts;
use hc_smoe::data::Benchmark;
use hc_smoe::eval::{log_softmax_at, Evaluator};
use hc_smoe::model::ModelContext;
use hc_smoe::serving::{serve, BatcherConfig, ServeSpec};

fn arts() -> Option<Artifacts> {
    let a = Artifacts::discover();
    if a.root.join("manifest.txt").exists() {
        Some(a)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn spec(arts: &Artifacts) -> ServeSpec {
    ServeSpec::for_tests(&arts.root.to_string_lossy(), "mixsim")
}

#[test]
fn server_scores_match_direct_evaluation() {
    let Some(arts) = arts() else { return };
    let ctx = ModelContext::load(&arts, "mixsim").unwrap();
    let bench = Benchmark::load(arts.benchmark("arc_e")).unwrap();
    let handle = serve(
        spec(&arts),
        BatcherConfig { max_rows: ctx.manifest.eval_b, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    // direct path
    let ev = Evaluator::new(&ctx).unwrap();
    let model = ctx.load_original().unwrap();
    let direct = ev.score_benchmark(&model, &bench).unwrap();
    // served path: same argmax predictions on the first items
    for (ii, item) in bench.items.iter().take(8).enumerate() {
        let scores = handle.score_item(&item.prompt, &item.choices).unwrap();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(pred, direct.predictions[ii], "item {ii} prediction differs");
    }
    handle.shutdown().unwrap();
}

#[test]
fn batcher_packs_concurrent_requests() {
    let Some(arts) = arts() else { return };
    let handle = serve(
        spec(&arts),
        BatcherConfig { max_rows: 32, max_wait: Duration::from_millis(30) },
    )
    .unwrap();
    let bench = Benchmark::load(arts.benchmark("boolq")).unwrap();
    let n_clients = 8;
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let tx = handle.sender();
            let item = bench.items[c].clone();
            s.spawn(move || {
                let rows = item
                    .choices
                    .iter()
                    .map(|ch| {
                        let mut seq = item.prompt.clone();
                        seq.extend_from_slice(ch);
                        hc_smoe::serving::RowSpec {
                            start: item.prompt.len(),
                            end: seq.len(),
                            seq,
                        }
                    })
                    .collect();
                let (reply, rx) = std::sync::mpsc::channel();
                tx.send(
                    hc_smoe::serving::ScoreRequest {
                        rows,
                        reply,
                        enqueued: std::time::Instant::now(),
                    }
                    .into(),
                )
                .unwrap();
                let scores = rx.recv().unwrap();
                assert_eq!(scores.len(), 2);
                assert!(scores.iter().all(|s| s.is_finite() && *s <= 0.0));
            });
        }
    });
    let snap = handle.metrics.snapshot();
    handle.shutdown().unwrap();
    assert_eq!(snap.requests, n_clients as u64);
    assert_eq!(snap.rows, (n_clients * 2) as u64);
    // 16 rows with a 30ms window should need at most a few device batches
    assert!(
        snap.batches < n_clients as u64,
        "batcher failed to pack: {} batches for {} requests",
        snap.batches,
        n_clients
    );
}

#[test]
fn shutdown_joins_cleanly_and_rejects_after() {
    let Some(arts) = arts() else { return };
    let handle = serve(
        spec(&arts),
        BatcherConfig { max_rows: 32, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let tx = handle.sender();
    handle.shutdown().unwrap();
    // the executor is gone; sends eventually error (channel disconnected)
    let (reply, _rx) = std::sync::mpsc::channel();
    let r = tx.send(
        hc_smoe::serving::ScoreRequest {
            rows: vec![],
            reply,
            enqueued: std::time::Instant::now(),
        }
        .into(),
    );
    assert!(r.is_err(), "sender must observe disconnection after shutdown");
}

#[test]
fn evaluator_scores_are_valid_logprobs() {
    let Some(arts) = arts() else { return };
    let ctx = ModelContext::load(&arts, "mixsim").unwrap();
    let ev = Evaluator::new(&ctx).unwrap();
    let model = ctx.load_original().unwrap();
    let bench = Benchmark::load(arts.benchmark("rte")).unwrap();
    let ts = ev.score_benchmark(&model, &bench).unwrap();
    assert_eq!(ts.predictions.len(), bench.items.len());
    assert_eq!(ts.golds.len(), bench.items.len());
    assert!(ts.predictions.iter().all(|&p| p < bench.n_choices));
    let recomputed = ts
        .predictions
        .iter()
        .zip(&ts.golds)
        .filter(|(p, g)| p == g)
        .count() as f64
        / bench.items.len() as f64;
    assert!((recomputed - ts.accuracy).abs() < 1e-12);
}

#[test]
fn log_softmax_row_sums_to_one_in_prob_space() {
    let row: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
    let total: f64 = (0..row.len()).map(|t| log_softmax_at(&row, t).exp()).sum();
    assert!((total - 1.0).abs() < 1e-9, "sum {total}");
}

#[test]
fn scoring_is_length_normalised() {
    // two choices with identical per-token logprob but different lengths
    // must tie under the normalised metric: verify via the formula itself
    let lp_short = -1.2f64; // one token at -1.2
    let lp_long = -2.4f64; // two tokens at -1.2 each
    assert!((lp_short / 1.0 - lp_long / 2.0).abs() < 1e-12);
}
