//! Native-backend unit tests: a hand-computed golden forward, an
//! independent scalar reference implementation, capacity-drop semantics,
//! the shared-expert path and the synthesized-checkpoint HCWT round-trip.
//! No artifacts or PJRT anywhere.

use std::collections::BTreeMap;

use hc_smoe::backend::native::{forward_logits, forward_logits_with, NativeBackend};
use hc_smoe::backend::{Backend, PrefillOpts};
use hc_smoe::config::ModelCfg;
use hc_smoe::pipeline::{quantize_expert_weights, MASK_OFF};
use hc_smoe::tensor::Tensor;
use hc_smoe::weights::Weights;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        name: "tiny".into(),
        n_layer: 1,
        d: 2,
        m: 2,
        n_exp: 2,
        k: 1,
        heads: 1,
        vocab: 3,
        t_max: 4,
        shared: false,
        m_shared: 2,
        cap_factor: 10.0,
        block_c: 1,
    }
}

/// Weights for [`tiny_cfg`] with zero attention and (at scale 0) zero
/// experts: the model reduces to
/// `logits = rmsnorm(embed[ids] + pos) @ embedᵀ`, computable by hand.
fn tiny_weights(expert0_scale: f32, router0: f32) -> Weights {
    let mut map = BTreeMap::new();
    map.insert(
        "embed".to_string(),
        Tensor::new(vec![3, 2], vec![1., 0., 0., 1., 1., 1.]).unwrap(),
    );
    map.insert("pos".to_string(), Tensor::zeros(vec![4, 2]));
    map.insert("ln_f".to_string(), Tensor::full(vec![2], 1.0));
    map.insert("layer00.ln1".to_string(), Tensor::full(vec![2], 1.0));
    map.insert("layer00.ln2".to_string(), Tensor::full(vec![2], 1.0));
    for wname in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
        map.insert(format!("layer00.{wname}"), Tensor::zeros(vec![2, 2]));
    }
    // router column 0 scores `router0 * (x0 + x1)`, column 1 the negation:
    // non-negative inputs always route to expert 0 when router0 > 0.
    map.insert(
        "layer00.router".to_string(),
        Tensor::new(vec![2, 2], vec![router0, -router0, router0, -router0]).unwrap(),
    );
    // expert 0: scaled-identity gate/up, identity down; expert 1: zeros
    let mut wg = vec![0f32; 2 * 2 * 2];
    wg[0] = expert0_scale; // e0 [ [s,0], [0,s] ]
    wg[3] = expert0_scale;
    map.insert("layer00.exp.wg".to_string(), Tensor::new(vec![2, 2, 2], wg.clone()).unwrap());
    map.insert("layer00.exp.wu".to_string(), Tensor::new(vec![2, 2, 2], wg).unwrap());
    let mut wd = vec![0f32; 2 * 2 * 2];
    wd[0] = 1.0;
    wd[3] = 1.0;
    map.insert("layer00.exp.wd".to_string(), Tensor::new(vec![2, 2, 2], wd).unwrap());
    Weights::new(map)
}

#[test]
fn golden_forward_hand_computed() {
    // zero attention + zero experts: h = embed[ids], then one final
    // rmsnorm and the weight-tied head. For id 0 (h = [1, 0]):
    //   rmsnorm: mean(x²) = 0.5 -> scale = √2, hn = [√2, 0]
    //   logits  = [hn·[1,0], hn·[0,1], hn·[1,1]] = [√2, 0, √2]
    // For id 2 (h = [1, 1]): hn = [1, 1], logits = [1, 1, 2].
    let cfg = tiny_cfg();
    let w = tiny_weights(0.0, 1.0);
    let out = forward_logits(&cfg, &w, &[0, 2], 1, 2).unwrap();
    assert_eq!(out.shape(), &[1, 2, 3]);
    let sqrt2 = std::f32::consts::SQRT_2;
    let expect = [sqrt2, 0.0, sqrt2, 1.0, 1.0, 2.0];
    for (got, want) in out.data().iter().zip(expect) {
        assert!((got - want).abs() < 1e-3, "got {got}, want {want}");
    }
}

#[test]
fn capacity_drops_tokens_beyond_queue_limit() {
    // Every token routes to expert 0 (router0 > 0). With cap_factor 0.26,
    // capacity(T=4, n=2) = 1: only the first token reaches the expert,
    // the rest are dropped (y = 0 for them). With cap_factor 10 nothing
    // drops — so position 0 agrees between the runs and later positions
    // that got expert output in the roomy run differ.
    let roomy_cfg = tiny_cfg();
    let tight_cfg = ModelCfg { cap_factor: 0.26, ..tiny_cfg() };
    assert_eq!(tight_cfg.capacity(4, 2), 1);
    let w = tiny_weights(10.0, 5.0);
    let ids = [0, 1, 2, 0];
    let roomy = forward_logits(&roomy_cfg, &w, &ids, 1, 4).unwrap();
    let tight = forward_logits(&tight_cfg, &w, &ids, 1, 4).unwrap();
    let v = 3usize;
    assert_eq!(&roomy.data()[..v], &tight.data()[..v], "token 0 is kept in both");
    assert_ne!(&roomy.data()[v..], &tight.data()[v..], "dropped tokens must change logits");
}

#[test]
fn router_mask_reroutes_to_surviving_expert() {
    let cfg = tiny_cfg();
    let w = tiny_weights(10.0, 5.0);
    let ids = [0, 1, 2, 0];
    let open = forward_logits(&cfg, &w, &ids, 1, 4).unwrap();
    // masking expert 0 forces all tokens onto (zero) expert 1
    let mask = vec![MASK_OFF, 0.0];
    let masked =
        forward_logits_with(&cfg, &w, &ids, 1, 4, &mask, None, cfg.n_exp, 1).unwrap();
    assert_ne!(open.data(), masked.data());
    // with expert 0 masked the MoE contributes nothing, so the result
    // equals the zero-expert golden model
    let w0 = tiny_weights(0.0, 5.0);
    let golden = forward_logits(&cfg, &w0, &ids, 1, 4).unwrap();
    for (a, b) in masked.data().iter().zip(golden.data()) {
        assert!((a - b).abs() < 1e-6);
    }
}

// ---------------------------------------------------------------------------
// Independent scalar reference
// ---------------------------------------------------------------------------

/// A from-scratch scalar implementation of the forward semantics of
/// `python/compile/model.py` (full-matrix causal softmax, dense per-token
/// routing, the same token-major capacity queue), sharing no code with
/// the backend under test.
fn scalar_forward(cfg: &ModelCfg, w: &Weights, ids: &[i32], b: usize, t: usize) -> Vec<f32> {
    let d = cfg.d;
    let (n, k) = (cfg.n_exp, cfg.k);
    let get = |name: &str| w.get(name).unwrap().data().to_vec();
    let embed = get("embed");
    let pos = get("pos");
    let rms = |x: &[f32], g: &[f32]| -> Vec<f32> {
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + 1e-6).sqrt();
        (0..d).map(|j| x[j] * g[j] * s).collect()
    };
    let matvec = |x: &[f32], mat: &[f32], rows: usize, cols: usize| -> Vec<f32> {
        let mut out = vec![0f32; cols];
        for i in 0..rows {
            for j in 0..cols {
                out[j] += x[i] * mat[i * cols + j];
            }
        }
        out
    };
    let mut h: Vec<Vec<f32>> = ids
        .iter()
        .enumerate()
        .map(|(i, &id)| {
            (0..d)
                .map(|j| embed[id as usize * d + j] + pos[(i % t) * d + j])
                .collect()
        })
        .collect();
    for l in 0..cfg.n_layer {
        let pre = format!("layer{l:02}.");
        let ln1 = get(&format!("{pre}ln1"));
        let ln2 = get(&format!("{pre}ln2"));
        let (wq, wk, wv, wo) = (
            get(&format!("{pre}attn.wq")),
            get(&format!("{pre}attn.wk")),
            get(&format!("{pre}attn.wv")),
            get(&format!("{pre}attn.wo")),
        );
        // attention per sequence, full-matrix softmax with -1e30 masking
        let hd = d / cfg.heads;
        for s in 0..b {
            let x1: Vec<Vec<f32>> =
                (0..t).map(|i| rms(&h[s * t + i], &ln1)).collect();
            let q: Vec<Vec<f32>> = x1.iter().map(|x| matvec(x, &wq, d, d)).collect();
            let kk: Vec<Vec<f32>> = x1.iter().map(|x| matvec(x, &wk, d, d)).collect();
            let vv: Vec<Vec<f32>> = x1.iter().map(|x| matvec(x, &wv, d, d)).collect();
            for i in 0..t {
                let mut ctx = vec![0f32; d];
                for head in 0..cfg.heads {
                    let off = head * hd;
                    let mut scores = vec![-1e30f32; t];
                    for j in 0..t {
                        if j <= i {
                            let mut sc = 0f32;
                            for u in 0..hd {
                                sc += q[i][off + u] * kk[j][off + u];
                            }
                            scores[j] = sc / (hd as f32).sqrt();
                        }
                    }
                    let mx = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let exps: Vec<f32> = scores.iter().map(|s| (s - mx).exp()).collect();
                    let z: f32 = exps.iter().sum();
                    for j in 0..t {
                        for u in 0..hd {
                            ctx[off + u] += exps[j] / z * vv[j][off + u];
                        }
                    }
                }
                let o = matvec(&ctx, &wo, d, d);
                for j in 0..d {
                    h[s * t + i][j] += o[j];
                }
            }
        }
        // MoE with token-major capacity queue
        let router = get(&format!("{pre}router"));
        let (wg, wu, wd) = (
            get(&format!("{pre}exp.wg")),
            get(&format!("{pre}exp.wu")),
            get(&format!("{pre}exp.wd")),
        );
        let m = cfg.m;
        let tok = b * t;
        let hf: Vec<Vec<f32>> = (0..tok).map(|i| rms(&h[i], &ln2)).collect();
        let cap = cfg.capacity(tok, n);
        let mut queue = vec![0usize; n];
        let mut y = vec![vec![0f32; d]; tok];
        for ti in 0..tok {
            let logits = matvec(&hf[ti], &router, d, n);
            // top-k: k rounds of first-wins argmax
            let mut work = logits.clone();
            let mut picks = Vec::new();
            for _ in 0..k {
                let mut best = 0usize;
                for e in 1..n {
                    if work[e] > work[best] {
                        best = e;
                    }
                }
                picks.push((best, logits[best]));
                work[best] = f32::NEG_INFINITY;
            }
            let mx = picks.iter().map(|p| p.1).fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = picks.iter().map(|p| (p.1 - mx).exp()).sum();
            for &(e, lv) in &picks {
                let p = (lv - mx).exp() / z;
                let pos_in_q = queue[e];
                queue[e] += 1;
                if pos_in_q >= cap {
                    continue;
                }
                // swiglu of expert e
                let we = &wg[e * d * m..(e + 1) * d * m];
                let ue = &wu[e * d * m..(e + 1) * d * m];
                let de = &wd[e * m * d..(e + 1) * m * d];
                let g = matvec(&hf[ti], we, d, m);
                let u = matvec(&hf[ti], ue, d, m);
                let act: Vec<f32> = (0..m)
                    .map(|j| g[j] / (1.0 + (-g[j]).exp()) * u[j])
                    .collect();
                let out = matvec(&act, de, m, d);
                for j in 0..d {
                    y[ti][j] += p * out[j];
                }
            }
        }
        if cfg.shared {
            let (sg, su, sd) = (
                get(&format!("{pre}shared.wg")),
                get(&format!("{pre}shared.wu")),
                get(&format!("{pre}shared.wd")),
            );
            let ms = cfg.m_shared;
            for ti in 0..tok {
                let g = matvec(&hf[ti], &sg, d, ms);
                let u = matvec(&hf[ti], &su, d, ms);
                let act: Vec<f32> = (0..ms)
                    .map(|j| g[j] / (1.0 + (-g[j]).exp()) * u[j])
                    .collect();
                let out = matvec(&act, &sd, ms, d);
                for j in 0..d {
                    y[ti][j] += out[j];
                }
            }
        }
        for ti in 0..tok {
            for j in 0..d {
                h[ti][j] += y[ti][j];
            }
        }
    }
    let ln_f = get("ln_f");
    let mut logits = Vec::with_capacity(b * t * cfg.vocab);
    for row in &h {
        let hn = rms(row, &ln_f);
        for v in 0..cfg.vocab {
            let mut s = 0f32;
            for j in 0..d {
                s += hn[j] * embed[v * d + j];
            }
            logits.push(s);
        }
    }
    logits
}

#[test]
fn native_forward_matches_scalar_reference() {
    let cfg = ModelCfg {
        name: "ref".into(),
        n_layer: 2,
        d: 4,
        m: 4,
        n_exp: 3,
        k: 2,
        heads: 2,
        vocab: 7,
        t_max: 8,
        shared: false,
        m_shared: 4,
        cap_factor: 2.0,
        block_c: 2,
    };
    let w = Weights::synthesize(&cfg, 42);
    let (b, t) = (2usize, 5usize);
    let ids: Vec<i32> = (0..b * t).map(|i| ((i * 3 + 1) % 7) as i32).collect();
    let got = forward_logits(&cfg, &w, &ids, b, t).unwrap();
    let want = scalar_forward(&cfg, &w, &ids, b, t);
    assert_eq!(got.len(), want.len());
    for (i, (g, r)) in got.data().iter().zip(&want).enumerate() {
        assert!((g - r).abs() < 1e-3, "logit {i}: native {g} vs reference {r}");
    }
}

#[test]
fn shared_expert_path_matches_scalar_reference() {
    let cfg = ModelCfg {
        name: "dsref".into(),
        n_layer: 1,
        d: 4,
        m: 4,
        n_exp: 2,
        k: 1,
        heads: 2,
        vocab: 7,
        t_max: 8,
        shared: true,
        m_shared: 6,
        cap_factor: 2.0,
        block_c: 2,
    };
    let w = Weights::synthesize(&cfg, 43);
    let ids: Vec<i32> = vec![1, 2, 3, 4];
    let got = forward_logits(&cfg, &w, &ids, 1, 4).unwrap();
    let want = scalar_forward(&cfg, &w, &ids, 1, 4);
    for (g, r) in got.data().iter().zip(&want) {
        assert!((g - r).abs() < 1e-3, "native {g} vs reference {r}");
    }
    // and the shared expert actually contributes: zeroing it changes output
    let mut w0 = w.clone();
    for suffix in ["shared.wg", "shared.wu", "shared.wd"] {
        w0.get_mut(&format!("layer00.{suffix}")).unwrap().scale(0.0);
    }
    let without = forward_logits(&cfg, &w0, &ids, 1, 4).unwrap();
    assert_ne!(got.data(), without.data());
}

#[test]
fn forward_is_bit_identical_across_thread_counts() {
    let cfg = ModelCfg {
        name: "par".into(),
        n_layer: 1,
        d: 8,
        m: 8,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 16,
        t_max: 16,
        shared: false,
        m_shared: 8,
        cap_factor: 2.0,
        block_c: 4,
    };
    let w = Weights::synthesize(&cfg, 11);
    let ids: Vec<i32> = (0..16).map(|i| (i % 16) as i32).collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let serial = forward_logits_with(&cfg, &w, &ids, 1, 16, &mask, None, 4, 1).unwrap();
    for threads in [2usize, 3, 8] {
        let par =
            forward_logits_with(&cfg, &w, &ids, 1, 16, &mask, None, 4, threads).unwrap();
        let same = serial
            .data()
            .iter()
            .zip(par.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "threads={threads}");
    }
}

#[test]
fn synthesized_checkpoint_roundtrips_through_hcwt() {
    let cfg = ModelCfg {
        name: "rt".into(),
        n_layer: 2,
        d: 8,
        m: 8,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 16,
        t_max: 16,
        shared: true,
        m_shared: 8,
        cap_factor: 1.5,
        block_c: 4,
    };
    let w = Weights::synthesize(&cfg, 99);
    assert_eq!(w.n_experts().unwrap(), cfg.n_exp);
    assert_eq!(w.n_layers(), cfg.n_layer);
    let path = std::env::temp_dir().join(format!("hcwt_rt_{}.hcwt", std::process::id()));
    w.save(&path).unwrap();
    let w2 = Weights::load(&path).unwrap();
    assert_eq!(w.len(), w2.len());
    for name in w.names() {
        assert_eq!(w.get(name).unwrap(), w2.get(name).unwrap(), "{name}");
    }
    // byte-for-byte stable on disk as well
    let bytes1 = std::fs::read(&path).unwrap();
    w2.save(&path).unwrap();
    let bytes2 = std::fs::read(&path).unwrap();
    assert_eq!(bytes1, bytes2);
    std::fs::remove_file(&path).ok();
}

fn quant_cfg() -> ModelCfg {
    ModelCfg {
        name: "q8".into(),
        n_layer: 2,
        d: 8,
        m: 8,
        n_exp: 4,
        k: 2,
        heads: 2,
        vocab: 16,
        t_max: 16,
        shared: true,
        m_shared: 8,
        cap_factor: 2.0,
        block_c: 4,
    }
}

#[test]
fn quantized_variant_tracks_f32_forward() {
    let cfg = quant_cfg();
    let w = Weights::synthesize(&cfg, 17);
    let qw = quantize_expert_weights(&w).unwrap();
    let ids: Vec<i32> = (0..12).map(|i| (i % 16) as i32).collect();
    let full = forward_logits(&cfg, &w, &ids, 1, 12).unwrap();
    let quant = forward_logits(&cfg, &qw, &ids, 1, 12).unwrap();
    assert!(quant.data().iter().all(|x| x.is_finite()));
    let max_diff = full
        .data()
        .iter()
        .zip(quant.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-2, "int8 logits drifted {max_diff} from f32");
    // the quantized kernel actually ran: outputs differ in the low bits
    let identical = full
        .data()
        .iter()
        .zip(quant.data())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(!identical, "quantized forward produced bit-identical logits — kernel not engaged");
}

#[test]
fn quantized_forward_is_bit_identical_across_thread_counts() {
    let cfg = quant_cfg();
    let qw = quantize_expert_weights(&Weights::synthesize(&cfg, 18)).unwrap();
    let ids: Vec<i32> = (0..16).map(|i| (i % 16) as i32).collect();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let serial = forward_logits_with(&cfg, &qw, &ids, 1, 16, &mask, None, cfg.n_exp, 1).unwrap();
    for threads in [2usize, 3, 8] {
        let par =
            forward_logits_with(&cfg, &qw, &ids, 1, 16, &mask, None, cfg.n_exp, threads).unwrap();
        let same = serial
            .data()
            .iter()
            .zip(par.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "threads={threads}");
    }
}

#[test]
fn quantized_variant_decodes_through_executor() {
    // an int8 variant must serve through the same prefill/decode executor
    // with the cached-decode == full-forward bit-identity contract intact
    let cfg = quant_cfg();
    let qw = quantize_expert_weights(&Weights::synthesize(&cfg, 19)).unwrap();
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&qw, cfg.n_exp).unwrap();
    let prompt: Vec<i32> = vec![1, 5, 9, 2];
    let (cache, prefill_logits) =
        backend.run_prefill(state.as_ref(), &prompt, PrefillOpts::new(&mask)).unwrap();
    let mut cache = cache.expect("fresh prefill returns a cache");
    // prefill logits == last row of the full forward
    let full = forward_logits(&cfg, &qw, &prompt, 1, prompt.len()).unwrap();
    let last = &full.data()[(prompt.len() - 1) * cfg.vocab..];
    assert!(prefill_logits
        .iter()
        .zip(last)
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    // cached decode == uncached re-forward over the extended sequence
    let next = 7i32;
    let step = backend
        .run_decode(state.as_ref(), cache.as_mut(), next, &mask, None)
        .unwrap();
    let mut extended = prompt.clone();
    extended.push(next);
    let full2 = forward_logits(&cfg, &qw, &extended, 1, extended.len()).unwrap();
    let last2 = &full2.data()[(extended.len() - 1) * cfg.vocab..];
    assert!(step.iter().zip(last2).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn quantized_calibration_is_refused_descriptively() {
    let cfg = quant_cfg();
    let qw = quantize_expert_weights(&Weights::synthesize(&cfg, 20)).unwrap();
    let ids: Vec<i32> = (0..8).map(|i| (i % 16) as i32).collect();
    let err = hc_smoe::backend::native::forward_calib_with(&cfg, &qw, &ids, 1, 8, 4, 2, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("quantized"), "{err}");
}
