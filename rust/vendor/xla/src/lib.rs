//! API-compatible stub of the `xla` PJRT bindings.
//!
//! The offline build environment ships no PJRT plugin or `xla` crate, so
//! this vendored path crate provides the exact type/method surface
//! `hc_smoe::runtime` compiles against. Every operation that would need a
//! real device (HLO parsing, compilation, buffer upload, execution) returns
//! a descriptive [`Error`] at runtime; everything artifact-free (client
//! construction, platform queries) works. Swapping in real PJRT bindings is
//! a `Cargo.toml` path/version change — no `hc_smoe` source edits
//! (see DESIGN.md, "Offline-environment notes").

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` Display surface.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(op: &str) -> Error {
    Error(format!(
        "PJRT backend unavailable: hc_smoe was built against the vendored `xla` stub, \
         which cannot perform `{op}`; link real PJRT bindings to run HLO executables"
    ))
}

/// Element types the runtime inspects on output literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host value types transferable to device buffers.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// PJRT client handle (stub: constructible, cannot compile or upload).
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: "stub-cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (stub: parsing always reports the missing backend).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(Error(format!(
            "cannot parse HLO text {}: hc_smoe was built against the vendored `xla` stub",
            path.as_ref().display()
        )))
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over borrowed argument buffers; mirrors the real signature
    /// returning per-device, per-output buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// Host-side literal (tuple or array) read back from the device.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable("Literal::ty"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Array shape (dims as i64, matching the real bindings).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_and_reports_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
    }

    #[test]
    fn device_operations_error_descriptively() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.buffer_from_host_buffer(&[1.0f32], &[1], None).unwrap_err();
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("missing.hlo.txt").is_err());
    }
}
