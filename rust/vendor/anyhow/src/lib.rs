//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access (see DESIGN.md,
//! "Offline-environment notes"), so this vendored path crate implements
//! exactly the subset `hc_smoe` uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Semantics mirror the real crate closely
//! enough that swapping the registry version back in is a one-line
//! `Cargo.toml` change.

use std::fmt;

/// A context-carrying error: an outermost message plus a chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the same defaulted form as the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Messages from outermost context to innermost cause.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.source;
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the full context chain, like the real crate.
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes = self.chain();
        if causes.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &causes[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut messages = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            messages.push(err.to_string());
            cur = err.source();
        }
        let mut out: Option<Error> = None;
        for msg in messages.into_iter().rev() {
            out = Some(match out {
                None => Error::msg(msg),
                Some(inner) => inner.context(msg),
            });
        }
        out.unwrap_or_else(|| Error::msg("unknown error"))
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: flag was false");
    }

    #[test]
    fn io_error_converts() {
        let r: Result<()> = Err(std::io::Error::new(std::io::ErrorKind::Other, "boom").into());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
