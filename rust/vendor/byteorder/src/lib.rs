//! Minimal offline stand-in for the `byteorder` crate.
//!
//! Implements the subset the HCWT/HCEV/HCTS binary IO paths use:
//! [`LittleEndian`], and the [`ReadBytesExt`] / [`WriteBytesExt`] extension
//! traits with `u32`/`i32`/`f32` accessors (including the bulk
//! `read_*_into` variants). Backed by `from_le_bytes`/`to_le_bytes`, so the
//! on-disk format is identical to the real crate's.

use std::io::{self, Read, Write};

/// Byte-order witness: converts between native values and 4-byte buffers.
pub trait ByteOrder {
    fn u32_from_bytes(b: [u8; 4]) -> u32;
    fn u32_to_bytes(v: u32) -> [u8; 4];
    fn i32_from_bytes(b: [u8; 4]) -> i32;
    fn i32_to_bytes(v: i32) -> [u8; 4];
    fn f32_from_bytes(b: [u8; 4]) -> f32;
    fn f32_to_bytes(v: f32) -> [u8; 4];
}

/// Little-endian byte order (the only one the HC formats use).
pub enum LittleEndian {}

/// Alias matching the real crate.
pub type LE = LittleEndian;

impl ByteOrder for LittleEndian {
    fn u32_from_bytes(b: [u8; 4]) -> u32 {
        u32::from_le_bytes(b)
    }

    fn u32_to_bytes(v: u32) -> [u8; 4] {
        v.to_le_bytes()
    }

    fn i32_from_bytes(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }

    fn i32_to_bytes(v: i32) -> [u8; 4] {
        v.to_le_bytes()
    }

    fn f32_from_bytes(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }

    fn f32_to_bytes(v: f32) -> [u8; 4] {
        v.to_le_bytes()
    }
}

/// Read extension: typed little/big-endian accessors over any `Read`.
pub trait ReadBytesExt: Read {
    fn read_u32<B: ByteOrder>(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(B::u32_from_bytes(b))
    }

    fn read_i32<B: ByteOrder>(&mut self) -> io::Result<i32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(B::i32_from_bytes(b))
    }

    fn read_f32<B: ByteOrder>(&mut self) -> io::Result<f32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(B::f32_from_bytes(b))
    }

    fn read_i32_into<B: ByteOrder>(&mut self, dst: &mut [i32]) -> io::Result<()> {
        for d in dst.iter_mut() {
            *d = self.read_i32::<B>()?;
        }
        Ok(())
    }

    fn read_f32_into<B: ByteOrder>(&mut self, dst: &mut [f32]) -> io::Result<()> {
        for d in dst.iter_mut() {
            *d = self.read_f32::<B>()?;
        }
        Ok(())
    }
}

impl<R: Read + ?Sized> ReadBytesExt for R {}

/// Write extension: typed little/big-endian writers over any `Write`.
pub trait WriteBytesExt: Write {
    fn write_u32<B: ByteOrder>(&mut self, v: u32) -> io::Result<()> {
        self.write_all(&B::u32_to_bytes(v))
    }

    fn write_i32<B: ByteOrder>(&mut self, v: i32) -> io::Result<()> {
        self.write_all(&B::i32_to_bytes(v))
    }

    fn write_f32<B: ByteOrder>(&mut self, v: f32) -> io::Result<()> {
        self.write_all(&B::f32_to_bytes(v))
    }
}

impl<W: Write + ?Sized> WriteBytesExt for W {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut buf = Vec::new();
        buf.write_u32::<LittleEndian>(0xDEAD_BEEF).unwrap();
        buf.write_i32::<LittleEndian>(-42).unwrap();
        buf.write_f32::<LittleEndian>(1.5).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(r.read_u32::<LittleEndian>().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.read_i32::<LittleEndian>().unwrap(), -42);
        assert_eq!(r.read_f32::<LittleEndian>().unwrap(), 1.5);
    }

    #[test]
    fn bulk_reads() {
        let mut buf = Vec::new();
        for i in 0..4 {
            buf.write_i32::<LittleEndian>(i).unwrap();
        }
        let mut r = std::io::Cursor::new(buf);
        let mut out = [0i32; 4];
        r.read_i32_into::<LittleEndian>(&mut out).unwrap();
        assert_eq!(out, [0, 1, 2, 3]);
    }
}
