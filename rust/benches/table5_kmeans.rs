//! Table 5: K-means (fixed/random init × metric) vs HC on qwensim at 50%
//! reduction — the initialisation-sensitivity comparison.

use hc_smoe::bench_support::{push_row, task_table, Lab, ABLATION_TASKS};
use hc_smoe::clustering::{KmeansInit, Linkage};
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let r = 8; // 50% reduction (paper: Qwen 30x)
    let mut table =
        task_table("Table 5 analog — K-means vs HC (qwensim r=8)", &ABLATION_TASKS);
    for (name, init) in [("K-fix", KmeansInit::Fixed), ("K-rnd", KmeansInit::Random { seed: 7 })] {
        for metric in [Metric::RouterLogits, Metric::Weight, Metric::ExpertOutput] {
            let method = Method::KMeans { init, metric, merge: MergeStrategy::Frequency };
            let label = format!("{name}({})", metric.short());
            let (scores, avg) = lab.eval_method(method, r, "general", &ABLATION_TASKS)?;
            push_row(&mut table, &label, r, &scores, avg);
        }
    }
    // K-rnd instability: a second seed (paper §4.3 "initialisation sensitivity")
    let method = Method::KMeans {
        init: KmeansInit::Random { seed: 1234 },
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    };
    let (scores, avg) = lab.eval_method(method, r, "general", &ABLATION_TASKS)?;
    push_row(&mut table, "K-rnd(eo,seed2)", r, &scores, avg);
    let hc = Method::HcSmoe {
        linkage: Linkage::Average,
        metric: Metric::ExpertOutput,
        merge: MergeStrategy::Frequency,
    };
    let (scores, avg) = lab.eval_method(hc, r, "general", &ABLATION_TASKS)?;
    push_row(&mut table, "HC(eo)", r, &scores, avg);
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
