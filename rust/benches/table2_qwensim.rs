//! Table 2: zero-shot comparison on the Qwen analog (qwensim, n=16) —
//! original vs all methods at 25% (r=12) and 50% (r=8) expert reduction.

use hc_smoe::bench_support::{self, paper_methods, push_row, task_table, Lab, PAPER_TASKS};

fn main() -> anyhow::Result<()> {
    if bench_support::smoke() {
        // CI bench-smoke job: exercise the harness without artifacts.
        return bench_support::run_smoke("table2_qwensim");
    }
    let lab = Lab::new("qwensim")?;
    let mut table = task_table(
        "Table 2 analog — qwensim (n=16), C4-analog calibration",
        &PAPER_TASKS,
    );
    let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
    push_row(&mut table, "None", 16, &scores, avg);
    for &r in &[12usize, 8] {
        for method in paper_methods(lab.ctx.cfg.n_exp, r) {
            let label = method.label();
            let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, &label, r, &scores, avg);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
