//! Tables 18-19 (Appendix B.6): extreme reduction (62.5% and 75%) where
//! pruning baselines collapse toward/below chance while HC-SMoE keeps
//! signal, plus per-method compression runtimes (Table 19's Time column).

use std::time::Instant;

use hc_smoe::bench_support::{task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    for (model, rs) in [("qwensim", [6usize, 4]), ("mixsim", [3, 2])] {
        let lab = Lab::new(model)?;
        let mut table = task_table(
            &format!("Tables 18-19 analog — extreme reduction ({model})"),
            &PAPER_TASKS,
        );
        let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
        let mut cells = vec!["None".to_string(), lab.ctx.cfg.n_exp.to_string()];
        cells.extend(scores.iter().map(|s| format!("{s:.4}")));
        cells.push(format!("{avg:.4}"));
        table.row(cells);
        for r in rs {
            let mut methods: Vec<(String, Method)> = vec![
                ("F-prune".into(), Method::FPrune),
                ("S-prune".into(), Method::SPrune),
                ("MC-SMoE".into(), Method::MSmoe),
                (
                    "HC-SMoE (ours)".into(),
                    Method::HcSmoe {
                        linkage: Linkage::Average,
                        metric: Metric::ExpertOutput,
                        merge: MergeStrategy::Frequency,
                    },
                ),
            ];
            // O-prune is feasible on the small expert count (Table 19 runs it
            // on Mixtral but skips Qwen's search space)
            if lab.ctx.cfg.n_exp <= 8 {
                methods.insert(0, ("O-prune".into(), Method::OPrune { samples: 20_000, seed: 42 }));
            }
            for (name, method) in methods {
                let t0 = Instant::now();
                let _ = lab.compress(method.clone(), r, "general")?;
                let secs = t0.elapsed().as_secs_f64();
                let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
                let mut cells = vec![format!("{name} [{secs:.2}s]"), r.to_string()];
                cells.extend(scores.iter().map(|s| format!("{s:.4}")));
                cells.push(format!("{avg:.4}"));
                table.row(cells);
            }
        }
        table.print();
        table.append_to("bench_results.md")?;
        println!("(chance floors: 0.25 on 4-way tasks, 0.5 on binary tasks)");
    }
    Ok(())
}
