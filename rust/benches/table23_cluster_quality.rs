//! Table 23 (Appendix D): cluster quality — last-layer output L2 error and
//! cosine similarity vs the original model, plus Silhouette and Dunn index
//! (Euclidean + cosine) for HC vs K-means under each similarity metric.

use hc_smoe::bench_support::Lab;
use hc_smoe::clustering::{hierarchical, kmeans, KmeansInit, Linkage};
use hc_smoe::data::TokenStream;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::quality::{dunn_index, output_fidelity, silhouette};
use hc_smoe::report::Table;
use hc_smoe::similarity::{distance_matrix, features, Distance, Metric};

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let stream = TokenStream::load(lab.ctx.arts.calib_tokens_path("ppl_heldout"))?;
    let orig = lab.ctx.load_original()?;
    for r in [12usize, 8] {
        let mut table = Table::new(
            &format!("Table 23 analog — cluster quality (qwensim r={r})"),
            &["Cluster", "Metric", "L2 error", "CosSim", "Sil-Euc", "Dunn-Euc", "Sil-Cos", "Dunn-Cos"],
        );
        let stats = lab.stats("general")?;
        for metric in [Metric::ExpertOutput, Metric::Weight, Metric::RouterLogits] {
            for clusterer in ["HC", "Kmeans"] {
                // intrinsic quality: mean over layers
                let mut sil_e = 0.0;
                let mut dunn_e = 0.0;
                let mut sil_c = 0.0;
                let mut dunn_c = 0.0;
                for l in 0..lab.ctx.cfg.n_layer {
                    let feats = features(metric, &lab.ctx.base, &stats.layers[l], l)?;
                    let assign = if clusterer == "HC" {
                        let d = distance_matrix(&feats, Distance::Euclidean);
                        hierarchical(&d, r, Linkage::Average).assign
                    } else {
                        kmeans(&feats, r, KmeansInit::Random { seed: 5 }, 100).assign
                    };
                    sil_e += silhouette(&feats, &assign, r, Distance::Euclidean);
                    dunn_e += dunn_index(&feats, &assign, r, Distance::Euclidean);
                    sil_c += silhouette(&feats, &assign, r, Distance::Cosine);
                    dunn_c += dunn_index(&feats, &assign, r, Distance::Cosine);
                }
                let nl = lab.ctx.cfg.n_layer as f64;
                // output fidelity of the resulting merged model
                let method = if clusterer == "HC" {
                    Method::HcSmoe {
                        linkage: Linkage::Average,
                        metric,
                        merge: MergeStrategy::Frequency,
                    }
                } else {
                    Method::KMeans {
                        init: KmeansInit::Random { seed: 5 },
                        metric,
                        merge: MergeStrategy::Frequency,
                    }
                };
                let cm = lab.compress(method, r, "general")?;
                let loaded = cm.load(&lab.ctx)?;
                let (l2, cos) = output_fidelity(&lab.ctx, &orig, &loaded, &stream, 2)?;
                table.row(vec![
                    clusterer.to_string(),
                    metric.short().to_string(),
                    format!("{l2:.1}"),
                    format!("{cos:.4}"),
                    format!("{:.4}", sil_e / nl),
                    format!("{:.4}", dunn_e / nl),
                    format!("{:.4}", sil_c / nl),
                    format!("{:.4}", dunn_c / nl),
                ]);
            }
        }
        table.print();
        table.append_to("bench_results.md")?;
    }
    Ok(())
}
