//! Table 6: single-shot (M-SMoE-style one-pass) grouping under each
//! similarity metric vs HC-SMoE on mixsim at 25% and 50% reduction.

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let mut table =
        task_table("Table 6 analog — single-shot vs HC (mixsim)", &PAPER_TASKS);
    let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
    push_row(&mut table, "None", 8, &scores, avg);
    for &r in &[6usize, 4] {
        for metric in [Metric::RouterLogits, Metric::Weight, Metric::ExpertOutput] {
            let method = Method::SingleShot { metric, merge: MergeStrategy::Frequency };
            let label = format!("single-shot({})", metric.short());
            let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, &label, r, &scores, avg);
        }
        let hc = Method::HcSmoe {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: MergeStrategy::Frequency,
        };
        let (scores, avg) = lab.eval_method(hc, r, "general", &PAPER_TASKS)?;
        push_row(&mut table, "HC-SMoE", r, &scores, avg);
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
