//! Table 9 (Appendix B.2): ZipIt vs Fix-Dom merging under act / weight /
//! act+weight features on mixsim at 50% reduction, with the merge runtime
//! that motivates Fix-Dom (the paper reports >100x).

use std::time::Instant;

use hc_smoe::bench_support::{task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::{FixDomFeature, MergeStrategy};
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let r = 4;
    let mut table = task_table("Table 9 analog — ZipIt vs Fix-Dom (mixsim r=4)", &PAPER_TASKS);
    // extra column for merge runtime: append to the label instead
    for feature in [FixDomFeature::Act, FixDomFeature::Weight, FixDomFeature::ActWeight] {
        for (name, merge) in [
            ("zipit", MergeStrategy::ZipIt(feature)),
            ("Fix-Dom", MergeStrategy::FixDom(feature)),
        ] {
            let method = Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge,
            };
            // time the merge (plan+apply) separately from cached eval
            let t0 = Instant::now();
            let _ = lab.compress(method.clone(), r, "general")?;
            let merge_s = t0.elapsed().as_secs_f64();
            let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
            let mut cells =
                vec![format!("{name}({})", feature.short()), format!("{merge_s:.2}s")];
            cells.extend(scores.iter().map(|s| format!("{s:.4}")));
            cells.push(format!("{avg:.4}"));
            table.row(cells);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
