//! Figures 6-13 (Appendix E): expert activation frequency analysis across
//! benchmark-task token streams vs the C4-analog — the evidence that
//! frequency is task-dependent and hence an unreliable retention criterion.

use hc_smoe::bench_support::Lab;
use hc_smoe::calib::CalibStats;
use hc_smoe::data::TokenStream;
use hc_smoe::report::Table;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let n = lab.ctx.cfg.n_exp;
    let streams: Vec<String> = std::iter::once("general".to_string())
        .chain(lab.ctx.manifest.tasks.iter().map(|t| format!("task_{t}")))
        .collect();
    for layer in [0usize, lab.ctx.cfg.n_layer - 1] {
        let mut headers = vec!["Stream".to_string()];
        headers.extend((0..n).map(|e| format!("E{e}")));
        let mut table = Table::new(
            &format!("Figures 6-13 analog — activation frequency, mixsim layer {layer}"),
            &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        let mut per_stream: Vec<Vec<f64>> = Vec::new();
        for stream_name in &streams {
            let ts = TokenStream::load(lab.ctx.arts.calib_tokens_path(stream_name))?;
            let stats = CalibStats::collect(&lab.ctx, &ts)?;
            let counts = &stats.layers[layer].counts;
            let total: f32 = counts.iter().sum();
            let freqs: Vec<f64> = counts.iter().map(|&c| (c / total) as f64).collect();
            let mut cells = vec![stream_name.clone()];
            cells.extend(freqs.iter().map(|f| format!("{f:.3}")));
            table.row(cells);
            per_stream.push(freqs);
        }
        table.print();
        table.append_to("bench_results.md")?;
        // the paper's point: the frequency ranking varies across tasks
        let rank_of = |f: &Vec<f64>| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| f[b].partial_cmp(&f[a]).unwrap());
            idx
        };
        let base_rank = rank_of(&per_stream[0]);
        let mut disagreements = 0;
        for f in &per_stream[1..] {
            if rank_of(f)[0] != base_rank[0] {
                disagreements += 1;
            }
        }
        println!(
            "layer {layer}: top-expert disagrees with the C4-analog on \
             {disagreements}/{} task streams",
            per_stream.len() - 1
        );
    }
    Ok(())
}
