//! Figure 1: average accuracy vs expert-parameter reduction rate on the
//! Qwen analog — HC-SMoE against the pruning/merging baselines at 25%,
//! 37.5%, 50%, 62.5% and 75% reduction (rows shared with Tables 2/18 via
//! the results cache).

use hc_smoe::bench_support::{Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::report::Table;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let reductions: Vec<(usize, &str)> =
        vec![(12, "25%"), (10, "37.5%"), (8, "50%"), (6, "62.5%"), (4, "75%")];
    let methods: Vec<(&str, Method)> = vec![
        (
            "HC-SMoE",
            Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge: MergeStrategy::Frequency,
            },
        ),
        ("F-prune", Method::FPrune),
        ("S-prune", Method::SPrune),
        ("M-SMoE", Method::MSmoe),
    ];
    let mut headers = vec!["Method".to_string(), "0%".to_string()];
    headers.extend(reductions.iter().map(|(_, p)| p.to_string()));
    let mut table = Table::new(
        "Figure 1 analog — average accuracy vs expert reduction (qwensim)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let (_, orig_avg) = lab.eval_original(&PAPER_TASKS)?;
    for (name, method) in methods {
        let mut cells = vec![name.to_string(), format!("{orig_avg:.4}")];
        for &(r, _) in &reductions {
            let (_, avg) = lab.eval_method(method.clone(), r, "general", &PAPER_TASKS)?;
            cells.push(format!("{avg:.4}"));
        }
        table.row(cells);
    }
    table.print();
    // ascii curve for the figure
    println!("\n(star = original at {orig_avg:.3})");
    table.append_to("bench_results.md")?;
    Ok(())
}
