//! Performance microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! Part 1 needs no artifacts: the serial-vs-parallel hot-path sweep at
//! E ∈ {8, 16, 64} experts (distance matrix, linkage scan, blocked matmul),
//! emitting the machine-readable `BENCH_parallel.json` that tracks the
//! perf trajectory PR over PR.
//!
//! Part 2, also artifact-free: native-backend inference throughput
//! (tokens/s of the scoring forward and the dense calibration pass,
//! serial vs parallel) on synthesized checkpoints, emitting
//! `BENCH_backend.json`.
//!
//! Part 3 — end-to-end execution latency, variant-load overhead, the full
//! compression pipeline and the serving batcher — runs on the discovered
//! artifact set (real AOT output when present, else the synthesized
//! offline set).

use std::time::{Duration, Instant};

use hc_smoe::backend::native::{forward_calib_with, forward_logits_with, NativeBackend};
use hc_smoe::backend::{Backend, KvCache, PrefillOpts};
use hc_smoe::bench_support::{
    self, AdaptBenchRow, BackendBenchRow, DecodeBatchRow, GenerateBenchRow, KvCacheBenchRow,
    Lab, ParallelBenchRow, QuantGemmRow, SchedBenchRow, SpecDecodeRow,
};
use hc_smoe::clustering::{hierarchical, hierarchical_with, kmeans, KmeansInit, Linkage};
use hc_smoe::config::ModelCfg;
use hc_smoe::generate::{generate, speculative, SamplingParams};
use hc_smoe::kvpool::{KvPool, PoolHandle, DEFAULT_BLOCK_TOKENS};
use hc_smoe::report::Table;
use hc_smoe::serving::{serve, BatcherConfig, Priority, ServeSpec};
use hc_smoe::similarity::{
    distance_matrix_serial, distance_matrix_with, features, Distance, Metric,
};
use hc_smoe::tensor::{
    matmul, matmul_blocked_with, matmul_q8_with, matmul_reference, quantize_rows_i8,
};
use hc_smoe::util::{bench_median, Rng};
use hc_smoe::weights::Weights;

const BENCH_JSON: &str = "BENCH_parallel.json";
const BACKEND_JSON: &str = "BENCH_backend.json";
const GENERATE_JSON: &str = "BENCH_generate.json";

fn synthetic_feats(e: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..e)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect()
}

/// Serial-vs-parallel sweep over expert counts; returns the JSON rows.
fn parallel_sweep(threads: usize, table: &mut Table) -> Vec<ParallelBenchRow> {
    let mut rows = Vec::new();
    // Feature length of the expert-output metric at production scale
    // (d_model of the larger analogs; gives the O(E²·d) sweep real work).
    let d_feat = 2048usize;
    let smoke = bench_support::smoke();
    let (warmup, iters) = if smoke { (0, 1) } else { (3, 15) };
    for &e in &[8usize, 16, 64] {
        let feats = synthetic_feats(e, d_feat, 0xC0FFEE + e as u64);
        let serial = bench_median(warmup, iters, || {
            std::hint::black_box(distance_matrix_serial(&feats, Distance::Euclidean));
        });
        let par = bench_median(warmup, iters, || {
            std::hint::black_box(distance_matrix_with(&feats, Distance::Euclidean, threads));
        });
        table.row(vec![
            format!("distance_matrix E={e}"),
            format!("{:.3}", serial.median_s * 1e3),
            format!("{:.3}", par.median_s * 1e3),
            format!("{:.2}x", serial.median_s / par.median_s.max(1e-12)),
        ]);
        rows.push(ParallelBenchRow {
            path: "distance_matrix".into(),
            n_experts: e,
            serial_ms: serial.median_s * 1e3,
            parallel_ms: par.median_s * 1e3,
        });

        // linkage scan: full agglomeration E -> E/4 on the same features.
        // The parallel column is the AUTO dispatch: at paper scales the scan
        // is µs-sized and the work gate keeps it serial (a per-merge-step
        // spawn was measured at a 25x slowdown at E=64), so ~1.0x here is
        // the gate doing its job; the scan parallelises from ~1450 clusters.
        let dist = distance_matrix_serial(&feats, Distance::Euclidean);
        let r = (e / 4).max(1);
        let serial = bench_median(warmup, iters, || {
            std::hint::black_box(hierarchical_with(&dist, r, Linkage::Average, 1));
        });
        let par = bench_median(warmup, iters, || {
            std::hint::black_box(hierarchical(&dist, r, Linkage::Average));
        });
        table.row(vec![
            format!("linkage_scan(auto) E={e}"),
            format!("{:.3}", serial.median_s * 1e3),
            format!("{:.3}", par.median_s * 1e3),
            format!("{:.2}x", serial.median_s / par.median_s.max(1e-12)),
        ]);
        rows.push(ParallelBenchRow {
            path: "linkage_scan_auto".into(),
            n_experts: e,
            serial_ms: serial.median_s * 1e3,
            parallel_ms: par.median_s * 1e3,
        });

        // blocked matmul at the ZipIt correlation shape: [E*m, t] x [t, E*m]
        let em = (e * 16).min(512);
        let t_feat = 128;
        let mut rng = Rng::new(0xBEEF + e as u64);
        let a: Vec<f32> = (0..em * t_feat).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..t_feat * em).map(|_| rng.normal() as f32).collect();
        let serial = bench_median(warmup, iters, || {
            std::hint::black_box(matmul(&a, &b, em, t_feat, em));
        });
        let par = bench_median(warmup, iters, || {
            std::hint::black_box(matmul_blocked_with(&a, &b, em, t_feat, em, threads));
        });
        table.row(vec![
            format!("matmul {em}x{t_feat}x{em}"),
            format!("{:.3}", serial.median_s * 1e3),
            format!("{:.3}", par.median_s * 1e3),
            format!("{:.2}x", serial.median_s / par.median_s.max(1e-12)),
        ]);
        rows.push(ParallelBenchRow {
            path: "matmul".into(),
            n_experts: e,
            serial_ms: serial.median_s * 1e3,
            parallel_ms: par.median_s * 1e3,
        });
    }
    rows
}

/// Toy model config for the artifact-free native-backend throughput sweep.
fn backend_cfg(n_exp: usize) -> ModelCfg {
    ModelCfg {
        name: format!("bench{n_exp}"),
        n_layer: 2,
        d: 64,
        m: 64,
        n_exp,
        k: 2,
        heads: 4,
        vocab: 256,
        t_max: 64,
        shared: false,
        m_shared: 64,
        cap_factor: 1.5,
        block_c: 8,
    }
}

/// Native-backend tokens/s, serial vs parallel -> `BENCH_backend.json`.
fn backend_sweep(threads: usize, table: &mut Table) -> Vec<BackendBenchRow> {
    let smoke = bench_support::smoke();
    let (warmup, iters) = if smoke { (0, 1) } else { (2, 9) };
    let (b, t) = (4usize, 64usize);
    let tokens = b * t;
    let mut rows = Vec::new();
    for &e in &[8usize, 16] {
        let cfg = backend_cfg(e);
        let w = Weights::synthesize(&cfg, 0xBACC + e as u64);
        let ids: Vec<i32> = (0..tokens).map(|i| (i % cfg.vocab) as i32).collect();
        let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
        let serial = bench_median(warmup, iters, || {
            std::hint::black_box(
                forward_logits_with(&cfg, &w, &ids, b, t, &mask, None, e, 1).unwrap(),
            );
        });
        let par = bench_median(warmup, iters, || {
            std::hint::black_box(
                forward_logits_with(&cfg, &w, &ids, b, t, &mask, None, e, threads).unwrap(),
            );
        });
        table.row(vec![
            format!("forward_logits E={e} ({tokens} tok)"),
            format!("{:.3}", serial.median_s * 1e3),
            format!("{:.3}", par.median_s * 1e3),
            format!("{:.0} tok/s", tokens as f64 / par.median_s.max(1e-12)),
        ]);
        rows.push(BackendBenchRow {
            path: "forward_logits".into(),
            n_experts: e,
            tokens,
            serial_ms: serial.median_s * 1e3,
            parallel_ms: par.median_s * 1e3,
        });
    }
    // the dense calibration pass (every expert on every token)
    let cfg = backend_cfg(8);
    let w = Weights::synthesize(&cfg, 0xCA11B);
    let ids: Vec<i32> = (0..tokens).map(|i| (i % cfg.vocab) as i32).collect();
    let serial = bench_median(warmup, iters, || {
        std::hint::black_box(forward_calib_with(&cfg, &w, &ids, b, t, 64, 32, 1).unwrap());
    });
    let par = bench_median(warmup, iters, || {
        std::hint::black_box(forward_calib_with(&cfg, &w, &ids, b, t, 64, 32, threads).unwrap());
    });
    table.row(vec![
        format!("forward_calib E=8 ({tokens} tok)"),
        format!("{:.3}", serial.median_s * 1e3),
        format!("{:.3}", par.median_s * 1e3),
        format!("{:.0} tok/s", tokens as f64 / par.median_s.max(1e-12)),
    ]);
    rows.push(BackendBenchRow {
        path: "forward_calib".into(),
        n_experts: 8,
        tokens,
        serial_ms: serial.median_s * 1e3,
        parallel_ms: par.median_s * 1e3,
    });
    rows
}

/// GEMM-kernel comparison at expert-projection shapes → the
/// `quant_gemm_sweep` section of BENCH_backend.json. One expert-shaped
/// weight panel `[k, n]` is multiplied by a token block `[m, k]` at the
/// decode shape (m = 1: the latency-bound serving step) and the prefill
/// shape (m = 64: one scheduler token block), through three kernels:
/// the scalar reference loop (`matmul_reference`, the pre-tiling GEMM
/// and still the parity oracle), the cache-blocked register-tiled kernel
/// (`matmul_blocked_with`, bit-identical outputs) and the int8
/// folded-scale kernel (`matmul_q8_with` on per-row-quantized weights —
/// 4x smaller weight stream). All three run single-threaded so the rows
/// isolate kernel quality from threading; `scripts/check_kernels.sh`
/// gates tiled ≥ scalar and int8 ≥ tiled on every row.
fn quant_gemm_sweep(table: &mut Table) -> Vec<QuantGemmRow> {
    let smoke = bench_support::smoke();
    let (warmup, iters) = if smoke { (0, 1) } else { (3, 15) };
    // production-leaning expert projection: d=256 hidden, m=1024 FFN
    let (k, n) = (256usize, 1024usize);
    let mut rng = Rng::new(0x6E88);
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.02).collect();
    let (q, scales) = quantize_rows_i8(&w, k, n);
    let mut rows = Vec::new();
    for (path, m) in [("decode_gemm", 1usize), ("prefill_gemm", 64)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let scalar = bench_median(warmup, iters, || {
            std::hint::black_box(matmul_reference(&a, &w, m, k, n));
        });
        let tiled = bench_median(warmup, iters, || {
            std::hint::black_box(matmul_blocked_with(&a, &w, m, k, n, 1));
        });
        let int8 = bench_median(warmup, iters, || {
            std::hint::black_box(matmul_q8_with(&a, &q, &scales, m, k, n, 1));
        });
        let row = QuantGemmRow {
            path: path.into(),
            m,
            k,
            n,
            scalar_ms: scalar.median_s * 1e3,
            tiled_ms: tiled.median_s * 1e3,
            int8_ms: int8.median_s * 1e3,
        };
        table.row(vec![
            format!("{path} {m}x{k}x{n}"),
            format!("{:.4}", row.scalar_ms),
            format!("{:.4}", row.tiled_ms),
            format!("{:.4} ({:.2}x / {:.2}x)", row.int8_ms, row.tiled_speedup(), row.int8_speedup()),
        ]);
        rows.push(row);
    }
    rows
}

/// Toy config for the generation sweep: like [`backend_cfg`] but with a
/// deeper context window (long decodes) and a roomy capacity factor so
/// dispatch stays drop-free — cached and uncached paths then walk the
/// same numerical trajectory.
fn gen_cfg(n_exp: usize) -> ModelCfg {
    ModelCfg { t_max: 192, cap_factor: 4.0, ..backend_cfg(n_exp) }
}

/// Median of raw per-run durations (seconds).
fn median_s(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Autoregressive decode throughput → `BENCH_generate.json`: KV-cached
/// decode (O(t)/token) vs uncached full re-forward (O(t²)/token), full vs
/// compact r-expert layout. The timed region is the decode loop only
/// (prefill is excluded); both paths feed the same fixed token stream so
/// they do identical model work. The cached path's per-step matmuls are
/// single-row and therefore thread-independent — its "serial" and
/// "parallel" columns are two independent measurements of the same code;
/// the uncached path re-runs the batched forward, where the thread count
/// is real.
fn generate_sweep(threads: usize, table: &mut Table) -> Vec<GenerateBenchRow> {
    let smoke = bench_support::smoke();
    let iters = if smoke { 1 } else { 3 };
    let decode_lens: &[usize] = if smoke { &[16] } else { &[32, 64, 128] };
    let cfg = gen_cfg(8);
    let w = Weights::synthesize(&cfg, 0x6E6E);
    let prompt: Vec<i32> = (0..16usize).map(|i| (16 + (i * 5) % 64) as i32).collect();
    let feed = |i: usize| -> i32 { 16 + ((i * 7) % 64) as i32 };
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];

    // compact r=4 layout: keep the first 4 experts, fold the rest on top
    let r = 4usize;
    let keep: Vec<Vec<usize>> = vec![(0..r).collect(); cfg.n_layer];
    let cw = w.to_compact(&cfg, &keep).expect("compact weights");
    let remap: Vec<i32> = (0..cfg.n_layer * cfg.n_exp)
        .map(|i| ((i % cfg.n_exp) % r) as i32)
        .collect();

    let backend = NativeBackend::new(cfg.clone());
    let full_state = backend.load_model(&w, cfg.n_exp).expect("load full");
    let compact_state = backend.load_model(&cw, r).expect("load compact");

    let mut rows = Vec::new();
    for (variant, n_slots, weights, state, remap_opt) in [
        ("full", cfg.n_exp, &w, full_state.as_ref(), None),
        ("compact", r, &cw, compact_state.as_ref(), Some(remap.as_slice())),
    ] {
        for &n_decode in decode_lens {
            // cached: one prefill (untimed), then n_decode O(t) steps
            let cached = |_threads: usize| -> f64 {
                let mut samples = Vec::with_capacity(iters);
                for _ in 0..iters {
                    let mut opts = PrefillOpts::new(&mask);
                    if let Some(rm) = remap_opt {
                        opts = opts.remap(rm);
                    }
                    let (cache, _) =
                        backend.run_prefill(state, &prompt, opts).expect("prefill");
                    let mut cache = cache.expect("fresh prefill returns a cache");
                    let t0 = Instant::now();
                    for i in 0..n_decode {
                        backend
                            .run_decode(state, cache.as_mut(), feed(i), &mask, remap_opt)
                            .expect("decode");
                    }
                    samples.push(t0.elapsed().as_secs_f64());
                }
                median_s(samples)
            };
            // uncached: re-forward the whole prefix for every emitted token
            let uncached = |threads: usize| -> f64 {
                let mut samples = Vec::with_capacity(iters);
                for _ in 0..iters {
                    let mut seq = prompt.clone();
                    let t0 = Instant::now();
                    for i in 0..n_decode {
                        seq.push(feed(i));
                        std::hint::black_box(
                            forward_logits_with(
                                &cfg,
                                weights,
                                &seq,
                                1,
                                seq.len(),
                                &mask,
                                remap_opt,
                                n_slots,
                                threads,
                            )
                            .expect("forward"),
                        );
                    }
                    samples.push(t0.elapsed().as_secs_f64());
                }
                median_s(samples)
            };
            for (path, serial_s, parallel_s) in [
                ("decode_cached", cached(1), cached(threads)),
                ("decode_uncached", uncached(1), uncached(threads)),
            ] {
                table.row(vec![
                    format!("{path} {variant} t={}", prompt.len() + n_decode),
                    format!("{:.3}", serial_s * 1e3),
                    format!("{:.3}", parallel_s * 1e3),
                    format!("{:.0} tok/s", n_decode as f64 / parallel_s.max(1e-12)),
                ]);
                rows.push(GenerateBenchRow {
                    path: path.into(),
                    variant: variant.into(),
                    n_slots,
                    prompt_tokens: prompt.len(),
                    decode_tokens: n_decode,
                    serial_ms: serial_s * 1e3,
                    parallel_ms: parallel_s * 1e3,
                });
            }
        }
    }
    rows
}

/// Batched continuous decode vs the per-sequence loop — the serving
/// executor's before/after. B sequences are prefilled (untimed), then
/// advanced `steps` tokens each: the sequential column calls
/// `run_decode` once per sequence per step (every weight matrix is
/// streamed B times per step), the batched column makes one
/// `run_decode_batch` call per step (shared `[B, d]` projection GEMMs,
/// per-expert grouped SwiGLU — each weight streamed once). Both columns
/// use the auto-gated trait entry points — exactly what the executor
/// runs — so this measures the batching win itself, with the per-product
/// work gate deciding threading identically on both sides. Both paths
/// produce bit-identical logits (`rust/tests/decode_batch.rs`); emits the
/// `decode_batch_sweep` section of BENCH_generate.json, where CI asserts
/// batched ≥ sequential at B = 4.
fn decode_batch_sweep(table: &mut Table) -> Vec<DecodeBatchRow> {
    let smoke = bench_support::smoke();
    // the B=4 row feeds a hard CI gate, so buy median stability with more
    // iterations and a longer timed region than the other sweeps
    let iters = if smoke { 1 } else { 7 };
    let steps = if smoke { 8 } else { 48 };
    let cfg = gen_cfg(8);
    let w = Weights::synthesize(&cfg, 0xBA7C);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).expect("load");
    let prompt_len = 16usize;
    let feed = |s: usize, i: usize| -> i32 { (16 + (s * 13 + i * 7) % 64) as i32 };
    let mut rows = Vec::new();
    for &b in &[1usize, 2, 4, 8] {
        let prompts: Vec<Vec<i32>> = (0..b)
            .map(|s| (0..prompt_len).map(|i| (16 + (i * 5 + s * 3) % 64) as i32).collect())
            .collect();
        let prefill_all = || -> Vec<Box<dyn KvCache>> {
            prompts
                .iter()
                .map(|p| {
                    backend
                        .run_prefill(state.as_ref(), p, PrefillOpts::new(&mask))
                        .expect("prefill")
                        .0
                        .expect("fresh prefill returns a cache")
                })
                .collect()
        };
        // per-sequence loop: B run_decode calls per step
        let mut seq_samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let mut caches = prefill_all();
            let t0 = Instant::now();
            for i in 0..steps {
                for (s, c) in caches.iter_mut().enumerate() {
                    backend
                        .run_decode(state.as_ref(), c.as_mut(), feed(s, i), &mask, None)
                        .expect("decode");
                }
            }
            seq_samples.push(t0.elapsed().as_secs_f64());
        }
        let seq_s = median_s(seq_samples);
        // batched: one run_decode_batch call per step
        let mut batch_samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let mut caches = prefill_all();
            let t0 = Instant::now();
            for i in 0..steps {
                let tokens: Vec<i32> = (0..b).map(|s| feed(s, i)).collect();
                let mut refs: Vec<&mut dyn KvCache> =
                    caches.iter_mut().map(|c| c.as_mut()).collect();
                backend
                    .run_decode_batch(state.as_ref(), &mut refs, &tokens, &mask, None)
                    .expect("decode batch");
            }
            batch_samples.push(t0.elapsed().as_secs_f64());
        }
        let batch_s = median_s(batch_samples);
        table.row(vec![
            format!("B={b} × {steps} steps"),
            format!("{:.3}", seq_s * 1e3),
            format!("{:.3}", batch_s * 1e3),
            format!(
                "{:.0} tok/s ({:.2}x)",
                (b * steps) as f64 / batch_s.max(1e-12),
                seq_s / batch_s.max(1e-12)
            ),
        ]);
        rows.push(DecodeBatchRow {
            batch: b,
            prompt_tokens: prompt_len,
            decode_tokens: steps,
            seq_ms: seq_s * 1e3,
            batch_ms: batch_s * 1e3,
        });
    }
    rows
}

/// Flat vs paged KV cache on the same decode workload, plus the
/// steady-state realloc count: one sequence is prefilled (untimed) and
/// decoded `n` steps; `capacity_bytes` is sampled per step and every
/// change on the flat path is a `Vec` regrowth (a full-buffer copy).
/// After the prefill-reservation fix the flat count must be 0, and the
/// paged pool never copies on block allocation — `scripts/check_kvpool.sh`
/// gates both at 0. Emits the `kv_cache_sweep` section of
/// BENCH_generate.json.
fn kv_cache_sweep(table: &mut Table) -> Vec<KvCacheBenchRow> {
    let smoke = bench_support::smoke();
    let iters = if smoke { 1 } else { 5 };
    let decode_lens: &[usize] = if smoke { &[16] } else { &[64, 160] };
    let cfg = gen_cfg(8);
    let w = Weights::synthesize(&cfg, 0x9A6ED);
    let mask = vec![0f32; cfg.n_layer * cfg.n_exp];
    let backend = NativeBackend::new(cfg.clone());
    let state = backend.load_model(&w, cfg.n_exp).expect("load");
    let prompt: Vec<i32> = (0..16usize).map(|i| (16 + (i * 5) % 64) as i32).collect();
    let feed = |i: usize| -> i32 { 16 + ((i * 7) % 64) as i32 };
    let mut rows = Vec::new();
    for &n_decode in decode_lens {
        for paged in [false, true] {
            let pool = PoolHandle::new(
                KvPool::for_model(&cfg, 4 << 20, DEFAULT_BLOCK_TOKENS).expect("pool"),
            );
            let block_bytes = cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS);
            let mut samples = Vec::with_capacity(iters);
            let mut reallocs = 0usize;
            for _ in 0..iters {
                let opts = if paged {
                    PrefillOpts::new(&mask).paged(&pool, prompt.len() + n_decode)
                } else {
                    PrefillOpts::new(&mask)
                };
                let (cache, _) =
                    backend.run_prefill(state.as_ref(), &prompt, opts).expect("prefill");
                let mut cache = cache.expect("fresh prefill returns a cache");
                let mut cap = cache.capacity_bytes();
                let t0 = Instant::now();
                for i in 0..n_decode {
                    backend
                        .run_decode(state.as_ref(), cache.as_mut(), feed(i), &mask, None)
                        .expect("decode");
                    let now = cache.capacity_bytes();
                    if now != cap {
                        // Flat: ANY capacity change is a Vec regrowth, i.e.
                        // a full-buffer copy. Paged: growing by exactly one
                        // block is a copy-free arena allocation (the normal
                        // path); anything else — a shrink, a multi-block
                        // jump — is not a shape this workload can produce
                        // and counts as a contract violation. Counted over
                        // every iteration (each runs a fresh cache, so one
                        // regressing iteration is enough to trip the gate).
                        if !paged || now != cap + block_bytes {
                            reallocs += 1;
                        }
                        cap = now;
                    }
                }
                samples.push(t0.elapsed().as_secs_f64());
            }
            let ms = median_s(samples) * 1e3;
            let path = if paged { "decode_paged" } else { "decode_flat" };
            table.row(vec![
                format!("{path} t={}", prompt.len() + n_decode),
                format!("{ms:.3}"),
                format!("{:.0} tok/s", n_decode as f64 / (ms / 1e3).max(1e-12)),
                reallocs.to_string(),
            ]);
            rows.push(KvCacheBenchRow {
                path: path.into(),
                decode_tokens: n_decode,
                ms,
                reallocs,
            });
        }
    }
    rows
}

/// Mixed-load scheduler sweep → the `sched_sweep` section of
/// BENCH_generate.json: a live server (synthesized `qwensim` artifacts, a
/// deliberately small 8-block KV pool) is driven with two concurrent
/// long-prompt Batch clients plus a stream of short Interactive requests,
/// once with whole-prompt prefills and once with a 4-token chunk. The
/// Interactive inter-token latency quantiles come from the server's
/// [`hc_smoe::serving::LatencyHisto`]; chunking bounds the decode stall a
/// Batch (re-)prefill can inject between two Interactive tokens, so the
/// chunked p99 must not exceed the unchunked one
/// (`scripts/check_sched.sh` gates this). The tight pool also makes the
/// two Batch reservations fill it completely, so Interactive arrivals
/// exercise the preemption path (`preemptions` in the rows).
fn sched_sweep(table: &mut Table) -> anyhow::Result<Vec<SchedBenchRow>> {
    let smoke = bench_support::smoke();
    let arts = bench_support::ensure_artifacts()?;
    let root = arts.root.to_string_lossy().into_owned();
    // qwensim synth config: L=2, d=32 → 512 B/token, 8 KiB per 16-token
    // block; 64 KiB = 8 blocks. One Batch job reserves 4 (48-token prompt
    // + 16 new = 64 = t_max), so two concurrent Batch jobs fill the pool.
    let kv_budget = 64 * 1024;
    let batch_clients = 2usize;
    let (jobs_per_client, interactive) = if smoke { (1usize, 3usize) } else { (2, 12) };
    let mut rows = Vec::new();
    for (mode, chunk) in [("unchunked", None), ("chunked", Some(4usize))] {
        let spec = ServeSpec {
            kv_budget_bytes: Some(kv_budget),
            prefill_chunk: chunk,
            ..ServeSpec::for_tests(&root, "qwensim")
        };
        let handle = serve(
            spec,
            BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(4) },
        )?;
        let h = &handle;
        std::thread::scope(|s| {
            for c in 0..batch_clients {
                s.spawn(move || {
                    for j in 0..jobs_per_client {
                        let prompt: Vec<i32> =
                            (0..48).map(|i| (16 + (i * 5 + c * 3 + j) % 64) as i32).collect();
                        h.generate_opts(
                            &prompt,
                            SamplingParams::greedy(16, None),
                            Priority::Batch,
                            None,
                        )
                        .expect("batch generation");
                    }
                });
            }
            s.spawn(move || {
                for i in 0..interactive {
                    let prompt: Vec<i32> =
                        (0..6).map(|p| (16 + (p * 3 + i) % 64) as i32).collect();
                    h.generate_opts(
                        &prompt,
                        SamplingParams::greedy(6, None),
                        Priority::Interactive,
                        Some(Duration::from_secs(60)),
                    )
                    .expect("interactive generation");
                }
            });
        });
        let snap = handle.metrics.snapshot();
        handle.shutdown()?;
        table.row(vec![
            format!("{mode} (chunk={})", chunk.unwrap_or(0)),
            format!("{:.3}", snap.itl_p50_ms),
            format!("{:.3}", snap.itl_p99_ms),
            format!(
                "preempt={} chunked={} stall≤{}",
                snap.preemptions, snap.chunked_prefills, snap.prefill_stall_tokens_max
            ),
        ]);
        rows.push(SchedBenchRow {
            mode: mode.into(),
            chunk: chunk.unwrap_or(0),
            interactive,
            batch_jobs: batch_clients * jobs_per_client,
            p50_ms: snap.itl_p50_ms,
            p99_ms: snap.itl_p99_ms,
            preemptions: snap.preemptions,
            chunked_prefills: snap.chunked_prefills,
        });
    }
    Ok(rows)
}

/// Speculative draft-k/verify-1 vs plain decode → the `spec_decode_sweep`
/// section of BENCH_generate.json: the synthesized `qwensim` original is
/// the verifier and its HC-merged compact r = E/2 variant the drafter.
/// Both paths run the same end-to-end generation (prefill included) on
/// the same prompt; speculation is exact by construction — the verifier's
/// own sampler picks every emitted token (`rust/tests/spec_decode.rs`
/// pins this bit-for-bit) — so each row records the equality check plus
/// the economics: acceptance rate and how many full-model verify forwards
/// replaced the one-forward-per-token plain loop.
/// `scripts/check_spec_decode.sh` gates `exact` on every row and
/// acceptance > 0 at k >= 2.
fn spec_decode_sweep(table: &mut Table) -> anyhow::Result<Vec<SpecDecodeRow>> {
    let smoke = bench_support::smoke();
    let iters = if smoke { 1usize } else { 5 };
    let max_new = if smoke { 8usize } else { 32 };
    let lab = Lab::new("qwensim")?;
    let r = (lab.ctx.cfg.n_exp / 2).max(1);
    let full = lab.ctx.load_original()?;
    let cm = lab.compress(
        hc_smoe::pipeline::Method::HcSmoe {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: hc_smoe::merging::MergeStrategy::Frequency,
        },
        r,
        "general",
    )?;
    let (cw, remap) = cm.to_compact(&lab.ctx)?;
    let drafter = lab.ctx.load_compact(r, &cw, remap, &cm.label)?;
    let prompt: Vec<i32> = (0..12usize).map(|i| (16 + (i * 5) % 64) as i32).collect();
    let params = SamplingParams::greedy(max_new, None);

    let mut rows = Vec::new();
    for &k in &[1usize, 2, 4, 8] {
        let mut plain = None;
        let mut plain_s = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let g = generate(&lab.ctx, &full, &prompt, params.clone())?;
            plain_s.push(t0.elapsed().as_secs_f64());
            plain = Some(g);
        }
        let plain = plain.expect("at least one plain iteration");
        let mut spec = None;
        let mut spec_s = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let s = speculative(&lab.ctx, &full, &drafter, &prompt, params.clone(), k)?;
            spec_s.push(t0.elapsed().as_secs_f64());
            spec = Some(s);
        }
        let spec = spec.expect("at least one speculative iteration");
        let exact =
            spec.gen.tokens == plain.tokens && spec.gen.finish == plain.finish;
        let row = SpecDecodeRow {
            draft_k: k,
            tokens: plain.tokens.len(),
            drafted: spec.drafted,
            accepted: spec.accepted,
            verify_steps: spec.verify_steps,
            plain_ms: median_s(plain_s) * 1e3,
            spec_ms: median_s(spec_s) * 1e3,
            exact,
        };
        table.row(vec![
            format!("k={k}"),
            format!("{:.3}", row.plain_ms),
            format!("{:.3}", row.spec_ms),
            format!(
                "{:.0}% accept, {} verify fwds, exact={}",
                row.acceptance_rate() * 100.0,
                row.verify_steps,
                row.exact
            ),
        ]);
        rows.push(row);
    }
    Ok(rows)
}

/// Adaptive serving sweep → the `adapt_sweep` section of
/// BENCH_generate.json: an adaptively-compressing server (synthesized
/// `qwensim`, HC-merged r = E/2 rebuild target) is driven with a steady
/// stream of blocking generation requests through three phases. The
/// routing window is sized just past what the `before` phase routes, so
/// the background recompression triggers — and the hot swap lands — in
/// the `during` phase; `after` then runs entirely on the swapped compact
/// variant. Because the rebuild runs on a worker thread while the
/// executor keeps serving, the `during` throughput must stay within a
/// bounded fraction of `before` (`scripts/check_adapt.sh` gates this,
/// plus swaps ≥ 1 by the `after` row).
fn adapt_sweep(table: &mut Table) -> anyhow::Result<Vec<AdaptBenchRow>> {
    let smoke = bench_support::smoke();
    let arts = bench_support::ensure_artifacts()?;
    let root = arts.root.to_string_lossy().into_owned();
    let r = (hc_smoe::model::ModelContext::load(&arts, "qwensim")?.cfg.n_exp / 2).max(1);
    let (per_phase, max_new) = if smoke { (3usize, 4usize) } else { (12, 8) };
    let prompt_len = 8usize;
    // each request routes at most prompt + max_new tokens, so the window
    // cannot fill during `before`; the first `during` request tips it over
    let window = (per_phase * (prompt_len + max_new)) as u64 + 1;
    let handle = serve(
        ServeSpec {
            adapt: Some(hc_smoe::serving::AdaptSpec {
                method: hc_smoe::pipeline::Method::HcSmoe {
                    linkage: Linkage::Average,
                    metric: Metric::ExpertOutput,
                    merge: hc_smoe::merging::MergeStrategy::Frequency,
                },
                r,
                domain: "general".into(),
                quantize: false,
                window_tokens: Some(window),
                min_tokens: Some(0),
            }),
            ..ServeSpec::for_tests(&root, "qwensim")
        },
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(1) },
    )?;
    let params = SamplingParams::greedy(max_new, None);
    let mut i = 0usize;
    let mut serve_phase = |phase: &str, until_swap: bool| -> anyhow::Result<AdaptBenchRow> {
        let t0 = Instant::now();
        let deadline = Instant::now() + Duration::from_secs(120);
        let (mut requests, mut tokens) = (0usize, 0usize);
        loop {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|p| (16 + (p * 5 + i) % 64) as i32).collect();
            let g = handle.generate(&prompt, params.clone())?;
            i += 1;
            requests += 1;
            tokens += g.tokens.len();
            if until_swap {
                if handle.metrics.snapshot().swaps >= 1 {
                    break;
                }
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "no hot swap landed during the adapt sweep"
                );
            } else if requests >= per_phase {
                break;
            }
        }
        let snap = handle.metrics.snapshot();
        Ok(AdaptBenchRow {
            phase: phase.into(),
            requests,
            tokens,
            ms: t0.elapsed().as_secs_f64() * 1e3,
            swaps: snap.swaps,
            entropy_bits: snap.dispatch_entropy,
        })
    };
    let rows = vec![
        serve_phase("before", false)?,
        serve_phase("during", true)?,
        serve_phase("after", false)?,
    ];
    let snap = handle.metrics.snapshot();
    handle.shutdown()?;
    for row in &rows {
        table.row(vec![
            row.phase.clone(),
            format!("{:.3}", row.ms),
            format!("{:.0} tok/s ({} req)", row.tok_s(), row.requests),
            format!("swaps={} H={:.3} bits", row.swaps, row.entropy_bits),
        ]);
    }
    table.row(vec![
        "(rebuild)".into(),
        format!("{:.3}", snap.recompress_s * 1e3),
        format!("variant {:016x}", snap.active_variant),
        format!("swaps={}", snap.swaps),
    ]);
    Ok(rows)
}

fn artifact_sections() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let (b, t) = (lab.ctx.manifest.eval_b, lab.ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 80) as i32 + 16).collect();
    let mut table = Table::new(
        &format!(
            "Perf microbench (qwensim, {} backend sections)",
            lab.ctx.backend_name()
        ),
        &["Path", "median", "min", "max", "unit"],
    );

    // 1. end-to-end scoring execution (the eval/serving hot path)
    let orig = lab.ctx.load_original()?;
    let st = bench_median(3, 12, || {
        lab.ctx.run_logits(&orig, &ids).unwrap();
    });
    table.row(vec![
        format!("lm_logits exec ({} tok)", b * t),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);

    // 2. variant load (paid once per compressed variant, amortised away
    // on the hot path)
    let st = bench_median(1, 5, || {
        std::hint::black_box(lab.ctx.load_original().unwrap());
    });
    table.row(vec![
        "variant load (resident weights)".into(),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);

    // 3. clustering on real features
    let n_exp = lab.ctx.cfg.n_exp;
    let r_half = (n_exp / 2).max(1);
    let stats = lab.stats("general")?;
    let feats = features(Metric::ExpertOutput, &lab.ctx.base, &stats.layers[0], 0)?;
    let st = bench_median(5, 50, || {
        let d = distance_matrix_serial(&feats, Distance::Euclidean);
        std::hint::black_box(hierarchical_with(&d, r_half, Linkage::Average, 1));
    });
    table.row(vec![
        format!("HC average-linkage (n={n_exp})"),
        format!("{:.1}", st.median_s * 1e6),
        format!("{:.1}", st.min_s * 1e6),
        format!("{:.1}", st.max_s * 1e6),
        "us".into(),
    ]);
    let st = bench_median(5, 50, || {
        std::hint::black_box(kmeans(&feats, r_half, KmeansInit::Fixed, 100));
    });
    table.row(vec![
        format!("K-means (n={n_exp})"),
        format!("{:.1}", st.median_s * 1e6),
        format!("{:.1}", st.min_s * 1e6),
        format!("{:.1}", st.max_s * 1e6),
        "us".into(),
    ]);

    // 4. full compression pipeline (plan + merge apply)
    let st = bench_median(1, 5, || {
        std::hint::black_box(
            lab.compress(
                hc_smoe::pipeline::Method::HcSmoe {
                    linkage: Linkage::Average,
                    metric: Metric::ExpertOutput,
                    merge: hc_smoe::merging::MergeStrategy::Frequency,
                },
                r_half,
                "general",
            )
            .unwrap(),
        );
    });
    table.row(vec![
        format!("HC-SMoE plan+apply (r={r_half})"),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);
    table.print();
    table.append_to("bench_results.md")?;

    // 5. serving batcher: throughput under concurrent clients
    let mut srv_table = Table::new(
        "Serving batcher (qwensim original, 64 requests x 4 rows)",
        &["clients", "wall s", "req/s", "rows/s busy", "batches", "fill"],
    );
    for clients in [1usize, 4, 16] {
        let spec =
            ServeSpec::for_tests(&lab.ctx.arts.root.to_string_lossy(), "qwensim");
        let handle = serve(
            spec,
            BatcherConfig { max_rows: b, max_wait: Duration::from_millis(4) },
        )?;
        let n_requests = 64usize;
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for c in 0..clients {
                let tx = handle.sender();
                s.spawn(move || {
                    for i in 0..n_requests / clients {
                        let prompt = vec![4, 20 + ((c + i) % 16) as i32, 50, 3];
                        let rows = (0..4)
                            .map(|ch| {
                                let mut seq = prompt.clone();
                                seq.push(60 + ch as i32);
                                hc_smoe::serving::RowSpec {
                                    start: prompt.len(),
                                    end: seq.len(),
                                    seq,
                                }
                            })
                            .collect();
                        let (reply, rx) = std::sync::mpsc::channel();
                        tx.send(
                            hc_smoe::serving::ScoreRequest {
                                rows,
                                reply,
                                enqueued: std::time::Instant::now(),
                            }
                            .into(),
                        )
                        .unwrap();
                        rx.recv().unwrap();
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let snap = handle.metrics.snapshot();
        handle.shutdown()?;
        srv_table.row(vec![
            clients.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", snap.requests as f64 / wall),
            format!("{:.1}", snap.rows_per_sec()),
            snap.batches.to_string(),
            format!("{:.2}", snap.mean_batch_fill(b)),
        ]);
    }
    srv_table.print();
    srv_table.append_to("bench_results.md")?;
    Ok(())
}

/// `HCSMOE_BENCH_ONLY` filter: run one section (`parallel` | `backend` |
/// `generate` | `artifact`) instead of everything — lets CI collect a
/// full-iteration `BENCH_generate.json` without re-running the other
/// sweeps.
fn section_enabled(name: &str) -> bool {
    match std::env::var("HCSMOE_BENCH_ONLY") {
        Ok(only) => only == name,
        Err(_) => true,
    }
}

fn main() -> anyhow::Result<()> {
    let threads = hc_smoe::parallel::default_threads();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if section_enabled("parallel") {
        let mut table = Table::new(
            &format!("Parallel vs serial hot paths ({threads} threads)"),
            &["Path", "serial ms", "parallel ms", "speedup"],
        );
        let rows = parallel_sweep(threads, &mut table);
        table.print();
        table.append_to("bench_results.md")?;
        let measurement = if bench_support::smoke() {
            "SMOKE MODE: single sample, harness check only — not a perf measurement"
        } else {
            "median of 15 (release)"
        };
        let note = format!(
            "{measurement}; host exposes {cores} cpus; linkage_scan_auto compares serial vs \
             auto dispatch (work-gated: parallel scan engages from ~1450 clusters)"
        );
        bench_support::write_parallel_json(
            BENCH_JSON,
            threads,
            "rust/benches/perf_microbench.rs",
            &note,
            &rows,
        )?;
        println!("wrote {BENCH_JSON}");
    }

    if section_enabled("backend") {
        let mut btable = Table::new(
            &format!("Native backend throughput ({threads} threads)"),
            &["Path", "serial ms", "parallel ms", "throughput"],
        );
        let brows = backend_sweep(threads, &mut btable);
        btable.print();
        btable.append_to("bench_results.md")?;
        let mut qtable = Table::new(
            "GEMM kernels: scalar reference vs cache-blocked vs int8 (1 thread)",
            &["Shape", "scalar ms", "tiled ms", "int8 ms (speedups)"],
        );
        let qrows = quant_gemm_sweep(&mut qtable);
        qtable.print();
        qtable.append_to("bench_results.md")?;
        let backend_measurement = if bench_support::smoke() {
            "SMOKE MODE: single sample, harness check only — not a perf measurement"
        } else {
            "median of 9 (release); quant_gemm_sweep median of 15"
        };
        let backend_note = format!(
            "{backend_measurement}; host exposes {cores} cpus; synthesized checkpoints \
             (b=4, t=64), native backend forward/calib; quant_gemm_sweep times one \
             256x1024 expert projection at decode (m=1) and prefill (m=64) shapes, \
             single-threaded — tiled is bit-identical to scalar, int8 streams 4x \
             fewer weight bytes"
        );
        bench_support::write_backend_json(
            BACKEND_JSON,
            threads,
            "rust/benches/perf_microbench.rs",
            &backend_note,
            &brows,
            &qrows,
        )?;
        println!("wrote {BACKEND_JSON}");
    }

    if !section_enabled("generate") {
        if bench_support::smoke() {
            println!("perf_microbench: smoke mode, skipping artifact sections");
            return Ok(());
        }
        if section_enabled("artifact") {
            match artifact_sections() {
                Ok(()) => {}
                Err(e) => println!("skipping artifact sections: {e:#}"),
            }
        }
        return Ok(());
    }

    let mut gtable = Table::new(
        &format!("Autoregressive decode: KV-cached vs uncached ({threads} threads)"),
        &["Path", "serial ms", "parallel ms", "decode throughput"],
    );
    let grows = generate_sweep(threads, &mut gtable);
    gtable.print();
    gtable.append_to("bench_results.md")?;
    let mut btable = Table::new(
        "Batched continuous decode: run_decode_batch vs per-sequence loop (auto-gated)",
        &["Batch", "per-seq ms", "batched ms", "batched throughput"],
    );
    let batch_rows = decode_batch_sweep(&mut btable);
    btable.print();
    btable.append_to("bench_results.md")?;
    let mut ktable = Table::new(
        "KV cache: flat vs paged decode (steady state, reallocs must be 0)",
        &["Path", "decode ms", "throughput", "reallocs"],
    );
    let kv_rows = kv_cache_sweep(&mut ktable);
    ktable.print();
    ktable.append_to("bench_results.md")?;
    let mut stable = Table::new(
        "Scheduler: chunked vs unchunked prefill under mixed Interactive+Batch load",
        &["Mode", "ITL p50 ms", "ITL p99 ms", "scheduler counters"],
    );
    let sched_rows = sched_sweep(&mut stable)?;
    stable.print();
    stable.append_to("bench_results.md")?;
    let mut sptable = Table::new(
        "Speculative decoding: compact drafter + full-model verify (exact output)",
        &["Draft k", "plain ms", "spec ms", "drafter economics"],
    );
    let spec_rows = spec_decode_sweep(&mut sptable)?;
    sptable.print();
    sptable.append_to("bench_results.md")?;
    let mut atable = Table::new(
        "Adaptive serving: throughput before/during/after live recompress + hot swap",
        &["Phase", "wall ms", "served throughput", "adapt counters"],
    );
    let adapt_rows = adapt_sweep(&mut atable)?;
    atable.print();
    atable.append_to("bench_results.md")?;
    let gen_measurement = if bench_support::smoke() {
        "SMOKE MODE: single sample, harness check only — not a perf measurement"
    } else {
        "median of 3 (release); decode_batch_sweep median of 7"
    };
    let gen_note = format!(
        "{gen_measurement}; host exposes {cores} cpus; synthesized checkpoint (L=2, d=64, \
         E=8 full / r=4 compact), 16-token prompt; timed region is the decode loop only; \
         cached decode is single-row and thread-independent (both columns measure the \
         same code), uncached re-forwards the whole prefix per token; decode_batch_sweep \
         compares one run_decode_batch call per step against B run_decode calls per step \
         (bit-identical outputs, wall-clock only); kv_cache_sweep compares flat vs paged \
         caches on one sequence (reallocs counts Vec regrowth copies — 0 is the contract); \
         sched_sweep drives a live server with mixed Interactive+Batch load on an 8-block \
         KV pool, chunked (4-token) vs unchunked prefill (chunked p99 ITL must not exceed \
         unchunked); spec_decode_sweep decodes the same prompt plainly and speculatively \
         (qwensim verifier, HC-merged r=4 compact drafter) — exact must hold on every row; \
         adapt_sweep serves a steady load through a live background recompression and \
         atomic hot swap (during tok/s must stay within a bounded fraction of before, \
         and a swap must land)"
    );
    bench_support::write_generate_json(
        GENERATE_JSON,
        threads,
        "rust/benches/perf_microbench.rs",
        &gen_note,
        &grows,
        &batch_rows,
        &kv_rows,
        &sched_rows,
        &spec_rows,
        &adapt_rows,
    )?;
    println!("wrote {GENERATE_JSON}");

    if bench_support::smoke() {
        println!("perf_microbench: smoke mode, skipping artifact sections");
        return Ok(());
    }
    if section_enabled("artifact") {
        match artifact_sections() {
            Ok(()) => {}
            Err(e) => println!("skipping artifact sections: {e:#}"),
        }
    }
    Ok(())
}
