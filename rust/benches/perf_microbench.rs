//! Performance microbenchmarks (EXPERIMENTS.md §Perf): the L3 hot paths —
//! PJRT execution latency, per-call data-upload overhead, algorithm
//! runtimes (HC / K-means / merging), and serving-batcher behaviour.

use std::time::Duration;

use hc_smoe::bench_support::Lab;
use hc_smoe::clustering::{hierarchical, kmeans, KmeansInit, Linkage};
use hc_smoe::report::Table;
use hc_smoe::serving::{serve, BatcherConfig, ServeSpec};
use hc_smoe::similarity::{distance_matrix, features, Distance, Metric};
use hc_smoe::util::bench_median;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let (b, t) = (lab.ctx.manifest.eval_b, lab.ctx.manifest.eval_t);
    let ids: Vec<i32> = (0..b * t).map(|i| (i % 97) as i32 + 16).collect();
    let mut table = Table::new(
        "Perf microbench (qwensim)",
        &["Path", "median", "min", "max", "unit"],
    );

    // 1. PJRT end-to-end scoring execution (the eval/serving hot path)
    let orig = lab.ctx.load_original()?;
    let st = bench_median(3, 12, || {
        lab.ctx.run_logits(&orig, &ids).unwrap();
    });
    table.row(vec![
        "lm_logits exec (1024 tok)".into(),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);

    // 2. weight upload (paid once per variant, amortised away on the hot path)
    let st = bench_median(1, 5, || {
        lab.ctx.lm_exe().unwrap().upload_weights(&lab.ctx.base).unwrap();
    });
    table.row(vec![
        "weights upload (2M params)".into(),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);

    // 3. clustering algorithms on real features
    let stats = lab.stats("general")?;
    let feats = features(Metric::ExpertOutput, &lab.ctx.base, &stats.layers[0], 0)?;
    let st = bench_median(5, 50, || {
        let d = distance_matrix(&feats, Distance::Euclidean);
        std::hint::black_box(hierarchical(&d, 8, Linkage::Average));
    });
    table.row(vec![
        "HC average-linkage (n=16)".into(),
        format!("{:.1}", st.median_s * 1e6),
        format!("{:.1}", st.min_s * 1e6),
        format!("{:.1}", st.max_s * 1e6),
        "us".into(),
    ]);
    let st = bench_median(5, 50, || {
        std::hint::black_box(kmeans(&feats, 8, KmeansInit::Fixed, 100));
    });
    table.row(vec![
        "K-means (n=16)".into(),
        format!("{:.1}", st.median_s * 1e6),
        format!("{:.1}", st.min_s * 1e6),
        format!("{:.1}", st.max_s * 1e6),
        "us".into(),
    ]);

    // 4. full compression pipeline (plan + merge apply)
    let st = bench_median(1, 5, || {
        std::hint::black_box(
            lab.compress(
                hc_smoe::pipeline::Method::HcSmoe {
                    linkage: Linkage::Average,
                    metric: Metric::ExpertOutput,
                    merge: hc_smoe::merging::MergeStrategy::Frequency,
                },
                8,
                "general",
            )
            .unwrap(),
        );
    });
    table.row(vec![
        "HC-SMoE plan+apply (r=8)".into(),
        format!("{:.2}", st.median_s * 1e3),
        format!("{:.2}", st.min_s * 1e3),
        format!("{:.2}", st.max_s * 1e3),
        "ms".into(),
    ]);
    table.print();
    table.append_to("bench_results.md")?;

    // 5. serving batcher: throughput under concurrent clients
    let mut srv_table = Table::new(
        "Serving batcher (qwensim original, 64 requests x 4 rows)",
        &["clients", "wall s", "req/s", "rows/s busy", "batches", "fill"],
    );
    for clients in [1usize, 4, 16] {
        let spec = ServeSpec {
            artifacts_root: lab.ctx.arts.root.to_string_lossy().into_owned(),
            model: "qwensim".into(),
            compress: None,
        };
        let handle = serve(
            spec,
            BatcherConfig { max_rows: b, max_wait: Duration::from_millis(4) },
        )?;
        let n_requests = 64usize;
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for c in 0..clients {
                let tx = handle.sender();
                s.spawn(move || {
                    for i in 0..n_requests / clients {
                        let prompt = vec![4, 20 + ((c + i) % 16) as i32, 50, 3];
                        let rows = (0..4)
                            .map(|ch| {
                                let mut seq = prompt.clone();
                                seq.push(60 + ch as i32);
                                hc_smoe::serving::RowSpec {
                                    start: prompt.len(),
                                    end: seq.len(),
                                    seq,
                                }
                            })
                            .collect();
                        let (reply, rx) = std::sync::mpsc::channel();
                        tx.send(hc_smoe::serving::ScoreRequest {
                            rows,
                            reply,
                            enqueued: std::time::Instant::now(),
                        })
                        .unwrap();
                        rx.recv().unwrap();
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let snap = handle.metrics.snapshot();
        handle.shutdown()?;
        srv_table.row(vec![
            clients.to_string(),
            format!("{wall:.2}"),
            format!("{:.1}", snap.requests as f64 / wall),
            format!("{:.1}", snap.rows_per_sec()),
            snap.batches.to_string(),
            format!("{:.2}", snap.mean_batch_fill(b)),
        ]);
    }
    srv_table.print();
    srv_table.append_to("bench_results.md")?;
    Ok(())
}
