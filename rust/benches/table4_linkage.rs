//! Table 4: linkage (single/complete/average) × similarity metric
//! (router-logits / weight / expert-output) ablation on qwensim at 25%
//! reduction, over the paper's 4-task ablation subset.

use hc_smoe::bench_support::{push_row, task_table, Lab, ABLATION_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let r = 12; // 25% reduction
    let mut table = task_table("Table 4 analog — linkage x metric (qwensim r=12)", &ABLATION_TASKS);
    let (scores, avg) = lab.eval_original(&ABLATION_TASKS)?;
    push_row(&mut table, "None", 16, &scores, avg);
    for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
        for metric in [Metric::RouterLogits, Metric::Weight, Metric::ExpertOutput] {
            let method = Method::HcSmoe {
                linkage,
                metric,
                merge: MergeStrategy::Frequency,
            };
            let label = format!("{}+{}", linkage.short(), metric.short());
            let (scores, avg) = lab.eval_method(method, r, "general", &ABLATION_TASKS)?;
            push_row(&mut table, &label, r, &scores, avg);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
