//! Table 8 (Appendix B.1): non-uniform per-layer cluster budgets at an
//! overall 25% reduction — linkage × metric × merge grid.

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::{FixDomFeature, MergeStrategy};
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let r = 12; // 25% average reduction
    let mut table = task_table(
        "Table 8 analog — non-uniform clustering (qwensim, avg r=12)",
        &PAPER_TASKS,
    );
    for linkage in [Linkage::Single, Linkage::Average] {
        for metric in [Metric::Weight, Metric::ExpertOutput] {
            for (mname, merge) in [
                ("freq", MergeStrategy::Frequency),
                ("fixdom", MergeStrategy::FixDom(FixDomFeature::Act)),
            ] {
                let method = Method::HcNonUniform { linkage, metric, merge };
                let label = format!("{}+{}+{}", linkage.short(), metric.short(), mname);
                let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
                push_row(&mut table, &label, r, &scores, avg);
            }
        }
    }
    // print the budget distribution (the paper's "[48, 45, 40, ...]" example)
    let stats = lab.stats("general")?;
    let freqs: Vec<Vec<f32>> = stats.layers.iter().map(|l| l.counts.clone()).collect();
    let budgets = hc_smoe::clustering::nonuniform_budgets(&freqs, r, lab.ctx.cfg.k);
    println!("per-layer budgets at avg r={r}: {budgets:?}");
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
