//! Tables 16-17 (Appendix B.5): hard HC-SMoE vs soft Fuzzy C-Means
//! clustering (which must also merge router columns, degrading routing).

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    for (model, rs) in [("qwensim", [12usize, 8]), ("mixsim", [6, 4])] {
        let lab = Lab::new(model)?;
        let mut table = task_table(
            &format!("Tables 16-17 analog — HC-SMoE vs Fuzzy C-Means ({model})"),
            &PAPER_TASKS,
        );
        let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
        push_row(&mut table, "None", lab.ctx.cfg.n_exp, &scores, avg);
        for r in rs {
            let hc = Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge: MergeStrategy::Frequency,
            };
            let (scores, avg) = lab.eval_method(hc, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, "HC-SMoE", r, &scores, avg);
            let (scores, avg) =
                lab.eval_method(Method::Fcm { seed: 7 }, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, "Fuzzy-Cmeans", r, &scores, avg);
        }
        table.print();
        table.append_to("bench_results.md")?;
    }
    Ok(())
}
