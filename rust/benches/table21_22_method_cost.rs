//! Tables 21-22 (Appendix C): runtime and working-set memory of each
//! compression algorithm itself (calibration excluded, as in the paper —
//! every method shares the same calibration pass).

use std::time::Instant;

use hc_smoe::bench_support::{paper_methods, Lab};
use hc_smoe::pipeline::{Method, Pipeline};
use hc_smoe::report::Table;

/// Approximate working set: base weights + the stat tensors a method reads.
fn method_memory_mb(lab: &Lab, method: &Method) -> f64 {
    let w = lab.ctx.base.byte_size() as f64;
    let stats = lab.stats("general").unwrap();
    let per_layer = |l: &hc_smoe::calib::LayerStats| -> f64 {
        let base = (l.mean_out.len() + l.counts.len() * 3) as f64;
        let extra = match method {
            Method::OPrune { .. } => (l.raw_sub.len() + l.rl_sub.len()) as f64,
            Method::MSmoe => l.rl_sub.len() as f64,
            Method::HcSmoe { .. } | Method::HcNonUniform { .. } => l.act_sub.len() as f64,
            _ => 0.0,
        };
        base + extra
    };
    let stat_bytes: f64 = stats.layers.iter().map(per_layer).sum::<f64>() * 4.0;
    (w + stat_bytes) / 1e6
}

fn main() -> anyhow::Result<()> {
    for (model, r) in [("mixsim", 4usize), ("qwensim", 8)] {
        let lab = Lab::new(model)?;
        let _ = lab.stats("general")?; // warm calibration once for all methods
        let mut table = Table::new(
            &format!("Tables 21-22 analog — method cost ({model}, r={r})"),
            &["Method", "Runtime (s)", "Working set (MB)"],
        );
        for method in paper_methods(lab.ctx.cfg.n_exp, r) {
            let label = method.label();
            let stats = lab.stats("general")?;
            let t0 = Instant::now();
            let plan = Pipeline::new(method.clone()).plan(&lab.ctx, &stats, r)?;
            let _cm = plan.apply(&lab.ctx, &stats)?;
            let secs = t0.elapsed().as_secs_f64();
            table.row(vec![
                label,
                format!("{secs:.3}"),
                format!("{:.1}", method_memory_mb(&lab, &method)),
            ]);
        }
        table.print();
        table.append_to("bench_results.md")?;
    }
    Ok(())
}
