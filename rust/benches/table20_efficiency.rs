//! Table 20 (Appendix C): computational/memory efficiency of the original
//! vs merged models — throughput (tokens/ms), latency per batch, analytic
//! GFLOPs per batch, weight memory and parameter count.
//!
//! Mirrors the paper's two regimes:
//! * the *n-slot* variant (router unchanged, merged experts duplicated) —
//!   memory shrinks logically but compute stays (the paper's "router
//!   functions as if the original number of experts exists");
//! * the *compact* r-expert executables, where compute and memory both
//!   shrink (our extension enabled by the remap-table design).

use hc_smoe::bench_support::Lab;
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::{compressed_params, Method};
use hc_smoe::report::Table;
use hc_smoe::similarity::Metric;
use hc_smoe::util::bench_median;

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 20 analog — efficiency (batch = eval_b x eval_t tokens)",
        &["Model", "Throughput tok/ms", "Latency ms", "GFLOPs/batch", "Memory MB", "Params M"],
    );
    for model in ["mixsim", "qwensim"] {
        let lab = Lab::new(model)?;
        let cfg = &lab.ctx.cfg;
        let (b, t) = (lab.ctx.manifest.eval_b, lab.ctx.manifest.eval_t);
        let tokens = (b * t) as f64;
        let ids: Vec<i32> = (0..b * t).map(|i| (i % 97) as i32 + 16).collect();

        // original (n-slot executable)
        let orig = lab.ctx.load_original()?;
        let st = bench_median(2, 8, || {
            lab.ctx.run_logits(&orig, &ids).unwrap();
        });
        let params = cfg.total_params(cfg.n_exp);
        table.row(vec![
            format!("{model} {}x (orig)", cfg.n_exp),
            format!("{:.1}", tokens / (st.median_s * 1e3)),
            format!("{:.1}", st.median_s * 1e3),
            format!("{:.2}", cfg.flops_per_token(cfg.n_exp) * tokens / 1e9),
            format!("{:.1}", params as f64 * 4.0 / 1e6),
            format!("{:.2}", params as f64 / 1e6),
        ]);

        // merged compact variants at the paper's 25% / 50% ratios
        let rs = &lab.ctx.manifest.reductions[model];
        for &r in &rs[..2] {
            let method = Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge: MergeStrategy::Frequency,
            };
            let cm = lab.compress(method, r, "general")?;
            let (cw, remap) = cm.to_compact(&lab.ctx)?;
            let compact = lab.ctx.load_compact(r, &cw, remap, &cm.label)?;
            let st = bench_median(2, 8, || {
                lab.ctx.run_logits_compact(&compact, &ids).unwrap();
            });
            let params = compressed_params(cfg, &cm.plan.experts_per_layer());
            table.row(vec![
                format!("{model} {r}x (merged)"),
                format!("{:.1}", tokens / (st.median_s * 1e3)),
                format!("{:.1}", st.median_s * 1e3),
                format!("{:.2}", cfg.flops_per_token(r) * tokens / 1e9),
                format!("{:.1}", cw.byte_size() as f64 / 1e6),
                format!("{:.2}", params as f64 / 1e6),
            ]);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
