//! Table 15 (Appendix B.4.2): domain-specific evaluation on the MedMCQA
//! analog (held-out specialist domain) — accuracy / precision / recall / F1
//! for HC-SMoE and the pruning/merging baselines, calibrated on the
//! specialist domain's own training stream (as the paper calibrates on the
//! MedMCQA train split).

use hc_smoe::bench_support::Lab;
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::report::Table;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let mut table = Table::new(
        "Table 15 analog — MedMCQA-analog (mixsim, med-domain calibration)",
        &["Model", "Method", "Accuracy", "Precision", "Recall", "F1"],
    );
    let p = lab.prf_original("med")?;
    table.row(vec![
        "mixsim 8x".into(),
        "None".into(),
        format!("{:.4}", p.accuracy),
        format!("{:.4}", p.precision),
        format!("{:.4}", p.recall),
        format!("{:.4}", p.f1),
    ]);
    for &r in &[6usize, 4] {
        let methods: Vec<(String, Method)> = vec![
            ("F-prune".into(), Method::FPrune),
            ("S-prune".into(), Method::SPrune),
            ("M-SMoE".into(), Method::MSmoe),
            (
                "HC-SMoE (ours)".into(),
                Method::HcSmoe {
                    linkage: Linkage::Average,
                    metric: Metric::ExpertOutput,
                    merge: MergeStrategy::Frequency,
                },
            ),
        ];
        for (name, method) in methods {
            let p = lab.prf_method(method, r, "med", "med")?;
            table.row(vec![
                format!("mixsim {r}x"),
                name,
                format!("{:.4}", p.accuracy),
                format!("{:.4}", p.precision),
                format!("{:.4}", p.recall),
                format!("{:.4}", p.f1),
            ]);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
