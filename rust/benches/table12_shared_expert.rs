//! Tables 12-13 (Appendix B.4.1): HC-SMoE on the DeepSeek-MoE analog
//! (dssim: 16 routed experts + 1 always-on shared expert) across 12.5%,
//! 25%, 37.5% and 50% reduction — the shared expert is excluded from
//! similarity/merging exactly as the paper does.

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("dssim")?;
    let mut table = task_table(
        "Table 12 analog — DeepSeek-style shared-expert model (dssim)",
        &PAPER_TASKS,
    );
    let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
    push_row(&mut table, "0%", 16, &scores, avg);
    for (ratio, r) in [("12.5%", 14usize), ("25%", 12), ("37.5%", 10), ("50%", 8)] {
        let method = Method::HcSmoe {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: MergeStrategy::Frequency,
        };
        let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
        push_row(&mut table, ratio, r, &scores, avg);
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
