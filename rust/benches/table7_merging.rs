//! Table 7: merging-strategy ablation (frequency / average / Fix-Dom) on
//! HC average-linkage expert-output clusters — the paper's claim that once
//! clusters are good, the merge rule barely matters.

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::{FixDomFeature, MergeStrategy};
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("qwensim")?;
    let mut table = task_table(
        "Table 7 analog — merging strategies on HC(avg,eo) clusters (qwensim)",
        &PAPER_TASKS,
    );
    let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
    push_row(&mut table, "None", 16, &scores, avg);
    for &r in &[12usize, 8] {
        let mut strat_avgs = Vec::new();
        for (name, merge) in [
            ("Frequency", MergeStrategy::Frequency),
            ("Average", MergeStrategy::Average),
            ("Fix-Dom", MergeStrategy::FixDom(FixDomFeature::Act)),
        ] {
            let method = Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge,
            };
            let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, name, r, &scores, avg);
            strat_avgs.push(avg);
        }
        let spread = strat_avgs.iter().cloned().fold(f64::MIN, f64::max)
            - strat_avgs.iter().cloned().fold(f64::MAX, f64::min);
        println!("r={r}: merge-strategy average spread = {spread:.4} (paper: ~0.002)");
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
