//! Tables 10-11 (Appendix B.3): calibration-dataset ablation — HC-SMoE
//! calibrated on the C4/MATH/CodeQA analogs, evaluated on the full suite.

use hc_smoe::bench_support::{push_row, task_table, Lab, PAPER_TASKS};
use hc_smoe::clustering::Linkage;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::pipeline::Method;
use hc_smoe::similarity::Metric;

fn main() -> anyhow::Result<()> {
    for (model, rs) in [("qwensim", [12usize, 8]), ("mixsim", [6, 4])] {
        let lab = Lab::new(model)?;
        let mut table = task_table(
            &format!("Tables 10-11 analog — calibration domains ({model})"),
            &PAPER_TASKS,
        );
        let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
        push_row(&mut table, "None", lab.ctx.cfg.n_exp, &scores, avg);
        for r in rs {
            for domain in ["general", "math", "code"] {
                let method = Method::HcSmoe {
                    linkage: Linkage::Average,
                    metric: Metric::ExpertOutput,
                    merge: MergeStrategy::Frequency,
                };
                let (scores, avg) = lab.eval_method(method, r, domain, &PAPER_TASKS)?;
                push_row(&mut table, &format!("HC-SMoE[{domain}]"), r, &scores, avg);
            }
        }
        table.print();
        table.append_to("bench_results.md")?;
    }
    Ok(())
}
