//! Table 3: zero-shot comparison on the Mixtral analog (mixsim, n=8) —
//! original vs all methods at six (25%) and four (50%) experts per layer.

use hc_smoe::bench_support::{paper_methods, push_row, task_table, Lab, PAPER_TASKS};

fn main() -> anyhow::Result<()> {
    let lab = Lab::new("mixsim")?;
    let mut table = task_table(
        "Table 3 analog — mixsim (n=8), C4-analog calibration",
        &PAPER_TASKS,
    );
    let (scores, avg) = lab.eval_original(&PAPER_TASKS)?;
    push_row(&mut table, "None", 8, &scores, avg);
    for &r in &[6usize, 4] {
        for method in paper_methods(lab.ctx.cfg.n_exp, r) {
            let label = method.label();
            let (scores, avg) = lab.eval_method(method, r, "general", &PAPER_TASKS)?;
            push_row(&mut table, &label, r, &scores, avg);
        }
    }
    table.print();
    table.append_to("bench_results.md")?;
    Ok(())
}
