//! Replica scale-out traffic bench → `BENCH_serve.json`.
//!
//! A [`Dispatcher`] fleet (synthesized `qwensim` artifacts) is driven
//! with the same traffic pattern at 1 and 2 replicas: bursty arrivals
//! (whole bursts submitted back-to-back, then a gap) of mixed prompt
//! lengths — short Interactive requests next to long Batch jobs — with
//! every third request additionally opting into live token streaming,
//! whose stream is checked against the final reply token-for-token.
//!
//! Columns: client-observed completion latency (p50/p99), goodput
//! (completed streams per second), and a `dropped` count (errors or
//! stream/reply divergence). `scripts/check_serve.sh` gates `dropped`
//! at 0 on every row and requires 2-replica goodput ≥ 1-replica —
//! scale-out must never lose streams and must actually scale.
//!
//! `HCSMOE_BENCH_SMOKE=1` shrinks the traffic for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hc_smoe::bench_support::{self, write_serve_json, ServeBenchRow};
use hc_smoe::generate::{Generated, SamplingParams};
use hc_smoe::parallel::default_threads;
use hc_smoe::report::Table;
use hc_smoe::serving::{
    BatcherConfig, Dispatcher, GenerateRequest, Priority, ReplyRx, ServeSpec,
};

const SERVE_JSON: &str = "BENCH_serve.json";

/// One in-flight request the traffic generator is waiting on.
struct InFlight {
    started: Instant,
    reply: ReplyRx<anyhow::Result<Generated>>,
    /// The live token stream, for requests that opted in.
    stream: Option<ReplyRx<i32>>,
}

/// Drive one fleet with the bursty mixed-length pattern; returns the row.
fn drive(root: &str, replicas: usize, bursts: usize, burst_size: usize) -> anyhow::Result<ServeBenchRow> {
    let d = Arc::new(Dispatcher::launch(
        ServeSpec::for_tests(root, "qwensim"),
        BatcherConfig { max_rows: 8, max_wait: Duration::from_millis(4) },
        Some(replicas),
    )?);
    let t0 = Instant::now();
    let mut inflight: Vec<InFlight> = Vec::new();
    let mut latencies_ms: Vec<f64> = Vec::new();
    let (mut completed, mut dropped, mut tokens) = (0usize, 0usize, 0u64);
    let mut drain = |inflight: &mut Vec<InFlight>,
                     latencies_ms: &mut Vec<f64>| {
        for f in inflight.drain(..) {
            let result = f.reply.recv();
            let streamed: Option<Vec<i32>> = f.stream.map(|rx| {
                let mut got = Vec::new();
                while let Ok(t) = rx.recv() {
                    got.push(t);
                }
                got
            });
            match result {
                Ok(Ok(g)) => {
                    if streamed.is_some_and(|s| s != g.tokens) {
                        dropped += 1; // stream diverged from the reply
                    } else {
                        completed += 1;
                        tokens += g.tokens.len() as u64;
                        latencies_ms.push(f.started.elapsed().as_secs_f64() * 1e3);
                    }
                }
                _ => dropped += 1,
            }
        }
    };
    for b in 0..bursts {
        // the whole burst arrives at once: placements overlap, so the
        // dispatcher spreads the burst across replica pools
        for i in 0..burst_size {
            let n = b * burst_size + i;
            // mixed lengths: every third request is a long Batch job,
            // the rest short Interactive traffic
            let long = n % 3 == 2;
            let len = if long { 32 + (n * 7) % 16 } else { 4 + (n * 5) % 8 };
            let prompt: Vec<i32> = (0..len).map(|p| (3 + p * 5 + n) as i32 % 90).collect();
            let params = SamplingParams::greedy(if long { 16 } else { 6 }, None);
            let mut req = GenerateRequest::new(&prompt, params)
                .priority(if long { Priority::Batch } else { Priority::Interactive });
            let mut stream = None;
            if n % 3 == 0 {
                let (r, rx) = req.streaming();
                req = r;
                stream = Some(rx);
            }
            let started = Instant::now();
            let (_, reply) = d.submit(req)?;
            inflight.push(InFlight {
                started,
                reply: reply.expect("fresh request owns its receiver"),
                stream,
            });
        }
        // drain the burst before the next one arrives (bursty, not
        // steady-state: the gap is the recv time of the slowest stream)
        drain(&mut inflight, &mut latencies_ms);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    d.shutdown()?;
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let quantile = |q: f64| -> f64 {
        if latencies_ms.is_empty() {
            return 0.0;
        }
        let idx = ((latencies_ms.len() - 1) as f64 * q).round() as usize;
        latencies_ms[idx]
    };
    Ok(ServeBenchRow {
        replicas,
        completed,
        dropped,
        tokens,
        wall_s,
        p50_ms: quantile(0.50),
        p99_ms: quantile(0.99),
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = bench_support::smoke();
    let (bursts, burst_size) = if smoke { (2usize, 6usize) } else { (4, 12) };
    let arts = bench_support::ensure_artifacts()?;
    let root = arts.root.to_string_lossy().into_owned();
    let mut table = Table::new(
        "replica scale-out (bursty mixed-length traffic)",
        &["replicas", "completed", "dropped", "goodput req/s", "p50/p99 ms"],
    );
    let mut rows = Vec::new();
    for replicas in [1usize, 2] {
        let row = drive(&root, replicas, bursts, burst_size)?;
        table.row(vec![
            row.replicas.to_string(),
            row.completed.to_string(),
            row.dropped.to_string(),
            format!("{:.2}", row.goodput()),
            format!("{:.2}/{:.2}", row.p50_ms, row.p99_ms),
        ]);
        rows.push(row);
    }
    table.print();
    write_serve_json(
        SERVE_JSON,
        default_threads(),
        "serve_traffic",
        &format!(
            "{} bursts x {} requests per replica count; every 3rd request streams; \
             dropped counts errors and stream/reply divergence",
            bursts, burst_size
        ),
        &rows,
    )?;
    println!("wrote {SERVE_JSON}");
    Ok(())
}
