//! Shared harness for the table/figure bench targets (`rust/benches/*.rs`)
//! and the examples.
//!
//! `Lab` wraps a model with memoised calibration statistics and a *disk*
//! results cache (`<artifacts>/cache/`): every (method, r, domain,
//! task-set) evaluation is stored once, so `cargo bench` re-runs and
//! benches sharing configurations (e.g. Fig. 1 reuses Table 2 rows) do
//! not re-execute minutes of model work.
//!
//! Artifacts resolve through [`synth::ensure_artifacts`]: real AOT output
//! wins when present, otherwise a deterministic synthetic set is generated
//! in-process, so every bench target and example *runs to completion*
//! offline instead of skipping.

pub mod synth;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

pub use synth::{ensure_artifacts, synthesize_artifacts};

use crate::calib::CalibStats;
use crate::config::Artifacts;
use crate::eval::{Evaluator, Prf};
use crate::model::ModelContext;
use crate::pipeline::{CompressedModel, Method, Pipeline};

/// The paper's 8 LM-Harness analogs (med is held out for Table 15).
pub const PAPER_TASKS: [&str; 8] =
    ["arc_e", "arc_c", "boolq", "hella", "mmlu", "obqa", "rte", "wino"];

/// Smoke/dry-run mode for CI: `HCSMOE_BENCH_SMOKE=1` makes bench targets
/// exercise their harness on synthetic statistics (no artifacts, no PJRT)
/// and exit quickly — catching bench-harness bitrot without paying full
/// bench cost.
pub fn smoke() -> bool {
    std::env::var("HCSMOE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// The artifact-free smoke workload: run the similarity → distance →
/// clustering → merging chain on synthetic grouped statistics, render a
/// table through the report path, and validate every partition. Exercises
/// the same library surface the real bench targets drive.
pub fn run_smoke(target: &str) -> Result<()> {
    use crate::clustering::{hierarchical, Linkage};
    use crate::merging::{merge_cluster, MergeStrategy};
    use crate::similarity::{distance_matrix, Distance};
    use crate::tensor::Tensor;

    let (n, d, m) = (16usize, 32usize, 8usize);
    let groups: Vec<Vec<usize>> = (0..n / 2).map(|g| vec![2 * g, 2 * g + 1]).collect();
    let stats = crate::calib::synthetic::synthetic_grouped(n, d, &groups, 0.01, 42);
    let mut map = std::collections::BTreeMap::new();
    let mut rng = crate::util::Rng::new(7);
    let mut mk = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal() as f32).collect() };
    map.insert("layer00.exp.wg".to_string(), Tensor::new(vec![n, d, m], mk(n * d * m))?);
    map.insert("layer00.exp.wu".to_string(), Tensor::new(vec![n, d, m], mk(n * d * m))?);
    map.insert("layer00.exp.wd".to_string(), Tensor::new(vec![n, m, d], mk(n * m * d))?);
    let weights = crate::weights::Weights::new(map);

    let mut table = crate::report::Table::new(
        &format!("{target} [SMOKE] — synthetic pipeline slice"),
        &["r", "clusters", "merged shape"],
    );
    for r in [8usize, 4] {
        let feats: Vec<Vec<f32>> = (0..n).map(|i| stats.mean_out.row(i).to_vec()).collect();
        let dist = distance_matrix(&feats, Distance::Euclidean);
        let c = hierarchical(&dist, r, Linkage::Average);
        c.validate()?;
        let first = c.groups().into_iter().next().unwrap();
        let merged = merge_cluster(&weights, &stats, 0, &first, MergeStrategy::Frequency)?;
        table.row(vec![
            r.to_string(),
            format!("{:?}", c.groups()),
            format!("{:?}", merged.wg.shape()),
        ]);
    }
    table.print();
    println!("{target}: smoke mode OK (set HCSMOE_BENCH_SMOKE=0 for the full bench)");
    Ok(())
}

/// One serial-vs-parallel measurement row for `BENCH_parallel.json`.
#[derive(Debug, Clone)]
pub struct ParallelBenchRow {
    /// Measured hot path (e.g. `distance_matrix`).
    pub path: String,
    /// Experts in the synthetic workload.
    pub n_experts: usize,
    /// Median wall-clock, single worker.
    pub serial_ms: f64,
    /// Median wall-clock at the benchmarked thread count.
    pub parallel_ms: f64,
}

impl ParallelBenchRow {
    /// Serial-over-parallel wall-clock ratio.
    pub fn speedup(&self) -> f64 {
        if self.parallel_ms > 0.0 {
            self.serial_ms / self.parallel_ms
        } else {
            0.0
        }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write the machine-readable parallel-bench report (hand-rolled JSON; the
/// offline crate set has no serde). Schema is stable: later perf PRs append
/// rows with new `path` names rather than reshaping the file.
pub fn write_parallel_json(
    path: &str,
    threads: usize,
    generator: &str,
    note: &str,
    rows: &[ParallelBenchRow],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"parallel_hot_paths\",\n");
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"generator\": \"{}\",\n", json_escape(generator)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"n_experts\": {}, \"serial_ms\": {:.4}, \
             \"parallel_ms\": {:.4}, \"speedup\": {:.2}}}{comma}\n",
            json_escape(&r.path),
            r.n_experts,
            r.serial_ms,
            r.parallel_ms,
            r.speedup()
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// One tokens/s measurement row for `BENCH_backend.json`: the native
/// backend scoring forward, serial vs parallel.
#[derive(Debug, Clone)]
pub struct BackendBenchRow {
    /// Measured path (e.g. `forward_logits`).
    pub path: String,
    /// Experts per layer of the measured model.
    pub n_experts: usize,
    /// Tokens scored per forward call.
    pub tokens: usize,
    /// Median wall-clock per call, single worker thread.
    pub serial_ms: f64,
    /// Median wall-clock per call at the benchmarked thread count.
    pub parallel_ms: f64,
}

impl BackendBenchRow {
    /// Serial throughput in tokens per second.
    pub fn serial_tok_s(&self) -> f64 {
        if self.serial_ms > 0.0 {
            self.tokens as f64 / (self.serial_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Parallel throughput in tokens per second.
    pub fn parallel_tok_s(&self) -> f64 {
        if self.parallel_ms > 0.0 {
            self.tokens as f64 / (self.parallel_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Parallel-over-serial speedup.
    pub fn speedup(&self) -> f64 {
        if self.parallel_ms > 0.0 {
            self.serial_ms / self.parallel_ms
        } else {
            0.0
        }
    }
}

/// One GEMM-kernel comparison row for the `quant_gemm_sweep` section of
/// `BENCH_backend.json`: one (m, k, n) matmul shape — decode (`m = 1`)
/// and prefill (`m` = token block) over expert-shaped weight panels —
/// timed through the scalar reference loop
/// ([`crate::tensor::matmul_reference`]), the cache-blocked tiled kernel
/// ([`crate::tensor::matmul_blocked_with`]) and the int8 folded-scale
/// kernel ([`crate::tensor::matmul_q8_with`]). The tiled and scalar
/// kernels produce bit-identical outputs, so the row isolates pure
/// kernel wall-clock; CI gates tiled ≥ scalar and int8 ≥ tiled
/// (`scripts/check_kernels.sh`).
#[derive(Debug, Clone)]
pub struct QuantGemmRow {
    /// Measured shape label (`decode_gemm` or `prefill_gemm`).
    pub path: String,
    /// Output rows (tokens per call).
    pub m: usize,
    /// Reduction length.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Median wall-clock of the scalar reference kernel (ms).
    pub scalar_ms: f64,
    /// Median wall-clock of the cache-blocked f32 kernel (ms).
    pub tiled_ms: f64,
    /// Median wall-clock of the int8 folded-scale kernel (ms).
    pub int8_ms: f64,
}

impl QuantGemmRow {
    /// Scalar-over-tiled wall-clock ratio (> 1 means tiling wins).
    pub fn tiled_speedup(&self) -> f64 {
        if self.tiled_ms > 0.0 {
            self.scalar_ms / self.tiled_ms
        } else {
            0.0
        }
    }

    /// Scalar-over-int8 wall-clock ratio (> 1 means int8 beats scalar).
    pub fn int8_speedup(&self) -> f64 {
        if self.int8_ms > 0.0 {
            self.scalar_ms / self.int8_ms
        } else {
            0.0
        }
    }
}

/// Write the machine-readable native-backend throughput report
/// (`BENCH_backend.json`). Hand-rolled JSON like
/// [`write_parallel_json`]; the schema is stable — later PRs append rows
/// with new `path` names rather than reshaping the file. The
/// `quant_gemm_sweep` section compares the scalar reference GEMM against
/// the cache-blocked tiled kernel and the int8 folded-scale kernel at
/// decode and prefill shapes (CI asserts tiled ≥ scalar and int8 ≥ tiled
/// via `scripts/check_kernels.sh`).
pub fn write_backend_json(
    path: &str,
    threads: usize,
    generator: &str,
    note: &str,
    rows: &[BackendBenchRow],
    quant_rows: &[QuantGemmRow],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"native_backend\",\n");
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"generator\": \"{}\",\n", json_escape(generator)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"n_experts\": {}, \"tokens\": {}, \
             \"serial_ms\": {:.4}, \"parallel_ms\": {:.4}, \
             \"serial_tok_s\": {:.1}, \"parallel_tok_s\": {:.1}, \"speedup\": {:.2}}}{comma}\n",
            json_escape(&r.path),
            r.n_experts,
            r.tokens,
            r.serial_ms,
            r.parallel_ms,
            r.serial_tok_s(),
            r.parallel_tok_s(),
            r.speedup()
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"quant_gemm_sweep\": [\n");
    for (i, r) in quant_rows.iter().enumerate() {
        let comma = if i + 1 < quant_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \
             \"scalar_ms\": {:.4}, \"tiled_ms\": {:.4}, \"int8_ms\": {:.4}, \
             \"tiled_speedup\": {:.3}, \"int8_speedup\": {:.3}}}{comma}\n",
            json_escape(&r.path),
            r.m,
            r.k,
            r.n,
            r.scalar_ms,
            r.tiled_ms,
            r.int8_ms,
            r.tiled_speedup(),
            r.int8_speedup()
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// One generation-throughput row for `BENCH_generate.json`: tokens/s of
/// the autoregressive decode loop, KV-cached vs uncached re-forward,
/// serial vs parallel, full vs compact expert layout.
#[derive(Debug, Clone)]
pub struct GenerateBenchRow {
    /// Measured path: `decode_cached` (run_prefill + run_decode) or
    /// `decode_uncached` (full re-forward over the prefix per token).
    pub path: String,
    /// Expert layout: `full` (n_exp slots) or `compact` (r slots + remap).
    pub variant: String,
    /// Physical expert slots of the measured layout.
    pub n_slots: usize,
    /// Prompt tokens prefilled before decoding.
    pub prompt_tokens: usize,
    /// Tokens decoded per measured run.
    pub decode_tokens: usize,
    /// Median wall-clock of the decode loop, single worker thread.
    pub serial_ms: f64,
    /// Median wall-clock of the decode loop at the benchmarked thread
    /// count.
    pub parallel_ms: f64,
}

impl GenerateBenchRow {
    /// Serial decode throughput in tokens per second.
    pub fn serial_tok_s(&self) -> f64 {
        if self.serial_ms > 0.0 {
            self.decode_tokens as f64 / (self.serial_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Parallel decode throughput in tokens per second.
    pub fn parallel_tok_s(&self) -> f64 {
        if self.parallel_ms > 0.0 {
            self.decode_tokens as f64 / (self.parallel_ms / 1e3)
        } else {
            0.0
        }
    }
}

/// One batched-continuous-decode measurement row for the
/// `decode_batch_sweep` section of `BENCH_generate.json`: `batch`
/// sequences are each advanced `decode_tokens` steps, once through a
/// per-sequence `run_decode` loop (the pre-batching executor) and once
/// through `run_decode_batch` (one call per step advancing all
/// sequences). The two paths produce bit-identical logits; the sweep
/// measures what the batching buys in wall-clock.
#[derive(Debug, Clone)]
pub struct DecodeBatchRow {
    /// Concurrent sequences advanced per step.
    pub batch: usize,
    /// Prompt tokens prefilled per sequence (untimed).
    pub prompt_tokens: usize,
    /// Decode steps per sequence in the timed region.
    pub decode_tokens: usize,
    /// Median wall-clock of the per-sequence `run_decode` loop.
    pub seq_ms: f64,
    /// Median wall-clock of the batched `run_decode_batch` loop.
    pub batch_ms: f64,
}

impl DecodeBatchRow {
    /// Total tokens advanced in the timed region (`batch × decode_tokens`).
    pub fn total_tokens(&self) -> usize {
        self.batch * self.decode_tokens
    }

    /// Per-sequence-loop throughput in tokens per second.
    pub fn seq_tok_s(&self) -> f64 {
        if self.seq_ms > 0.0 {
            self.total_tokens() as f64 / (self.seq_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Batched throughput in tokens per second.
    pub fn batch_tok_s(&self) -> f64 {
        if self.batch_ms > 0.0 {
            self.total_tokens() as f64 / (self.batch_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Sequential-over-batched wall-clock ratio (> 1 means batching wins).
    pub fn speedup(&self) -> f64 {
        if self.batch_ms > 0.0 {
            self.seq_ms / self.batch_ms
        } else {
            0.0
        }
    }
}

/// One flat-vs-paged KV-cache measurement row for the `kv_cache_sweep`
/// section of `BENCH_generate.json`: one sequence is prefilled (untimed)
/// and decoded `decode_tokens` steps against either the flat per-layer
/// `Vec` cache or the paged block pool, sampling `capacity_bytes` after
/// every step of every iteration. `reallocs` counts contract-violating
/// capacity events: for the flat cache, any change (a `Vec` regrowth is a
/// full-buffer copy); for the paged cache, any change other than growth by
/// exactly one block (single-block arena allocation is the only copy-free
/// shape this workload can produce). CI gates every row at 0, pinning the
/// steady-state no-realloc property on both paths.
#[derive(Debug, Clone)]
pub struct KvCacheBenchRow {
    /// Measured path: `decode_flat` or `decode_paged`.
    pub path: String,
    /// Decode steps in the timed region.
    pub decode_tokens: usize,
    /// Median wall-clock of the decode loop in milliseconds.
    pub ms: f64,
    /// Buffer-regrowth copy events observed during the decode loop.
    pub reallocs: usize,
}

impl KvCacheBenchRow {
    /// Decode throughput in tokens per second.
    pub fn tok_s(&self) -> f64 {
        if self.ms > 0.0 {
            self.decode_tokens as f64 / (self.ms / 1e3)
        } else {
            0.0
        }
    }
}

/// One mixed-load scheduler measurement row for the `sched_sweep` section
/// of `BENCH_generate.json`: a live server is driven with long-prompt
/// Batch-class jobs plus short Interactive requests, once with chunked
/// prefill off (`chunk = 0` in the row ⇒ whole-prompt prefills) and once
/// with a chunk size set. The inter-token latency quantiles come from the
/// server's Interactive-only [`crate::serving::LatencyHisto`]; CI asserts
/// the chunked p99 is no worse than unchunked (`scripts/check_sched.sh`).
#[derive(Debug, Clone)]
pub struct SchedBenchRow {
    /// Measured mode: `unchunked` or `chunked`.
    pub mode: String,
    /// Prefill chunk size in prompt tokens (0 = whole-prompt prefills).
    pub chunk: usize,
    /// Interactive requests completed.
    pub interactive: usize,
    /// Batch-class jobs completed.
    pub batch_jobs: usize,
    /// Median Interactive inter-token latency (ms).
    pub p50_ms: f64,
    /// 99th-percentile Interactive inter-token latency (ms).
    pub p99_ms: f64,
    /// Batch-class preemptions (swap-outs) the run performed.
    pub preemptions: u64,
    /// Prefills that were actually split across chunks.
    pub chunked_prefills: u64,
}

/// One speculative-decoding measurement row for the `spec_decode_sweep`
/// section of `BENCH_generate.json`: the same prompt is decoded once
/// plainly on the full model and once speculatively
/// ([`crate::generate::speculative`]) with the compact merged variant
/// drafting `draft_k` tokens per verify round. The two runs are
/// bit-identical by construction — `exact` records the comparison so CI
/// can gate on it (`scripts/check_spec_decode.sh`), and the interesting
/// numbers are the acceptance rate and how many full-model forwards the
/// drafter saved.
#[derive(Debug, Clone)]
pub struct SpecDecodeRow {
    /// Draft depth (tokens proposed per verify round).
    pub draft_k: usize,
    /// Tokens the run emitted (identical between the two paths).
    pub tokens: usize,
    /// Draft tokens proposed across the run.
    pub drafted: usize,
    /// Draft tokens the verifier's own sampling accepted.
    pub accepted: usize,
    /// Full-model verify forwards the speculative run executed (the
    /// plain run uses one forward per emitted token).
    pub verify_steps: usize,
    /// Median wall-clock of the plain decode loop (ms).
    pub plain_ms: f64,
    /// Median wall-clock of the speculative draft+verify loop (ms).
    pub spec_ms: f64,
    /// Whether the speculative token stream equalled the plain one.
    pub exact: bool,
}

impl SpecDecodeRow {
    /// Fraction of proposed drafts accepted (0 when nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted > 0 {
            self.accepted as f64 / self.drafted as f64
        } else {
            0.0
        }
    }

    /// Plain decode throughput in tokens per second.
    pub fn plain_tok_s(&self) -> f64 {
        if self.plain_ms > 0.0 {
            self.tokens as f64 / (self.plain_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Speculative decode throughput in tokens per second.
    pub fn spec_tok_s(&self) -> f64 {
        if self.spec_ms > 0.0 {
            self.tokens as f64 / (self.spec_ms / 1e3)
        } else {
            0.0
        }
    }
}

/// One adaptive-serving measurement row for the `adapt_sweep` section of
/// `BENCH_generate.json`: an adaptively-compressing server
/// ([`crate::serving::AdaptSpec`]) is driven with a steady request load
/// through three phases — `before` (original variant, window still
/// filling), `during` (background recompression in flight on the worker
/// thread) and `after` (the hot-swapped compact variant) — measuring
/// served throughput per phase. `swaps` counts hot swaps observed by the
/// end of the phase and `entropy_bits` is the layer-0 dispatch entropy of
/// the most recent routing window. CI asserts the serving path never
/// stalls behind the rebuild (`during` ≥ a fraction of `before`) and that
/// a swap actually landed (`scripts/check_adapt.sh`).
#[derive(Debug, Clone)]
pub struct AdaptBenchRow {
    /// Measured phase: `before`, `during` or `after` the first hot swap.
    pub phase: String,
    /// Generation requests completed in the phase.
    pub requests: usize,
    /// Tokens emitted in the phase.
    pub tokens: usize,
    /// Wall-clock of the phase (ms).
    pub ms: f64,
    /// Hot swaps the server had performed by the end of the phase.
    pub swaps: u64,
    /// Layer-0 dispatch entropy (bits) of the latest routing window.
    pub entropy_bits: f64,
}

impl AdaptBenchRow {
    /// Served throughput in tokens per second.
    pub fn tok_s(&self) -> f64 {
        if self.ms > 0.0 {
            self.tokens as f64 / (self.ms / 1e3)
        } else {
            0.0
        }
    }
}

/// Write the machine-readable generation-throughput report
/// (`BENCH_generate.json`). Hand-rolled JSON like [`write_parallel_json`];
/// the schema is stable — later PRs append rows with new `path`/`variant`
/// names rather than reshaping the file. Comparing `decode_cached` vs
/// `decode_uncached` rows at the same (variant, decode_tokens) shows the
/// O(t) vs O(t²) gap the KV cache buys; the `decode_batch_sweep` section
/// compares batched continuous decode against the per-sequence loop at
/// B ∈ {1, 2, 4, 8} (CI asserts batched ≥ sequential at B = 4); the
/// `kv_cache_sweep` section compares flat vs paged caches and pins the
/// zero-realloc steady state (CI gates `reallocs` at 0 per row); the
/// `sched_sweep` section compares chunked vs unchunked prefill under a
/// mixed Interactive+Batch load (CI asserts chunked p99 inter-token
/// latency ≤ unchunked); the `spec_decode_sweep` section compares plain
/// decode against speculative draft-k/verify-1 with a compact merged
/// drafter (CI asserts `exact` on every row and acceptance > 0 for
/// k ≥ 2 via `scripts/check_spec_decode.sh`); the `adapt_sweep` section
/// measures served throughput before/during/after a live
/// recompression + hot swap (CI asserts the rebuild never stalls serving
/// and that a swap landed via `scripts/check_adapt.sh`).
pub fn write_generate_json(
    path: &str,
    threads: usize,
    generator: &str,
    note: &str,
    rows: &[GenerateBenchRow],
    batch_rows: &[DecodeBatchRow],
    kv_rows: &[KvCacheBenchRow],
    sched_rows: &[SchedBenchRow],
    spec_rows: &[SpecDecodeRow],
    adapt_rows: &[AdaptBenchRow],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"generate\",\n");
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"generator\": \"{}\",\n", json_escape(generator)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"variant\": \"{}\", \"n_slots\": {}, \
             \"prompt_tokens\": {}, \"decode_tokens\": {}, \
             \"serial_ms\": {:.4}, \"parallel_ms\": {:.4}, \
             \"serial_tok_s\": {:.1}, \"parallel_tok_s\": {:.1}}}{comma}\n",
            json_escape(&r.path),
            json_escape(&r.variant),
            r.n_slots,
            r.prompt_tokens,
            r.decode_tokens,
            r.serial_ms,
            r.parallel_ms,
            r.serial_tok_s(),
            r.parallel_tok_s()
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"decode_batch_sweep\": [\n");
    for (i, r) in batch_rows.iter().enumerate() {
        let comma = if i + 1 < batch_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"batch\": {}, \"prompt_tokens\": {}, \"decode_tokens\": {}, \
             \"seq_ms\": {:.4}, \"batch_ms\": {:.4}, \
             \"seq_tok_s\": {:.1}, \"batch_tok_s\": {:.1}, \"speedup\": {:.3}}}{comma}\n",
            r.batch,
            r.prompt_tokens,
            r.decode_tokens,
            r.seq_ms,
            r.batch_ms,
            r.seq_tok_s(),
            r.batch_tok_s(),
            r.speedup()
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"kv_cache_sweep\": [\n");
    for (i, r) in kv_rows.iter().enumerate() {
        let comma = if i + 1 < kv_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"path\": \"{}\", \"decode_tokens\": {}, \"ms\": {:.4}, \
             \"tok_s\": {:.1}, \"reallocs\": {}}}{comma}\n",
            json_escape(&r.path),
            r.decode_tokens,
            r.ms,
            r.tok_s(),
            r.reallocs
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"sched_sweep\": [\n");
    for (i, r) in sched_rows.iter().enumerate() {
        let comma = if i + 1 < sched_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"chunk\": {}, \"interactive\": {}, \
             \"batch_jobs\": {}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
             \"preemptions\": {}, \"chunked_prefills\": {}}}{comma}\n",
            json_escape(&r.mode),
            r.chunk,
            r.interactive,
            r.batch_jobs,
            r.p50_ms,
            r.p99_ms,
            r.preemptions,
            r.chunked_prefills
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"spec_decode_sweep\": [\n");
    for (i, r) in spec_rows.iter().enumerate() {
        let comma = if i + 1 < spec_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"draft_k\": {}, \"tokens\": {}, \"drafted\": {}, \
             \"accepted\": {}, \"verify_steps\": {}, \
             \"acceptance_rate\": {:.4}, \"plain_ms\": {:.4}, \
             \"spec_ms\": {:.4}, \"plain_tok_s\": {:.1}, \
             \"spec_tok_s\": {:.1}, \"exact\": {}}}{comma}\n",
            r.draft_k,
            r.tokens,
            r.drafted,
            r.accepted,
            r.verify_steps,
            r.acceptance_rate(),
            r.plain_ms,
            r.spec_ms,
            r.plain_tok_s(),
            r.spec_tok_s(),
            r.exact
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"adapt_sweep\": [\n");
    for (i, r) in adapt_rows.iter().enumerate() {
        let comma = if i + 1 < adapt_rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"phase\": \"{}\", \"requests\": {}, \"tokens\": {}, \
             \"ms\": {:.4}, \"tok_s\": {:.1}, \"swaps\": {}, \
             \"entropy_bits\": {:.4}}}{comma}\n",
            json_escape(&r.phase),
            r.requests,
            r.tokens,
            r.ms,
            r.tok_s(),
            r.swaps,
            r.entropy_bits
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// One replica-scale-out measurement row for `BENCH_serve.json`: a
/// [`crate::serving::Dispatcher`] fleet is driven with bursty arrivals
/// of mixed-length prompts (the serve_traffic bench), and the row
/// records what the fleet delivered at that replica count. `goodput`
/// is completed streams per wall-clock second; `dropped` counts
/// requests that ended in an error or a stream/reply token mismatch —
/// `scripts/check_serve.sh` gates dropped at 0 and requires 2-replica
/// goodput ≥ 1-replica.
#[derive(Debug, Clone)]
pub struct ServeBenchRow {
    /// Executor replicas behind the dispatcher.
    pub replicas: usize,
    /// Streams that completed successfully.
    pub completed: usize,
    /// Streams that errored or whose live stream diverged from the reply.
    pub dropped: usize,
    /// Tokens generated across every completed stream.
    pub tokens: u64,
    /// Wall-clock of the whole traffic run (seconds).
    pub wall_s: f64,
    /// Median request completion latency (ms, client-observed).
    pub p50_ms: f64,
    /// 99th-percentile request completion latency (ms, client-observed).
    pub p99_ms: f64,
}

impl ServeBenchRow {
    /// Completed streams per wall-clock second.
    pub fn goodput(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Generated tokens per wall-clock second.
    pub fn tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Write the machine-readable replica-scale-out report
/// (`BENCH_serve.json`). Hand-rolled JSON like [`write_parallel_json`];
/// one row per replica count, same traffic pattern each — so the
/// goodput column is directly comparable across rows.
pub fn write_serve_json(
    path: &str,
    threads: usize,
    generator: &str,
    note: &str,
    rows: &[ServeBenchRow],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve\",\n");
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"generator\": \"{}\",\n", json_escape(generator)));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"replicas\": {}, \"completed\": {}, \"dropped\": {}, \
             \"tokens\": {}, \"wall_s\": {:.4}, \"p50_ms\": {:.4}, \
             \"p99_ms\": {:.4}, \"goodput\": {:.4}, \"tok_s\": {:.1}}}{comma}\n",
            r.replicas,
            r.completed,
            r.dropped,
            r.tokens,
            r.wall_s,
            r.p50_ms,
            r.p99_ms,
            r.goodput(),
            r.tok_s()
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// The 4-task subset used by the paper's ablation tables (Tables 4, 5).
pub const ABLATION_TASKS: [&str; 4] = ["arc_c", "boolq", "obqa", "rte"];

/// A model + memoised calibration stats + the on-disk results cache.
pub struct Lab {
    /// The loaded model under study.
    pub ctx: ModelContext,
    stats: RefCell<HashMap<String, Rc<CalibStats>>>,
    cache_dir: std::path::PathBuf,
}

impl Lab {
    /// Open a lab on the discovered (or synthesized) artifact set.
    pub fn new(model: &str) -> Result<Self> {
        Self::at(ensure_artifacts()?, model)
    }

    /// Open a lab on an explicit artifact directory.
    pub fn at(arts: Artifacts, model: &str) -> Result<Self> {
        let ctx = ModelContext::load(&arts, model)
            .context("loading model context (artifacts present but unreadable?)")?;
        let cache_dir = arts.root.join("cache");
        std::fs::create_dir_all(&cache_dir)?;
        Ok(Self { ctx, stats: Default::default(), cache_dir })
    }

    /// Calibration statistics for `domain`, memoised per lab.
    pub fn stats(&self, domain: &str) -> Result<Rc<CalibStats>> {
        if let Some(s) = self.stats.borrow().get(domain) {
            return Ok(Rc::clone(s));
        }
        let s = Rc::new(self.ctx.calibrate(domain)?);
        self.stats.borrow_mut().insert(domain.to_string(), Rc::clone(&s));
        Ok(s)
    }

    fn cache_key(&self, label: &str, r: usize, domain: &str, tasks: &[&str]) -> String {
        let safe: String = label
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '-' { c } else { '_' })
            .collect();
        format!("{}_{safe}_r{r}_{domain}_{}", self.ctx.cfg.name, tasks.join("-"))
    }

    fn cache_read(&self, key: &str) -> Option<Vec<f64>> {
        let path = self.cache_dir.join(format!("{key}.txt"));
        let text = std::fs::read_to_string(path).ok()?;
        let vals: Vec<f64> = text
            .split_whitespace()
            .map(|s| s.parse().ok())
            .collect::<Option<_>>()?;
        Some(vals)
    }

    fn cache_write(&self, key: &str, vals: &[f64]) {
        let path = self.cache_dir.join(format!("{key}.txt"));
        let text: Vec<String> = vals.iter().map(|v| format!("{v:.6}")).collect();
        let _ = std::fs::write(path, text.join(" "));
    }

    /// Accuracy of the ORIGINAL model on `tasks` (cached).
    pub fn eval_original(&self, tasks: &[&str]) -> Result<(Vec<f64>, f64)> {
        let key = self.cache_key("original", self.ctx.cfg.n_exp, "-", tasks);
        if let Some(v) = self.cache_read(&key) {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            return Ok((v, avg));
        }
        let ev = Evaluator::new(&self.ctx)?;
        let model = self.ctx.load_original()?;
        let mut scores = Vec::new();
        for t in tasks {
            scores.push(ev.accuracy(&model, t)?);
        }
        self.cache_write(&key, &scores);
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        Ok((scores, avg))
    }

    /// Compress with `method` at target `r` (calibrated on `domain`) and
    /// score `tasks`. Cached on disk by (label, r, domain, tasks).
    pub fn eval_method(
        &self,
        method: Method,
        r: usize,
        domain: &str,
        tasks: &[&str],
    ) -> Result<(Vec<f64>, f64)> {
        let label = method.label();
        let key = self.cache_key(&label, r, domain, tasks);
        if let Some(v) = self.cache_read(&key) {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            return Ok((v, avg));
        }
        let cm = self.compress(method, r, domain)?;
        let scores = self.eval_compressed(&cm, tasks)?;
        self.cache_write(&key, &scores);
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        Ok((scores, avg))
    }

    /// Compress only (no cache) — for quality/efficiency analyses.
    pub fn compress(&self, method: Method, r: usize, domain: &str) -> Result<CompressedModel> {
        let stats = self.stats(domain)?;
        let plan = Pipeline::new(method).plan(&self.ctx, &stats, r)?;
        plan.apply(&self.ctx, &stats)
    }

    /// Score an already-compressed model (no cache).
    pub fn eval_compressed(&self, cm: &CompressedModel, tasks: &[&str]) -> Result<Vec<f64>> {
        let ev = Evaluator::new(&self.ctx)?;
        let model = cm.load(&self.ctx)?;
        tasks.iter().map(|t| ev.accuracy(&model, t)).collect()
    }

    /// P/R/F1 on one task for a method (Table 15).
    pub fn prf_method(&self, method: Method, r: usize, domain: &str, task: &str) -> Result<Prf> {
        let cm = self.compress(method, r, domain)?;
        let ev = Evaluator::new(&self.ctx)?;
        let model = cm.load(&self.ctx)?;
        ev.prf(&model, task)
    }

    /// P/R/F1 of the original model on one task.
    pub fn prf_original(&self, task: &str) -> Result<Prf> {
        let ev = Evaluator::new(&self.ctx)?;
        let model = self.ctx.load_original()?;
        ev.prf(&model, task)
    }
}

/// The standard method roster of Tables 2-3 (with model-appropriate O-prune
/// sampling budgets).
pub fn paper_methods(n_exp: usize, r: usize) -> Vec<Method> {
    use crate::clustering::Linkage;
    use crate::merging::MergeStrategy;
    use crate::similarity::Metric;
    let samples = if crate::pruning::n_choose_r(n_exp, r) <= 20_000 { 20_000 } else { 5_000 };
    vec![
        Method::OPrune { samples, seed: 42 },
        Method::FPrune,
        Method::SPrune,
        Method::MSmoe,
        Method::HcSmoe {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: MergeStrategy::Frequency,
        },
        Method::HcSmoe {
            linkage: Linkage::Single,
            metric: Metric::ExpertOutput,
            merge: MergeStrategy::Frequency,
        },
    ]
}

/// Standard table header for an 8-task comparison.
pub fn task_table(title: &str, tasks: &[&str]) -> crate::report::Table {
    let mut headers = vec!["Method".to_string(), "r".to_string()];
    headers.extend(tasks.iter().map(|s| s.to_string()));
    headers.push("Average".to_string());
    crate::report::Table::new(title, &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>())
}

/// Push one scored row.
pub fn push_row(
    table: &mut crate::report::Table,
    label: &str,
    r: impl std::fmt::Display,
    scores: &[f64],
    avg: f64,
) {
    let mut cells = vec![label.to_string(), r.to_string()];
    cells.extend(scores.iter().map(|s| format!("{s:.4}")));
    cells.push(format!("{avg:.4}"));
    table.row(cells);
}
