//! In-process artifact synthesis: a small deterministic checkpoint +
//! dataset set so the full compress → eval → serve flow runs with **zero
//! Python, PJRT or network** in the loop.
//!
//! `python/compile/aot.py` produces the real trained artifacts; this
//! module produces structurally identical ones (same `manifest.txt` /
//! `.cfg` keys, same HCWT/HCEV/HCTS bytes — see `FORMATS.md`) at toy
//! scale with random-init weights, purely from a seed. Scores are
//! near-chance (nothing is trained), but every pipeline stage — the
//! calibration pass, clustering, merging, pruning, zero-shot scoring,
//! perplexity, serving — executes for real on the native backend, which
//! is exactly what CI's `backend-e2e` smoke and the offline examples
//! need.
//!
//! [`ensure_artifacts`] is the entry point the bench harness and examples
//! use: real artifacts win when present; otherwise a synthetic set is
//! generated once (default `./artifacts-synth`, kept separate from the
//! `./artifacts` directory `make artifacts` owns).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use byteorder::{LittleEndian, WriteBytesExt};

use crate::config::{Artifacts, ModelCfg};
use crate::util::Rng;
use crate::weights::Weights;

/// Seed for the default synthetic artifact set (checkpoint + datasets).
pub const SYNTH_SEED: u64 = 0x5EED_AB1E;

/// Directory used when no real artifacts exist and `HCSMOE_ARTIFACTS` is
/// unset.
pub const SYNTH_DIR: &str = "artifacts-synth";

/// Eval batch shape of the synthetic manifest.
pub const SYNTH_EVAL: (usize, usize) = (8, 32);
/// Calibration batch shape of the synthetic manifest.
pub const SYNTH_CALIB: (usize, usize) = (4, 64);
/// Subsampled-statistics sizes (t_sub, t_act) of the synthetic manifest.
pub const SYNTH_SUB: (usize, usize) = (64, 32);
/// Items per synthetic benchmark task.
pub const SYNTH_N_ITEMS: usize = 24;

/// The benchmark tasks a synthetic artifact set ships (the paper's 8 plus
/// the held-out `med` task of Table 15).
pub const SYNTH_TASKS: [&str; 9] =
    ["arc_e", "arc_c", "boolq", "hella", "mmlu", "obqa", "rte", "wino", "med"];

/// Calibration/analysis token-stream domains a synthetic set ships.
pub const SYNTH_DOMAINS: [&str; 5] = ["general", "math", "code", "med", "ppl_heldout"];

/// Toy-scale configs for the three simulated families (same family names
/// as the real artifacts so every hardcoded `"qwensim"` call site works).
fn synth_cfgs() -> Vec<(ModelCfg, Vec<usize>)> {
    let base = ModelCfg {
        name: String::new(),
        n_layer: 2,
        d: 32,
        m: 32,
        n_exp: 8,
        k: 2,
        heads: 2,
        vocab: 96,
        t_max: 64,
        shared: false,
        m_shared: 32,
        // roomy capacity: synthetic routers are near-uniform, and a drop-free
        // dispatch keeps the full/compact layouts and the serving batcher in
        // numerical agreement (capacity-drop semantics are pinned separately
        // by rust/tests/backend_native.rs)
        cap_factor: 4.0,
        block_c: 8,
    };
    let qwensim = ModelCfg { name: "qwensim".into(), ..base.clone() };
    let mixsim = ModelCfg { name: "mixsim".into(), n_exp: 4, m: 64, ..base.clone() };
    let dssim = ModelCfg { name: "dssim".into(), m: 16, shared: true, ..base };
    vec![
        (qwensim, vec![6, 4, 3, 2]),
        (mixsim, vec![3, 2]),
        (dssim, vec![6, 4]),
    ]
}

/// Use real artifacts when present, else synthesize a deterministic set.
///
/// Resolution order: the `HCSMOE_ARTIFACTS` / `./artifacts` location from
/// [`Artifacts::discover`] wins if its `manifest.txt` exists; otherwise a
/// synthetic set is generated (once) into `HCSMOE_ARTIFACTS` if set, else
/// [`SYNTH_DIR`].
pub fn ensure_artifacts() -> Result<Artifacts> {
    let arts = Artifacts::discover();
    if arts.root.join("manifest.txt").exists() {
        return Ok(arts);
    }
    let root = if std::env::var_os("HCSMOE_ARTIFACTS").is_some() {
        arts.root.clone()
    } else {
        PathBuf::from(SYNTH_DIR)
    };
    let arts = Artifacts::new(&root);
    if !arts.root.join("manifest.txt").exists() {
        synthesize_artifacts(&root, SYNTH_SEED)?;
        eprintln!(
            "hc-smoe: no AOT artifacts found; synthesized an offline set at {}",
            root.display()
        );
    }
    Ok(arts)
}

/// Write a complete synthetic artifact set under `root`: `manifest.txt`,
/// a `.cfg` + `.hcwt` checkpoint per model family, HCEV benchmarks under
/// `eval/` and HCTS token streams under `calib/`. Fully deterministic in
/// `seed`.
pub fn synthesize_artifacts<P: AsRef<Path>>(root: P, seed: u64) -> Result<()> {
    let root = root.as_ref();
    std::fs::create_dir_all(root.join("eval"))?;
    std::fs::create_dir_all(root.join("calib"))?;
    let cfgs = synth_cfgs();
    let (eval_b, eval_t) = SYNTH_EVAL;
    let (calib_b, calib_t) = SYNTH_CALIB;
    let (t_sub, t_act) = SYNTH_SUB;

    // per-model config + checkpoint
    for (i, (cfg, _)) in cfgs.iter().enumerate() {
        std::fs::write(root.join(format!("{}.cfg", cfg.name)), cfg_kv(cfg))?;
        let w = Weights::synthesize(cfg, seed ^ (i as u64 + 1));
        w.save(root.join(format!("{}.hcwt", cfg.name)))
            .with_context(|| format!("writing synthetic checkpoint for {}", cfg.name))?;
    }

    // benchmarks (HCEV) + token streams (HCTS); every model shares the
    // vocabulary layout, so one dataset set serves all three families.
    let vocab = cfgs[0].0.vocab as i32;
    for (ti, task) in SYNTH_TASKS.iter().enumerate() {
        let n_choices = match *task {
            "boolq" | "rte" | "wino" => 2,
            _ => 4,
        };
        let mut rng = Rng::new(seed ^ 0xE7A1 ^ ((ti as u64 + 1) << 8));
        write_benchmark(
            &root.join(format!("eval/{task}.bin")),
            SYNTH_N_ITEMS,
            n_choices,
            vocab,
            &mut rng,
        )?;
    }
    for (di, domain) in SYNTH_DOMAINS.iter().enumerate() {
        let n_tokens = if *domain == "ppl_heldout" {
            4 * eval_b * eval_t
        } else {
            4 * calib_b * calib_t
        };
        let mut rng = Rng::new(seed ^ 0x70CE ^ ((di as u64 + 1) << 16));
        write_stream(&root.join(format!("calib/{domain}.bin")), n_tokens, vocab, &mut rng)?;
    }

    // manifest LAST: its presence is what ensure_artifacts treats as "the
    // set is complete", so an interrupted synthesis is retried rather than
    // half-used.
    let mut manifest = String::new();
    manifest.push_str("# synthetic offline artifact set (bench_support::synth)\n");
    manifest.push_str("synthetic = 1\n");
    manifest.push_str(&format!("eval_b = {eval_b}\neval_t = {eval_t}\n"));
    manifest.push_str(&format!("calib_b = {calib_b}\ncalib_t = {calib_t}\n"));
    manifest.push_str(&format!("t_sub = {t_sub}\nt_act = {t_act}\n"));
    manifest.push_str(&format!("n_items = {SYNTH_N_ITEMS}\n"));
    let model_names: Vec<&str> = cfgs.iter().map(|(c, _)| c.name.as_str()).collect();
    manifest.push_str(&format!("models = {}\n", model_names.join(",")));
    manifest.push_str(&format!("tasks = {}\n", SYNTH_TASKS.join(",")));
    for (cfg, reds) in &cfgs {
        let reds: Vec<String> = reds.iter().map(|r| r.to_string()).collect();
        manifest.push_str(&format!("reductions_{} = {}\n", cfg.name, reds.join(",")));
    }
    std::fs::write(root.join("manifest.txt"), manifest)?;
    Ok(())
}

/// `key = value` serialisation of a model config (mirror of
/// `python/compile/model.py::ModelCfg.to_kv`).
fn cfg_kv(cfg: &ModelCfg) -> String {
    format!(
        "name = {}\nn_layer = {}\nd = {}\nm = {}\nn_exp = {}\nk = {}\nheads = {}\n\
         vocab = {}\nt_max = {}\nshared = {}\nm_shared = {}\ncap_factor = {}\n\
         block_c = {}\n",
        cfg.name,
        cfg.n_layer,
        cfg.d,
        cfg.m,
        cfg.n_exp,
        cfg.k,
        cfg.heads,
        cfg.vocab,
        cfg.t_max,
        u8::from(cfg.shared),
        cfg.m_shared,
        cfg.cap_factor,
        cfg.block_c
    )
}

/// A token drawn from the "content" classes (everything above the control
/// tokens, inside the synthetic vocabulary).
fn content_token(rng: &mut Rng, vocab: i32) -> i32 {
    16 + rng.below((vocab - 16) as usize) as i32
}

/// Write one HCEV multiple-choice benchmark (see `FORMATS.md` §HCEV).
fn write_benchmark(
    path: &Path,
    n_items: usize,
    n_choices: usize,
    vocab: i32,
    rng: &mut Rng,
) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(b"HCEV")?;
    w.write_u32::<LittleEndian>(1)?;
    w.write_u32::<LittleEndian>(n_items as u32)?;
    w.write_u32::<LittleEndian>(n_choices as u32)?;
    for _ in 0..n_items {
        // prompt: [BOS, Q, <3 content tokens>, SEP, A]
        let mut prompt = vec![crate::data::vocab::BOS, crate::data::vocab::Q];
        for _ in 0..3 {
            prompt.push(content_token(rng, vocab));
        }
        prompt.push(crate::data::vocab::SEP);
        prompt.push(crate::data::vocab::A);
        let answer = rng.below(n_choices);
        w.write_u32::<LittleEndian>(prompt.len() as u32)?;
        for &tok in &prompt {
            w.write_i32::<LittleEndian>(tok)?;
        }
        w.write_u32::<LittleEndian>(answer as u32)?;
        for _ in 0..n_choices {
            let clen = 1 + rng.below(2);
            w.write_u32::<LittleEndian>(clen as u32)?;
            for _ in 0..clen {
                w.write_i32::<LittleEndian>(content_token(rng, vocab))?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Write one HCTS token stream (see `FORMATS.md` §HCTS).
fn write_stream(path: &Path, n_tokens: usize, vocab: i32, rng: &mut Rng) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(b"HCTS")?;
    w.write_u32::<LittleEndian>(1)?;
    w.write_u32::<LittleEndian>(n_tokens as u32)?;
    for _ in 0..n_tokens {
        w.write_i32::<LittleEndian>(content_token(rng, vocab))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Benchmark, TokenStream};

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("hcsmoe_synth_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn synthetic_set_loads_end_to_end() {
        let dir = tmpdir("load");
        synthesize_artifacts(&dir, 1).unwrap();
        let arts = Artifacts::new(&dir);
        let manifest = arts.manifest().unwrap();
        assert_eq!(manifest.models, vec!["qwensim", "mixsim", "dssim"]);
        assert_eq!(manifest.eval_b, SYNTH_EVAL.0);
        for m in &manifest.models {
            let cfg = arts.model_cfg(m).unwrap();
            let w = Weights::load(arts.weights_path(m)).unwrap();
            assert_eq!(w.n_experts().unwrap(), cfg.n_exp);
            assert_eq!(w.n_layers(), cfg.n_layer);
        }
        for task in SYNTH_TASKS {
            let b = Benchmark::load(arts.benchmark(task)).unwrap();
            assert_eq!(b.items.len(), SYNTH_N_ITEMS);
        }
        for domain in SYNTH_DOMAINS {
            let ts = TokenStream::load(arts.calib_tokens_path(domain)).unwrap();
            assert!(!ts.tokens.is_empty());
            assert!(ts.tokens.iter().all(|&t| t >= 0 && t < 96));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synthesis_is_deterministic() {
        let (a, b) = (tmpdir("det_a"), tmpdir("det_b"));
        synthesize_artifacts(&a, 7).unwrap();
        synthesize_artifacts(&b, 7).unwrap();
        for rel in ["manifest.txt", "qwensim.hcwt", "eval/arc_e.bin", "calib/general.bin"] {
            let xa = std::fs::read(a.join(rel)).unwrap();
            let xb = std::fs::read(b.join(rel)).unwrap();
            assert_eq!(xa, xb, "{rel} must be byte-identical across runs");
        }
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
    }
}
