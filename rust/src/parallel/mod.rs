//! Scoped thread-pool primitives with deterministic work splitting.
//!
//! The compression hot paths — per-layer calibration accumulation, the
//! O(E²) similarity distance matrix, agglomerative linkage scans, K-means /
//! FCM assignment sweeps, and the blocked matmul behind the ZipIt/Fix-Dom
//! correlation features — are embarrassingly parallel over disjoint output
//! regions. This module gives them a dependency-free `std::thread::scope`
//! pool with **deterministic** splitting: every parallel variant partitions
//! the output index space, and each element is computed by exactly the
//! expression the serial path uses (same operand order, same reduction
//! order), so results are bit-identical to the serial path at any thread
//! count. `rust/tests/determinism.rs` enforces this property.
//!
//! Thread-count resolution for auto-dispatched paths: the `HCSMOE_THREADS`
//! environment variable if set, else `std::thread::available_parallelism()`
//! clamped to [`MAX_AUTO_THREADS`]. With the `parallel` cargo feature
//! disabled, [`default_threads`] reports 1 and every auto-dispatched path
//! stays on its serial reference implementation.

use std::ops::Range;
use std::sync::OnceLock;

/// Upper bound on auto-detected worker threads; an explicit
/// `HCSMOE_THREADS` may exceed it (useful for oversubscription tests).
pub const MAX_AUTO_THREADS: usize = 64;

/// Element-op count below which auto-dispatched paths stay serial. A scoped
/// spawn costs ~50µs on container hosts (measured); a parallel sweep must
/// amortise several of those to win, which puts the break-even near 10⁶
/// single-f32 operations. Explicit `*_with(threads)` calls bypass this —
/// gates are a wall-clock policy, never a correctness one.
pub const PAR_AUTO_WORK: usize = 1 << 20;

/// Pool size used by auto-dispatched parallel paths (resolved once).
pub fn default_threads() -> usize {
    if !cfg!(feature = "parallel") {
        return 1;
    }
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("HCSMOE_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_AUTO_THREADS)
    })
}

/// Deterministic near-equal split of `0..len` into at most `threads`
/// non-empty contiguous ranges (the first `len % threads` ranges take one
/// extra element). Covers `0..len` exactly, in order.
pub fn split_ranges(len: usize, threads: usize) -> Vec<Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, len);
    let base = len / t;
    let rem = len % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let size = base + usize::from(i < rem);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Map every chunk range of `0..len` (from [`split_ranges`]) through `f`;
/// returns the per-chunk results in range order. The calling thread runs
/// the final chunk itself, so `threads` workers cost only `threads - 1`
/// spawns; a single chunk runs inline with no spawn at all.
pub fn par_map_chunks<T, F>(threads: usize, len: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let mut ranges = split_ranges(len, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    let last = ranges.pop().unwrap();
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges.into_iter().map(|r| s.spawn(move || f(r))).collect();
        let tail = f(last);
        let mut out: Vec<T> = handles
            .into_iter()
            .map(|h| h.join().expect("parallel worker panicked"))
            .collect();
        out.push(tail);
        out
    })
}

/// Split `out` into the per-range mutable chunks induced by
/// [`split_ranges`] over its length and run `f(range_start, chunk)` on
/// scoped threads. Chunks are disjoint, so no synchronisation is needed and
/// writes land exactly where the serial loop would put them.
pub fn par_chunks_mut<T, F>(threads: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let ranges = split_ranges(out.len(), threads);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r.start, out);
        }
        return;
    }
    let f = &f;
    let n_ranges = ranges.len();
    std::thread::scope(|s| {
        let mut rest: &mut [T] = out;
        for (idx, r) in ranges.into_iter().enumerate() {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
            rest = tail;
            if idx + 1 == n_ranges {
                // the calling thread takes the final chunk: one fewer spawn
                f(r.start, head);
            } else {
                s.spawn(move || f(r.start, head));
            }
        }
    });
}

/// [`par_chunks_mut`] for row-major [rows, row_len] buffers: chunks are
/// row-aligned and `f` receives the first row index of its chunk. The
/// spawn-saving last-chunk rule lives here once, shared by every
/// row-parallel kernel (matmul, correlation matrix).
pub fn par_row_chunks_mut<T, F>(threads: usize, out: &mut [T], row_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let ranges = split_ranges(rows, threads);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r.start, out);
        }
        return;
    }
    let f = &f;
    let n_ranges = ranges.len();
    std::thread::scope(|s| {
        let mut rest: &mut [T] = out;
        for (idx, r) in ranges.into_iter().enumerate() {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * row_len);
            rest = tail;
            if idx + 1 == n_ranges {
                f(r.start, head);
            } else {
                s.spawn(move || f(r.start, head));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_exactly_in_order() {
        for len in [0usize, 1, 2, 7, 16, 63, 64, 65] {
            for threads in [1usize, 2, 3, 4, 7, 64, 1000] {
                let ranges = split_ranges(len, threads);
                let flat: Vec<usize> = ranges.iter().cloned().flatten().collect();
                assert_eq!(flat, (0..len).collect::<Vec<_>>(), "len={len} t={threads}");
                assert!(ranges.iter().all(|r| !r.is_empty()));
                assert!(ranges.len() <= threads.max(1));
                if len > 0 {
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    assert!(max - min <= 1, "near-equal split");
                }
            }
        }
    }

    fn square_sum(r: Range<usize>) -> u64 {
        r.map(|i| (i * i) as u64).sum()
    }

    #[test]
    fn par_map_matches_serial_map() {
        let serial: u64 = square_sum(0..1000);
        for threads in [1usize, 2, 3, 8] {
            let total: u64 = par_map_chunks(threads, 1000, square_sum).into_iter().sum();
            assert_eq!(total, serial, "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_writes_every_slot_once() {
        for threads in [1usize, 2, 3, 5] {
            let mut out = vec![0usize; 97];
            par_chunks_mut(threads, &mut out, |start, chunk| {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = (start + off) * 3 + 1;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i * 3 + 1);
            }
        }
    }

    #[test]
    fn par_row_chunks_mut_respects_row_alignment() {
        let (rows, row_len) = (13usize, 5usize);
        for threads in [1usize, 2, 4, 13] {
            let mut out = vec![0usize; rows * row_len];
            par_row_chunks_mut(threads, &mut out, row_len, |first_row, chunk| {
                assert_eq!(chunk.len() % row_len, 0, "row-aligned chunk");
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = first_row * row_len + off;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i, "threads={threads}");
            }
        }
    }

    #[test]
    fn default_threads_is_positive_and_stable() {
        let a = default_threads();
        let b = default_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
