//! Calibration statistics (Section 3.2.1 / Algorithm 1 lines 1-4).
//!
//! One pass of the `calib_<model>` HLO executable over a calibration token
//! stream yields, per layer, everything every method in the paper needs:
//!
//! * `mean_out`  — o_j = E_x[E_j(x)] (Eq. 4), HC-SMoE's similarity metric;
//! * `counts`    — top-k routing frequencies (frequency merging, F-prune);
//! * `probs_sum` — accumulated full-softmax router scores (S-prune);
//! * `gate_sum`  — accumulated top-k gate weights;
//! * `rl_sub`    — router-logit profiles on subsampled tokens (M-SMoE);
//! * `raw_sub`   — per-expert outputs on subsampled tokens (O-prune);
//! * `act_sub`   — intermediate activations (ZipIt / Fix-Dom features);
//! * `hid_sub`   — pre-MoE hidden states (layer-output replay).

use anyhow::{ensure, Result};

use crate::data::TokenStream;
use crate::model::ModelContext;
use crate::parallel;
use crate::tensor::Tensor;

/// Per-layer statistics.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Average expert outputs o_j (Eq. 4), `[n, d]`.
    pub mean_out: Tensor,
    /// Top-k routing frequencies, `[n]`.
    pub counts: Vec<f32>,
    /// Accumulated full-softmax router scores, `[n]`.
    pub probs_sum: Vec<f32>,
    /// Accumulated top-k gate weights, `[n]`.
    pub gate_sum: Vec<f32>,
    /// Router-logit profiles on subsampled tokens, `[t_sub, n]`.
    pub rl_sub: Tensor,
    /// Per-expert outputs on subsampled tokens, `[n, t_sub, d]`.
    pub raw_sub: Tensor,
    /// Intermediate activations on subsampled tokens, `[n, t_act, m]`.
    pub act_sub: Tensor,
    /// Pre-MoE hidden states on subsampled tokens, `[t_sub, d]`.
    pub hid_sub: Tensor,
}

impl LayerStats {
    /// Router-logit profile of expert `i` across the subsampled tokens —
    /// the M-SMoE similarity feature.
    pub fn rl_profile(&self, i: usize) -> Vec<f32> {
        let (t, n) = (self.rl_sub.shape()[0], self.rl_sub.shape()[1]);
        (0..t).map(|s| self.rl_sub.data()[s * n + i]).collect()
    }

    /// Raw outputs of expert `i`: [t_sub, d] slice.
    pub fn raw_out(&self, i: usize) -> Tensor {
        self.raw_sub.index(i)
    }

    /// Activation features of expert `i`: [t_act, m] slice.
    pub fn acts(&self, i: usize) -> Tensor {
        self.act_sub.index(i)
    }

    /// Normalised frequencies f̃ (Algorithm 1 line 14) over a subset.
    pub fn norm_freq(&self, experts: &[usize]) -> Vec<f32> {
        let total: f32 = experts.iter().map(|&e| self.counts[e]).sum();
        if total <= 0.0 {
            return vec![1.0 / experts.len() as f32; experts.len()];
        }
        experts.iter().map(|&e| self.counts[e] / total).collect()
    }
}

/// Full-model calibration statistics.
#[derive(Debug, Clone)]
pub struct CalibStats {
    /// Calibration domain the stats were collected on.
    pub domain: String,
    /// Per-layer statistics, layer 0 first.
    pub layers: Vec<LayerStats>,
    /// Total calibration tokens consumed.
    pub n_tokens: usize,
}

impl CalibStats {
    /// Run the calibration executable over every [calib_b, calib_t] batch in
    /// the stream and average/accumulate the statistics.
    pub fn collect(ctx: &ModelContext, ts: &TokenStream) -> Result<Self> {
        let (b, t) = (ctx.manifest.calib_b, ctx.manifest.calib_t);
        let batches = ts.batches(b, t);
        ensure!(!batches.is_empty(), "calibration stream shorter than one batch");
        let threads = parallel::default_threads();
        let mut agg: Option<Vec<LayerStats>> = None;
        for ids in &batches {
            let outs = ctx.run_calib(ids)?;
            ensure!(outs.len() == 8, "calib tuple must have 8 elements");
            let layers = unpack(ctx, outs)?;
            agg = Some(match agg {
                None => layers,
                Some(mut acc) => {
                    merge_layerwise(&mut acc, &layers, threads);
                    acc
                }
            });
        }
        let mut layers = agg.unwrap();
        let nb = batches.len() as f32;
        if nb > 1.0 {
            // mean_out is a mean per batch -> average across batches;
            // counts/sums accumulate (they are totals).
            let t = if accum_work(&layers) >= parallel::PAR_AUTO_WORK { threads } else { 1 };
            parallel::par_chunks_mut(t, &mut layers, |_, chunk| {
                for l in chunk {
                    l.mean_out.scale(1.0 / nb);
                }
            });
        }
        Ok(Self {
            domain: String::new(),
            layers,
            n_tokens: batches.len() * b * t,
        })
    }

    /// Number of layers covered.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Experts per layer.
    pub fn n_experts(&self) -> usize {
        self.layers[0].counts.len()
    }

    /// The same statistics with every layer's routing frequencies replaced
    /// by **live serving dispatch counts** (one `[n_exp]` row per layer,
    /// e.g. a [`crate::backend::RoutingSnapshot`] window) — the adaptive
    /// recompression bridge: similarity features stay calibration-derived,
    /// while frequency weighting ([`LayerStats::norm_freq`], Algorithm 1
    /// line 14) follows the traffic actually served. A layer whose live
    /// row is all-zero keeps `norm_freq`'s uniform fallback semantics.
    pub fn reweighted(&self, live: &[Vec<u64>]) -> Result<Self> {
        ensure!(
            live.len() == self.n_layers(),
            "live counts cover {} layers, stats have {}",
            live.len(),
            self.n_layers()
        );
        let mut out = self.clone();
        for (l, (layer, row)) in out.layers.iter_mut().zip(live).enumerate() {
            ensure!(
                row.len() == layer.counts.len(),
                "live counts at layer {l} cover {} experts, stats have {}",
                row.len(),
                layer.counts.len()
            );
            layer.counts = row.iter().map(|&c| c as f32).collect();
        }
        Ok(out)
    }
}

/// Accumulate `fresh` into `acc` layer by layer. Layers are independent, so
/// the sweep parallelises over disjoint layer chunks; each layer's
/// accumulation is the exact serial expression, keeping batch order — and
/// therefore every statistic — bit-identical to the serial path.
fn merge_layerwise(acc: &mut [LayerStats], fresh: &[LayerStats], threads: usize) {
    debug_assert_eq!(acc.len(), fresh.len());
    let threads = if accum_work(acc) >= parallel::PAR_AUTO_WORK { threads } else { 1 };
    parallel::par_chunks_mut(threads, acc, |start, chunk| {
        for (off, a) in chunk.iter_mut().enumerate() {
            merge_into(a, &fresh[start + off]);
        }
    });
}

/// Element ops one accumulation (or rescale) sweep touches — the gate input
/// keeping tiny-model calibration on the serial path (same policy as every
/// other auto-dispatched hot path).
fn accum_work(layers: &[LayerStats]) -> usize {
    layers.iter().map(|l| l.mean_out.len() + 3 * l.counts.len()).sum()
}

fn merge_into(a: &mut LayerStats, l: &LayerStats) {
    a.mean_out.add_scaled(&l.mean_out, 1.0);
    for (x, y) in a.counts.iter_mut().zip(&l.counts) {
        *x += y;
    }
    for (x, y) in a.probs_sum.iter_mut().zip(&l.probs_sum) {
        *x += y;
    }
    for (x, y) in a.gate_sum.iter_mut().zip(&l.gate_sum) {
        *x += y;
    }
    // subsampled tensors: keep the first batch's subsample (stable; the
    // profiles only need a representative token sample).
}

fn unpack(ctx: &ModelContext, outs: Vec<Tensor>) -> Result<Vec<LayerStats>> {
    let nl = ctx.cfg.n_layer;
    let mut it = outs.into_iter();
    let mean_out = it.next().unwrap();
    let counts = it.next().unwrap();
    let probs_sum = it.next().unwrap();
    let gate_sum = it.next().unwrap();
    let rl_sub = it.next().unwrap();
    let raw_sub = it.next().unwrap();
    let act_sub = it.next().unwrap();
    let hid_sub = it.next().unwrap();
    ensure!(mean_out.shape()[0] == nl, "layer dim mismatch");
    let mut layers = Vec::with_capacity(nl);
    for l in 0..nl {
        layers.push(LayerStats {
            mean_out: mean_out.index(l),
            counts: counts.index(l).into_data(),
            probs_sum: probs_sum.index(l).into_data(),
            gate_sum: gate_sum.index(l).into_data(),
            rl_sub: rl_sub.index(l),
            raw_sub: raw_sub.index(l),
            act_sub: act_sub.index(l),
            hid_sub: hid_sub.index(l),
        });
    }
    Ok(layers)
}

pub mod synthetic {
    //! Synthetic `LayerStats` for algorithm unit tests, the determinism
    //! property suite and the artifact-free bench paths (no PJRT needed).
    use super::*;
    use crate::util::Rng;

    /// Build stats where experts form `groups` of near-identical behaviour —
    /// the ground truth the clustering tests recover.
    pub fn synthetic_grouped(
        n: usize,
        d: usize,
        groups: &[Vec<usize>],
        noise: f32,
        seed: u64,
    ) -> LayerStats {
        let mut rng = Rng::new(seed);
        let t_sub = 16;
        let m = 8;
        let mut centers = vec![vec![0f32; d]; groups.len()];
        for c in &mut centers {
            for x in c.iter_mut() {
                *x = rng.normal() as f32;
            }
        }
        let mut mean = vec![0f32; n * d];
        for (gi, g) in groups.iter().enumerate() {
            for &e in g {
                for j in 0..d {
                    mean[e * d + j] = centers[gi][j] + noise * rng.normal() as f32;
                }
            }
        }
        let counts: Vec<f32> = (0..n).map(|_| 1.0 + rng.below(100) as f32).collect();
        LayerStats {
            mean_out: Tensor::new(vec![n, d], mean).unwrap(),
            probs_sum: counts.clone(),
            gate_sum: counts.clone(),
            counts,
            rl_sub: Tensor::zeros(vec![t_sub, n]),
            raw_sub: Tensor::zeros(vec![n, t_sub, d]),
            act_sub: Tensor::zeros(vec![n, t_sub.min(8), m]),
            hid_sub: Tensor::zeros(vec![t_sub, d]),
        }
    }
}
