//! Cluster-quality analysis (Appendix D, Table 23): output fidelity of the
//! compressed model (L2 error / cosine similarity of last-layer logits vs
//! the original), the post-merge int8 quantization quality delta, and
//! intrinsic clustering criteria (Silhouette score and Dunn index under
//! Euclidean and cosine distances).

use anyhow::Result;

use crate::data::TokenStream;
use crate::eval::Evaluator;
use crate::model::{LoadedModel, ModelContext};
use crate::pipeline::CompressedModel;
use crate::similarity::Distance;
use crate::tensor::{cosine_sim, l2_dist};

/// Output fidelity over a token stream: (Σ||T(x)-S(x)||₂, mean cosine sim).
pub fn output_fidelity(
    ctx: &ModelContext,
    original: &LoadedModel,
    compressed: &LoadedModel,
    stream: &TokenStream,
    max_batches: usize,
) -> Result<(f64, f64)> {
    let (b, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let mut l2 = 0f64;
    let mut cos = 0f64;
    let mut rows = 0usize;
    for batch in stream.tokens.chunks_exact(b * t).take(max_batches) {
        let lo = ctx.run_logits(original, batch)?;
        let lc = ctx.run_logits(compressed, batch)?;
        let v = lo.shape()[2];
        for i in 0..b * t {
            let ro = &lo.data()[i * v..(i + 1) * v];
            let rc = &lc.data()[i * v..(i + 1) * v];
            l2 += l2_dist(ro, rc) as f64;
            cos += cosine_sim(ro, rc) as f64;
            rows += 1;
        }
    }
    anyhow::ensure!(rows > 0, "stream too short");
    Ok((l2, cos / rows as f64))
}

/// Eval-harness quality delta of post-merge int8 quantization: loads the
/// compressed model and its int8 sibling ([`CompressedModel::quantize`]),
/// scores both on the named benchmark tasks, and returns
/// `(f32_accuracy, int8_accuracy)` per task in input order. Acceptance
/// bounds live with the caller — the serving test suite pins the mean
/// `|Δ|` within a named tolerance.
pub fn quantization_delta(
    ctx: &ModelContext,
    cm: &CompressedModel,
    tasks: &[&str],
) -> Result<Vec<(f64, f64)>> {
    let f32_model = cm.load(ctx)?;
    let q_model = cm.quantize()?.load(ctx)?;
    let ev = Evaluator::new(ctx)?;
    tasks
        .iter()
        .map(|task| {
            let full = ev.accuracy(&f32_model, task)?;
            let quant = ev.accuracy(&q_model, task)?;
            Ok((full, quant))
        })
        .collect()
}

fn dist(a: &[f32], b: &[f32], d: Distance) -> f32 {
    match d {
        Distance::Euclidean => l2_dist(a, b),
        Distance::Cosine => crate::tensor::cosine_dist(a, b),
    }
}

/// Mean Silhouette coefficient over all points.
/// s(i) = (b(i) - a(i)) / max(a(i), b(i)); singleton clusters score 0.
pub fn silhouette(feats: &[Vec<f32>], assign: &[usize], r: usize, metric: Distance) -> f64 {
    let n = feats.len();
    let mut total = 0f64;
    for i in 0..n {
        let own = assign[i];
        let own_size = assign.iter().filter(|&&c| c == own).count();
        if own_size <= 1 {
            continue; // s(i) = 0
        }
        let mut a = 0f64;
        let mut b_best = f64::INFINITY;
        for c in 0..r {
            let members: Vec<usize> = (0..n).filter(|&j| assign[j] == c && j != i).collect();
            if members.is_empty() {
                continue;
            }
            let mean: f64 = members
                .iter()
                .map(|&j| dist(&feats[i], &feats[j], metric) as f64)
                .sum::<f64>()
                / members.len() as f64;
            if c == own {
                a = mean;
            } else {
                b_best = b_best.min(mean);
            }
        }
        if b_best.is_finite() {
            total += (b_best - a) / a.max(b_best).max(1e-12);
        }
    }
    total / n as f64
}

/// Dunn index: min inter-cluster distance / max intra-cluster diameter.
pub fn dunn_index(feats: &[Vec<f32>], assign: &[usize], r: usize, metric: Distance) -> f64 {
    let n = feats.len();
    let mut min_inter = f64::INFINITY;
    let mut max_diam = 0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let d = dist(&feats[i], &feats[j], metric) as f64;
            if assign[i] == assign[j] {
                max_diam = max_diam.max(d);
            } else {
                min_inter = min_inter.min(d);
            }
        }
    }
    let _ = r;
    if max_diam <= 0.0 {
        return f64::INFINITY;
    }
    if !min_inter.is_finite() {
        return 0.0;
    }
    min_inter / max_diam
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs() -> (Vec<Vec<f32>>, Vec<usize>) {
        (
            vec![
                vec![0.0, 0.0],
                vec![0.1, 0.0],
                vec![5.0, 5.0],
                vec![5.1, 5.0],
            ],
            vec![0, 0, 1, 1],
        )
    }

    #[test]
    fn silhouette_high_for_good_clustering() {
        let (f, a) = blobs();
        let s = silhouette(&f, &a, 2, Distance::Euclidean);
        assert!(s > 0.9, "silhouette {s}");
    }

    #[test]
    fn silhouette_lower_for_bad_clustering() {
        let (f, _) = blobs();
        let bad = vec![0, 1, 0, 1];
        let s_good = silhouette(&f, &[0, 0, 1, 1], 2, Distance::Euclidean);
        let s_bad = silhouette(&f, &bad, 2, Distance::Euclidean);
        assert!(s_bad < s_good);
        assert!(s_bad < 0.0, "crossed clusters must score negative: {s_bad}");
    }

    #[test]
    fn dunn_prefers_separated_clusters() {
        let (f, a) = blobs();
        let good = dunn_index(&f, &a, 2, Distance::Euclidean);
        let bad = dunn_index(&f, &[0, 1, 0, 1], 2, Distance::Euclidean);
        assert!(good > 10.0, "well separated: {good}");
        assert!(bad < 1.0, "crossed: {bad}");
    }

    #[test]
    fn dunn_cosine_variant_runs() {
        let (f, a) = blobs();
        let d = dunn_index(&f, &a, 2, Distance::Cosine);
        assert!(d.is_finite() && d >= 0.0);
    }
}
