//! Autoregressive generation on top of the backend prefill/decode split.
//!
//! This is the workload the paper's deployment pitch (Section 1, Table 20)
//! actually cares about: emit tokens one at a time from a (merged) SMoE
//! model with a KV-cached decode loop, so each new token costs O(t)
//! instead of the O(t²) of re-running the full forward per step.
//!
//! Three layers:
//!
//! * [`SamplingParams`] / [`Strategy`] — greedy or seeded temperature/
//!   top-k sampling (via the deterministic [`crate::util::Rng`]), plus the
//!   stop conditions (`max_new_tokens`, optional EOS token; the model's
//!   `t_max` context limit is always enforced).
//! * [`Session`] — the pure decision loop: feed it the last position's
//!   logits, it samples the next token and tracks the stop conditions.
//!   Both the offline driver below and the serving executor's continuous
//!   batcher (`crate::serving`) run sequences through this one type, which
//!   is what makes a server-side generation bit-identical to an offline
//!   [`generate`] call with the same parameters.
//! * [`generate`] / [`generate_compact`] — the offline drivers:
//!   prefill → sample → decode → … → [`Generated`]; plus
//!   [`speculative`] / [`speculative_paged`] — draft-k/verify-1
//!   speculative decoding with the compact merged variant as the
//!   drafter, pinned bit-identical to the plain drivers
//!   ([`SpecOutcome`] adds draft/accept accounting on top of
//!   [`Generated`]).
//!
//! Determinism: the native backend forward is bit-deterministic and the
//! sampler is seeded, so the same (weights, prompt, params) always yields
//! the same token sequence — `rust/tests/generate.rs` pins this, and the
//! README's self-verification quickstart relies on it.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::backend::KvCache;
use crate::kvpool::PoolHandle;
use crate::model::{CompactModel, LoadedModel, ModelContext};
use crate::util::Rng;

/// Token-selection rule applied to each step's logits.
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// Pick the highest logit (first index wins ties, like `jnp.argmax`).
    Greedy,
    /// Seeded stochastic sampling: softmax over the `k` highest logits at
    /// the given temperature, then one multinomial draw per step from the
    /// deterministic xorshift64* stream.
    TopK {
        /// Candidates kept per step (clamped to the vocabulary size).
        k: usize,
        /// Softmax temperature (> 0; lower = sharper).
        temperature: f32,
        /// RNG seed — identical seeds replay identical token streams.
        seed: u64,
    },
}

/// Why a generation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The EOS token was sampled (it is included in the output).
    Eos,
    /// `max_new_tokens` tokens were emitted.
    MaxTokens,
    /// The model's `t_max` context window filled up.
    MaxContext,
}

/// Generation request: selection strategy plus stop conditions.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// Token-selection rule.
    pub strategy: Strategy,
    /// Hard cap on emitted tokens.
    pub max_new_tokens: usize,
    /// Stop (inclusively) when this token is sampled, if set.
    pub eos: Option<i32>,
}

impl SamplingParams {
    /// Greedy decoding for up to `max_new_tokens` tokens, stopping early
    /// at `eos` when given.
    pub fn greedy(max_new_tokens: usize, eos: Option<i32>) -> Self {
        Self { strategy: Strategy::Greedy, max_new_tokens, eos }
    }

    /// Seeded temperature/top-k sampling for up to `max_new_tokens`
    /// tokens, stopping early at `eos` when given.
    pub fn top_k(
        k: usize,
        temperature: f32,
        seed: u64,
        max_new_tokens: usize,
        eos: Option<i32>,
    ) -> Self {
        Self {
            strategy: Strategy::TopK { k, temperature, seed },
            max_new_tokens,
            eos,
        }
    }

    /// Reject degenerate parameters up front: [`Strategy::TopK`] needs
    /// `k >= 1` and a finite `temperature > 0`. (`k` larger than the
    /// vocabulary is legal — it clamps to the full vocabulary at sampling
    /// time.) Every driver calls this before running — the offline
    /// [`generate`]/[`generate_compact`] loops error out, and the serving
    /// executor answers the request with the error instead of letting a
    /// bad parameter panic or sample garbage on the executor thread.
    pub fn validate(&self) -> Result<()> {
        if let Strategy::TopK { k, temperature, .. } = self.strategy {
            ensure!(k >= 1, "top-k sampling needs k >= 1 (got k = 0)");
            ensure!(
                temperature.is_finite() && temperature > 0.0,
                "top-k sampling needs a finite temperature > 0 (got {temperature})"
            );
        }
        Ok(())
    }
}

/// One finished generation.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Emitted tokens, in order (the EOS token, when hit, is included).
    pub tokens: Vec<i32>,
    /// Which stop condition ended the sequence.
    pub finish: FinishReason,
    /// Wall-clock seconds spent in the prompt prefill.
    pub prefill_s: f64,
    /// Wall-clock seconds spent across all decode steps.
    pub decode_s: f64,
}

impl Generated {
    /// Decode throughput in tokens per second (0 when nothing decoded).
    /// The first token is sampled from the prefill logits, so the decode
    /// loop ran `tokens.len() - 1` steps — that is the numerator here,
    /// matching what `decode_s` actually timed.
    pub fn decode_tok_s(&self) -> f64 {
        let steps = self.tokens.len().saturating_sub(1);
        if self.decode_s > 0.0 && steps > 0 {
            steps as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// The per-sequence decision loop: sample-next-token + stop tracking,
/// decoupled from *where* logits come from so the offline [`generate`]
/// driver and the serving executor's continuous batcher share it verbatim.
///
/// Protocol: after each forward (prefill or decode), call
/// [`Session::advance`] with the new logits and the cache's current
/// length. `Some(tok)` means "feed `tok` to the next decode step";
/// `None` means the sequence finished — read [`Session::finish`] /
/// [`Session::tokens`].
///
/// `Clone` copies the whole decision state *including the RNG position*:
/// the speculative drafter clones the session so its draft picks spend
/// exactly the random draws the real session will spend verifying — the
/// construction that makes speculative output bit-identical to plain
/// decoding (see [`speculative`]).
#[derive(Clone)]
pub struct Session {
    params: SamplingParams,
    rng: Rng,
    tokens: Vec<i32>,
    finish: Option<FinishReason>,
}

impl Session {
    /// New session; for [`Strategy::TopK`] the RNG stream starts at the
    /// given seed.
    pub fn new(params: SamplingParams) -> Self {
        let seed = match params.strategy {
            Strategy::TopK { seed, .. } => seed,
            Strategy::Greedy => 0,
        };
        Self { params, rng: Rng::new(seed), tokens: Vec::new(), finish: None }
    }

    /// Sample the next token from `logits` and update the stop conditions.
    /// `ctx_len` is the KV cache's current sequence length (tokens already
    /// resident *before* feeding the returned token); `t_max` the model's
    /// context limit. Returns the token to feed to the next decode step,
    /// or `None` once the sequence is finished.
    pub fn advance(&mut self, logits: &[f32], ctx_len: usize, t_max: usize) -> Option<i32> {
        if self.finish.is_some() {
            return None;
        }
        if self.tokens.len() >= self.params.max_new_tokens {
            self.finish = Some(FinishReason::MaxTokens);
            return None;
        }
        let tok = self.pick(logits);
        self.tokens.push(tok);
        if self.params.eos == Some(tok) {
            self.finish = Some(FinishReason::Eos);
            return None;
        }
        if self.tokens.len() >= self.params.max_new_tokens {
            self.finish = Some(FinishReason::MaxTokens);
            return None;
        }
        if ctx_len + 1 > t_max {
            self.finish = Some(FinishReason::MaxContext);
            return None;
        }
        Some(tok)
    }

    /// Tokens emitted so far.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// The sampling parameters this session runs under (the speculative
    /// drivers read `max_new_tokens` to clamp their draft depth).
    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Consume the session, returning the emitted tokens.
    pub fn into_tokens(self) -> Vec<i32> {
        self.tokens
    }

    /// The stop condition that ended the sequence (None while running).
    pub fn finish(&self) -> Option<FinishReason> {
        self.finish
    }

    /// One raw token selection from a logits row, consuming exactly the
    /// RNG draw the next [`Session::advance`] would — no stop-condition
    /// tracking, no token recording. This is the speculative **draft**
    /// pick: a cloned session drafts with it, so draft and verifier
    /// selections for the same emitted-token index use the same random
    /// draw and are comparable pick for pick.
    pub fn pick_next(&mut self, logits: &[f32]) -> i32 {
        self.pick(logits)
    }

    /// One token selection from a logits row.
    fn pick(&mut self, logits: &[f32]) -> i32 {
        match self.params.strategy {
            Strategy::Greedy => argmax_first(logits) as i32,
            Strategy::TopK { k, temperature, .. } => {
                // deterministic clamps behind the validate() gate: k stays
                // within the vocabulary, and a non-finite/non-positive
                // temperature (possible via direct struct construction)
                // degrades to near-greedy instead of inverting the
                // distribution or propagating NaN
                let k = k.max(1).min(logits.len());
                let temp = temperature.max(1e-6);
                // k rounds of first-wins argmax (the route_topk idiom)
                let mut work = logits.to_vec();
                let mut idx = Vec::with_capacity(k);
                let mut sel = Vec::with_capacity(k);
                for _ in 0..k {
                    let bi = argmax_first(&work);
                    idx.push(bi);
                    sel.push(logits[bi] / temp);
                    work[bi] = f32::NEG_INFINITY;
                }
                // softmax over the selected candidates, then one draw
                let mx = sel.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0f64;
                let exps: Vec<f64> = sel
                    .iter()
                    .map(|&s| {
                        let e = ((s - mx) as f64).exp();
                        z += e;
                        e
                    })
                    .collect();
                // a degenerate row (all -inf, or NaN logits) makes every
                // exp weight 0 or NaN: fall back to the deterministic
                // greedy pick rather than sampling from garbage
                if !z.is_finite() || z <= 0.0 {
                    return argmax_first(logits) as i32;
                }
                let u = self.rng.next_f64() * z;
                let mut acc = 0f64;
                for (j, &e) in exps.iter().enumerate() {
                    acc += e;
                    if u < acc {
                        return idx[j] as i32;
                    }
                }
                idx[k - 1] as i32
            }
        }
    }
}

/// First-wins argmax (ties break to the lowest index, like `jnp.argmax`).
fn argmax_first(xs: &[f32]) -> usize {
    let mut bi = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            bi = i;
        }
    }
    bi
}

/// Generate tokens from a resident variant with the KV-cached decode loop.
///
/// # Examples
///
/// ```
/// use hc_smoe::bench_support::synthesize_artifacts;
/// use hc_smoe::config::Artifacts;
/// use hc_smoe::generate::{generate, SamplingParams};
/// use hc_smoe::model::ModelContext;
///
/// let dir = std::env::temp_dir().join(format!("hcsmoe_doc_gen_{}", std::process::id()));
/// synthesize_artifacts(&dir, 1).unwrap();
/// let ctx = ModelContext::load(&Artifacts::new(&dir), "qwensim").unwrap();
/// let model = ctx.load_original().unwrap();
///
/// let out = generate(&ctx, &model, &[1, 4, 20, 3], SamplingParams::greedy(4, None)).unwrap();
/// assert_eq!(out.tokens.len(), 4);
/// // greedy decoding on deterministic weights replays exactly
/// let again = generate(&ctx, &model, &[1, 4, 20, 3], SamplingParams::greedy(4, None)).unwrap();
/// assert_eq!(out.tokens, again.tokens);
/// # std::fs::remove_dir_all(&dir).ok();
/// ```
pub fn generate(
    ctx: &ModelContext,
    model: &LoadedModel,
    prompt: &[i32],
    params: SamplingParams,
) -> Result<Generated> {
    run_loop(
        ctx.cfg.t_max,
        params,
        || ctx.prefill(model, prompt),
        |cache, tok| ctx.decode(model, cache, tok),
    )
}

/// [`generate`] on a compact r-expert variant (the Table 20 efficiency
/// layout: r physical expert slots plus the router remap table).
pub fn generate_compact(
    ctx: &ModelContext,
    model: &CompactModel,
    prompt: &[i32],
    params: SamplingParams,
) -> Result<Generated> {
    run_loop(
        ctx.cfg.t_max,
        params,
        || ctx.prefill_compact(model, prompt),
        |cache, tok| ctx.decode_compact(model, cache, tok),
    )
}

/// One finished **speculative** generation: the ordinary [`Generated`]
/// output plus draft/accept accounting.
#[derive(Debug, Clone)]
pub struct SpecOutcome {
    /// The generation itself — bit-identical to what plain [`generate`]
    /// with the same parameters produces.
    pub gen: Generated,
    /// Draft tokens proposed by the compact drafter (excludes the
    /// already-committed token that heads each verify run).
    pub drafted: usize,
    /// Draft tokens the verifier's own sampling agreed with.
    pub accepted: usize,
    /// Verify forwards executed (each scores one draft run; plain decode
    /// would have used one forward per emitted token instead).
    pub verify_steps: usize,
}

impl SpecOutcome {
    /// Fraction of proposed draft tokens accepted (0 when none proposed).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Draft-k/verify-1 speculative decoding: the compact r-expert `drafter`
/// proposes up to `draft_k` tokens per round on its own KV cache, the
/// `full` model scores every proposed position in **one**
/// [`ModelContext::verify`] forward, and the longest prefix the
/// verifier's own sampling agrees with is accepted — both caches are
/// rolled back past the first rejection.
///
/// **Exact-output guarantee, by construction:** the real [`Session`] is
/// the only thing that ever emits a token, it consumes verify logits
/// rows in emission order, and (inductively) row `i`'s logits are
/// bit-identical to what plain decode would have seen after the same
/// emitted prefix. Greedy and seeded top-k both hold: drafting runs on a
/// *clone* of the session, so every draft pick spends the same RNG draw
/// the verifier's pick spends, and a disagreement simply falls back to
/// the verifier's token (rejection-style). The token stream and finish
/// reason are therefore bit-identical to plain [`generate`] — at any
/// `draft_k` — which `rust/tests/spec_decode.rs` pins; compression
/// quality shows up purely as acceptance rate (fewer full-model
/// forwards), never as output drift.
pub fn speculative(
    ctx: &ModelContext,
    full: &LoadedModel,
    drafter: &CompactModel,
    prompt: &[i32],
    params: SamplingParams,
    draft_k: usize,
) -> Result<SpecOutcome> {
    spec_loop(ctx, full, drafter, prompt, params, draft_k, None)
}

/// [`speculative`] with both caches in one paged block pool (the serving
/// configuration: full/drafter caches never alias blocks because the
/// pool's sharing map is keyed by variant fingerprint).
pub fn speculative_paged(
    ctx: &ModelContext,
    full: &LoadedModel,
    drafter: &CompactModel,
    prompt: &[i32],
    params: SamplingParams,
    draft_k: usize,
    pool: &PoolHandle,
    reserve_tokens: usize,
) -> Result<SpecOutcome> {
    spec_loop(ctx, full, drafter, prompt, params, draft_k, Some((pool, reserve_tokens)))
}

/// The draft → verify → accept/rollback loop behind both speculative
/// entry points.
///
/// Invariant at the top of every round: both caches hold the prompt plus
/// every emitted token except `pending` (the last emitted, not yet fed)
/// — exactly the plain decode loop's cache state, which is what makes
/// round boundaries indistinguishable from plain decoding.
fn spec_loop(
    ctx: &ModelContext,
    full: &LoadedModel,
    drafter: &CompactModel,
    prompt: &[i32],
    params: SamplingParams,
    draft_k: usize,
    paged: Option<(&PoolHandle, usize)>,
) -> Result<SpecOutcome> {
    params.validate()?;
    ensure!(draft_k >= 1, "speculative decoding needs draft_k >= 1");
    let t_max = ctx.cfg.t_max;
    let t0 = Instant::now();
    let ((mut full_cache, logits), (mut draft_cache, _)) = match paged {
        None => (ctx.prefill(full, prompt)?, ctx.prefill_compact(drafter, prompt)?),
        Some((pool, reserve)) => (
            ctx.prefill_paged(full, prompt, pool, reserve)?,
            ctx.prefill_paged_compact(drafter, prompt, pool, reserve)?,
        ),
    };
    let prefill_s = t0.elapsed().as_secs_f64();
    let mut session = Session::new(params);
    let t1 = Instant::now();
    let (mut drafted, mut accepted, mut verify_steps) = (0usize, 0usize, 0usize);
    let mut pending = session.advance(&logits, full_cache.seq_len(), t_max);
    while let Some(tok) = pending {
        let t_base = full_cache.seq_len();
        ensure!(
            draft_cache.seq_len() == t_base,
            "draft cache out of sync with the verifier ({} vs {t_base} tokens)",
            draft_cache.seq_len()
        );
        // never verify more positions than the session can still emit or
        // the context window can still hold (both bounds are >= 1 here:
        // `advance` just returned Some)
        let remaining = session.params.max_new_tokens - session.tokens().len();
        let k_eff = draft_k.min(remaining).min(t_max - t_base).max(1);
        // draft k_eff - 1 tokens on the compact drafter's own cache; a
        // snapshot per drafter length makes any rejection point
        // restorable
        let mut run = Vec::with_capacity(k_eff);
        run.push(tok);
        let mut dsnaps = Vec::with_capacity(k_eff);
        dsnaps.push(ctx.snapshot_cache(draft_cache.as_ref())?);
        let mut draft_sess = session.clone();
        for j in 1..k_eff {
            let dl = ctx.decode_compact(drafter, draft_cache.as_mut(), run[j - 1])?;
            dsnaps.push(ctx.snapshot_cache(draft_cache.as_ref())?);
            run.push(draft_sess.pick_next(&dl));
        }
        drafted += run.len() - 1;
        // score every proposed position on the full model in one forward
        let mut caches: [&mut dyn KvCache; 1] = [full_cache.as_mut()];
        let out = ctx
            .verify(full, &mut caches, &[run.as_slice()])
            .map(|mut v| v.pop().expect("one VerifyOut per sequence"))?;
        verify_steps += 1;
        // the REAL session consumes the verify rows in emission order —
        // its picks are the authoritative stream; drafts that disagree
        // are discarded along with everything after them
        let k_run = run.len();
        let mut fed = k_run; // verify rows whose fed token stays accepted
        let mut next_pending = None;
        for i in 0..k_run {
            match session.advance(&out.logits[i], t_base + i + 1, t_max) {
                None => {
                    // finished (EOS / budget / context): rows past i are
                    // speculative overshoot
                    fed = i + 1;
                    next_pending = None;
                    break;
                }
                Some(t) if i + 1 < k_run => {
                    if t == run[i + 1] {
                        accepted += 1; // draft confirmed, consume next row
                    } else {
                        fed = i + 1; // verifier's token replaces the draft
                        next_pending = Some(t);
                        break;
                    }
                }
                Some(t) => next_pending = Some(t), // all rows accepted
            }
        }
        if fed < k_run {
            // roll both caches back past the first rejected position
            ctx.rollback_cache(full_cache.as_mut(), &out.checkpoints[fed - 1])?;
            ctx.rollback_cache(draft_cache.as_mut(), &dsnaps[fed])?;
        } else if next_pending.is_some() {
            // full accept: the drafter never fed the run's last token —
            // replay it so both caches re-enter the round invariant
            ctx.decode_compact(drafter, draft_cache.as_mut(), run[k_run - 1])?;
        }
        pending = next_pending;
    }
    let decode_s = t1.elapsed().as_secs_f64();
    let finish = session.finish();
    ensure!(finish.is_some(), "speculative loop ended without a finish reason");
    Ok(SpecOutcome {
        gen: Generated {
            tokens: session.into_tokens(),
            finish: finish.unwrap(),
            prefill_s,
            decode_s,
        },
        drafted,
        accepted,
        verify_steps,
    })
}

/// The shared prefill → sample → decode loop behind both variants.
fn run_loop(
    t_max: usize,
    params: SamplingParams,
    prefill: impl FnOnce() -> Result<(Box<dyn KvCache>, Vec<f32>)>,
    mut decode: impl FnMut(&mut dyn KvCache, i32) -> Result<Vec<f32>>,
) -> Result<Generated> {
    params.validate()?;
    let t0 = Instant::now();
    let (mut cache, mut logits) = prefill()?;
    let prefill_s = t0.elapsed().as_secs_f64();
    let mut session = Session::new(params);
    let t1 = Instant::now();
    while let Some(tok) = session.advance(&logits, cache.seq_len(), t_max) {
        logits = decode(cache.as_mut(), tok)?;
    }
    let decode_s = t1.elapsed().as_secs_f64();
    let finish = session.finish();
    ensure!(finish.is_some(), "generation loop ended without a finish reason");
    Ok(Generated {
        tokens: session.into_tokens(),
        finish: finish.unwrap(),
        prefill_s,
        decode_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_first_max() {
        let mut s = Session::new(SamplingParams::greedy(4, None));
        let next = s.advance(&[0.0, 3.0, 3.0, -1.0], 4, 64);
        assert_eq!(next, Some(1), "ties break to the first index");
    }

    #[test]
    fn max_tokens_stops_and_zero_budget_emits_nothing() {
        let mut s = Session::new(SamplingParams::greedy(2, None));
        assert!(s.advance(&[1.0, 0.0], 4, 64).is_some());
        assert_eq!(s.advance(&[1.0, 0.0], 5, 64), None);
        assert_eq!(s.finish(), Some(FinishReason::MaxTokens));
        assert_eq!(s.tokens(), &[0, 0]);

        let mut empty = Session::new(SamplingParams::greedy(0, None));
        assert_eq!(empty.advance(&[1.0, 0.0], 4, 64), None);
        assert_eq!(empty.finish(), Some(FinishReason::MaxTokens));
        assert!(empty.tokens().is_empty());
    }

    #[test]
    fn eos_stops_inclusively() {
        let mut s = Session::new(SamplingParams::greedy(8, Some(0)));
        assert_eq!(s.advance(&[1.0, 0.0], 4, 64), None);
        assert_eq!(s.finish(), Some(FinishReason::Eos));
        assert_eq!(s.tokens(), &[0]);
    }

    #[test]
    fn context_limit_stops() {
        let mut s = Session::new(SamplingParams::greedy(8, None));
        // cache already at t_max: the sampled token cannot be fed back
        assert_eq!(s.advance(&[1.0, 0.0], 16, 16), None);
        assert_eq!(s.finish(), Some(FinishReason::MaxContext));
        assert_eq!(s.tokens().len(), 1);
    }

    #[test]
    fn topk_is_seed_deterministic_and_stays_in_topk() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3).collect();
        let run = |seed: u64| -> Vec<i32> {
            let mut s = Session::new(SamplingParams::top_k(4, 0.7, seed, 8, None));
            let mut out = Vec::new();
            while let Some(t) = s.advance(&logits, 4 + out.len(), 64) {
                out.push(t);
            }
            out.push(*s.tokens().last().unwrap());
            out
        };
        assert_eq!(run(9), run(9), "same seed must replay");
        // top-4 of these logits are indices 12..16
        for t in run(9) {
            assert!((12..16).contains(&t), "sampled {t} outside top-k");
        }
    }

    #[test]
    fn cloned_session_drafts_the_same_draws() {
        // the speculative construction: a cloned session's pick_next must
        // spend the same RNG draws the real session's advance spends, so
        // draft and verifier picks for the same emitted index agree
        // whenever their logits do
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3).collect();
        let mut real = Session::new(SamplingParams::top_k(4, 0.7, 42, 8, None));
        let mut draft = real.clone();
        for step in 0..4 {
            let d = draft.pick_next(&logits);
            let r = real.advance(&logits, 4 + step, 64).expect("budget not exhausted");
            assert_eq!(d, r, "draft pick diverged at step {step}");
        }
    }

    #[test]
    fn validate_rejects_degenerate_params() {
        // k = 0: no candidate to sample from
        assert!(SamplingParams::top_k(0, 0.8, 1, 4, None).validate().is_err());
        // temperature <= 0 or non-finite: softmax is undefined/inverted
        assert!(SamplingParams::top_k(4, 0.0, 1, 4, None).validate().is_err());
        assert!(SamplingParams::top_k(4, -1.0, 1, 4, None).validate().is_err());
        assert!(SamplingParams::top_k(4, f32::NAN, 1, 4, None).validate().is_err());
        assert!(SamplingParams::top_k(4, f32::INFINITY, 1, 4, None).validate().is_err());
        // legal: greedy always, and k beyond the vocabulary (clamped later)
        assert!(SamplingParams::greedy(4, None).validate().is_ok());
        assert!(SamplingParams::top_k(1_000_000, 0.8, 1, 4, None).validate().is_ok());
    }

    #[test]
    fn topk_k_beyond_vocab_clamps_to_full_row() {
        let logits = [0.3f32, -0.1, 0.9, 0.2];
        let mut s = Session::new(SamplingParams::top_k(1000, 0.7, 11, 16, None));
        let mut out = Vec::new();
        while let Some(t) = s.advance(&logits, out.len() + 1, 64) {
            out.push(t);
        }
        assert_eq!(s.tokens().len(), 16);
        for &t in s.tokens() {
            assert!((0..4).contains(&t), "sampled {t} outside the 4-token vocab");
        }
    }

    #[test]
    fn all_neg_inf_logits_fall_back_deterministically() {
        let logits = [f32::NEG_INFINITY; 6];
        for seed in [1u64, 2, 3] {
            let mut s = Session::new(SamplingParams::top_k(3, 0.8, seed, 4, None));
            // no panic, and a deterministic in-vocab pick (greedy fallback:
            // first index) regardless of the seed
            assert_eq!(s.advance(&logits, 1, 64), Some(0));
        }
        // mixed rows keep sampling from the finite candidates only
        let mixed = [f32::NEG_INFINITY, 2.0, f32::NEG_INFINITY, 1.5];
        let mut s = Session::new(SamplingParams::top_k(3, 0.8, 9, 8, None));
        while let Some(t) = s.advance(&mixed, s.tokens().len() + 1, 64) {
            assert!(t == 1 || t == 3, "sampled a -inf candidate: {t}");
        }
    }

    #[test]
    fn finished_session_stays_finished() {
        let mut s = Session::new(SamplingParams::greedy(1, None));
        assert_eq!(s.advance(&[0.0, 2.0], 4, 64), None);
        assert_eq!(s.advance(&[0.0, 2.0], 4, 64), None);
        assert_eq!(s.tokens(), &[1]);
    }
}
