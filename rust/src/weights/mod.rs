//! Model weights: HCWT binary IO (shared format with `python/compile/export.py`)
//! plus the expert-level accessors the merging/pruning algorithms operate on.
//!
//! Tensor order inside the file is sorted-by-name — the exact order the HLO
//! parameters were lowered in, so `Weights::ordered()` can be fed straight
//! into `runtime::Executable::run`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};

use crate::config::ModelCfg;
use crate::tensor::{dequantize_rows_i8, quantize_rows_i8, Tensor};

const MAGIC: &[u8; 4] = b"HCWT";

/// Sanity cap on tensor rank in HCWT headers — a corrupt `ndim` field must
/// fail descriptively instead of driving a huge allocation.
const MAX_NDIM: usize = 8;

/// Per-row-scaled int8 tensor (HCWT v2 quantized section): the post-merge
/// compressed form of an expert weight. `shape` is the logical f32 shape;
/// quantization rows are all leading dims (`shape[..ndim-1]` flattened) and
/// columns the last dim, so a `[n, d, m]` gate tensor carries one scale per
/// expert per reduction index — exactly what the folded-scale quantized
/// GEMM ([`crate::tensor::matmul_q8_with`]) consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    shape: Vec<usize>,
    scales: Vec<f32>,
    q: Vec<i8>,
}

impl QuantTensor {
    /// Build from parts, checking `scales`/`q` lengths against `shape`.
    pub fn new(shape: Vec<usize>, scales: Vec<f32>, q: Vec<i8>) -> Result<Self> {
        anyhow::ensure!(!shape.is_empty(), "QuantTensor needs rank >= 1");
        let total: usize = shape.iter().product();
        let rows: usize = shape[..shape.len() - 1].iter().product();
        anyhow::ensure!(
            q.len() == total && scales.len() == rows,
            "QuantTensor {shape:?} wants {total} elems / {rows} scales, got {} / {}",
            q.len(),
            scales.len()
        );
        Ok(Self { shape, scales, q })
    }

    /// Quantize an f32 tensor per leading-dim row (scale = maxabs/127).
    pub fn from_f32(t: &Tensor) -> Result<Self> {
        anyhow::ensure!(!t.shape().is_empty(), "cannot quantize a rank-0 tensor");
        let cols = *t.shape().last().unwrap();
        let rows: usize = t.shape()[..t.shape().len() - 1].iter().product();
        anyhow::ensure!(cols > 0, "cannot quantize with a zero last dim");
        let (q, scales) = quantize_rows_i8(t.data(), rows, cols);
        Ok(Self { shape: t.shape().to_vec(), scales, q })
    }

    /// Reconstruct the (lossy) f32 tensor: `w = q · scale` per row.
    pub fn dequantize(&self) -> Tensor {
        let cols = *self.shape.last().unwrap();
        let rows: usize = self.shape[..self.shape.len() - 1].iter().product();
        let data = dequantize_rows_i8(&self.q, &self.scales, rows, cols);
        Tensor::new(self.shape.clone(), data).expect("shape/data consistent by construction")
    }

    /// Logical f32 shape, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Per-row scales (one per flattened leading-dims row).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Flat row-major int8 payload.
    pub fn q(&self) -> &[i8] {
        &self.q
    }

    /// `(q, scales)` slices of the sub-tensor at leading index `i` — e.g.
    /// one expert of a `[n, d, m]` tensor: `d·m` int8 values, `d` scales.
    pub fn index_slices(&self, i: usize) -> (&[i8], &[f32]) {
        assert!(self.shape.len() >= 2 && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        let inner_rows: usize = self.shape[1..self.shape.len() - 1].iter().product();
        (
            &self.q[i * inner..(i + 1) * inner],
            &self.scales[i * inner_rows..(i + 1) * inner_rows],
        )
    }
}

/// Expert weight triple (Eq. 2): gate / up / down matrices.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    /// Gate projection, `[d, m]`.
    pub wg: Tensor,
    /// Up projection, `[d, m]`.
    pub wu: Tensor,
    /// Down projection, `[m, d]`.
    pub wd: Tensor,
}

impl ExpertWeights {
    /// Flattened concatenation [Wg | Wu | Wd] — the paper's "weight" metric.
    pub fn flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.wg.len() + self.wu.len() + self.wd.len());
        v.extend_from_slice(self.wg.data());
        v.extend_from_slice(self.wu.data());
        v.extend_from_slice(self.wd.data());
        v
    }
}

/// A named tensor set (one model checkpoint), sorted by name. A checkpoint
/// may additionally carry per-row-scaled int8 tensors (the HCWT v2
/// quantized section) in a separate map; a quantized variant holds its
/// expert tensors *only* there, while attention/router/norm/shared tensors
/// stay f32.
#[derive(Clone, Debug)]
pub struct Weights {
    map: BTreeMap<String, Tensor>,
    qmap: BTreeMap<String, QuantTensor>,
}

impl Weights {
    /// Wrap an explicit name → tensor map (no quantized section).
    pub fn new(map: BTreeMap<String, Tensor>) -> Self {
        Self { map, qmap: BTreeMap::new() }
    }

    /// Load an HCWT checkpoint file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes)
    }

    /// Parse HCWT bytes (see `FORMATS.md`). Version 1 is the f32-only
    /// layout; version 2 appends a quantized-tensor section. Any defect —
    /// bad magic, unknown version, truncation, oversized headers, name
    /// collisions — returns a descriptive error and never yields a
    /// partially-initialized `Weights` (the maps are only wrapped into a
    /// value after every section parsed cleanly).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("HCWT: truncated magic")?;
        if &magic != MAGIC {
            bail!("bad magic {magic:?}");
        }
        let version = r.read_u32::<LittleEndian>().context("HCWT: truncated version")?;
        if version != 1 && version != 2 {
            bail!("unsupported HCWT version {version}");
        }
        let metas = Self::read_headers(&mut r, bytes.len(), "f32 section")?;
        let mut map = BTreeMap::new();
        for (name, dims) in metas {
            let count: usize = dims.iter().product();
            Self::ensure_remaining(&r, bytes.len(), count.checked_mul(4), &name)?;
            let mut data = vec![0f32; count];
            r.read_f32_into::<LittleEndian>(&mut data)
                .with_context(|| format!("HCWT: truncated f32 data for {name:?}"))?;
            map.insert(name, Tensor::new(dims, data)?);
        }
        let mut qmap = BTreeMap::new();
        if version == 2 {
            let qmetas = Self::read_headers(&mut r, bytes.len(), "quantized section")?;
            for (name, dims) in qmetas {
                if map.contains_key(&name) {
                    bail!("HCWT quantized section: {name:?} collides with an f32 tensor");
                }
                if dims.is_empty() {
                    bail!("HCWT quantized section: {name:?} has rank 0");
                }
                let count: usize = dims.iter().product();
                let rows: usize = dims[..dims.len() - 1].iter().product();
                Self::ensure_remaining(&r, bytes.len(), rows.checked_mul(4), &name)?;
                let mut scales = vec![0f32; rows];
                r.read_f32_into::<LittleEndian>(&mut scales)
                    .with_context(|| format!("HCWT: truncated scales for {name:?}"))?;
                Self::ensure_remaining(&r, bytes.len(), Some(count), &name)?;
                let mut qb = vec![0u8; count];
                r.read_exact(&mut qb)
                    .with_context(|| format!("HCWT: truncated int8 data for {name:?}"))?;
                let q: Vec<i8> = qb.into_iter().map(|b| b as i8).collect();
                qmap.insert(name, QuantTensor::new(dims, scales, q)?);
            }
        }
        Ok(Self { map, qmap })
    }

    /// Read one header table (count + per-tensor name/ndim/dims), shared by
    /// the f32 and quantized sections. Validates sizes against the bytes
    /// actually present so corrupt counts fail before any large allocation.
    fn read_headers(
        r: &mut std::io::Cursor<&[u8]>,
        total: usize,
        section: &str,
    ) -> Result<Vec<(String, Vec<usize>)>> {
        let n = r
            .read_u32::<LittleEndian>()
            .with_context(|| format!("HCWT {section}: truncated tensor count"))? as usize;
        let mut metas = Vec::new();
        for idx in 0..n {
            let nl = r
                .read_u32::<LittleEndian>()
                .with_context(|| format!("HCWT {section}: truncated header {idx}"))?
                as usize;
            Self::ensure_remaining(r, total, Some(nl), section)?;
            let mut nb = vec![0u8; nl];
            r.read_exact(&mut nb)
                .with_context(|| format!("HCWT {section}: truncated name in header {idx}"))?;
            let name = String::from_utf8(nb)
                .with_context(|| format!("HCWT {section}: non-UTF-8 name in header {idx}"))?;
            let ndim = r
                .read_u32::<LittleEndian>()
                .with_context(|| format!("HCWT {section}: truncated ndim for {name:?}"))?
                as usize;
            if ndim > MAX_NDIM {
                bail!("HCWT {section}: {name:?} claims rank {ndim} (max {MAX_NDIM})");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r
                    .read_u32::<LittleEndian>()
                    .with_context(|| format!("HCWT {section}: truncated dims for {name:?}"))?
                    as usize);
            }
            metas.push((name, dims));
        }
        Ok(metas)
    }

    /// Fail descriptively when fewer than `need` bytes remain (or when the
    /// size computation overflowed).
    fn ensure_remaining(
        r: &std::io::Cursor<&[u8]>,
        total: usize,
        need: Option<usize>,
        what: &str,
    ) -> Result<()> {
        let need = need.ok_or_else(|| anyhow!("HCWT: size overflow for {what:?}"))?;
        let left = total.saturating_sub(r.position() as usize);
        if need > left {
            bail!("HCWT: {what:?} wants {need} bytes but only {left} remain (truncated/corrupt)");
        }
        Ok(())
    }

    /// Write the HCWT serialisation of this weight set: version 1 when no
    /// quantized tensors are present (byte-exact with pre-v2 writers),
    /// version 2 with the appended quantized section otherwise.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        let version: u32 = if self.qmap.is_empty() { 1 } else { 2 };
        w.write_u32::<LittleEndian>(version)?;
        w.write_u32::<LittleEndian>(self.map.len() as u32)?;
        for (name, t) in &self.map {
            w.write_u32::<LittleEndian>(name.len() as u32)?;
            w.write_all(name.as_bytes())?;
            w.write_u32::<LittleEndian>(t.shape().len() as u32)?;
            for &d in t.shape() {
                w.write_u32::<LittleEndian>(d as u32)?;
            }
        }
        for t in self.map.values() {
            for &x in t.data() {
                w.write_f32::<LittleEndian>(x)?;
            }
        }
        if version == 2 {
            w.write_u32::<LittleEndian>(self.qmap.len() as u32)?;
            for (name, t) in &self.qmap {
                w.write_u32::<LittleEndian>(name.len() as u32)?;
                w.write_all(name.as_bytes())?;
                w.write_u32::<LittleEndian>(t.shape().len() as u32)?;
                for &d in t.shape() {
                    w.write_u32::<LittleEndian>(d as u32)?;
                }
            }
            for t in self.qmap.values() {
                for &s in t.scales() {
                    w.write_f32::<LittleEndian>(s)?;
                }
                let qb: Vec<u8> = t.q().iter().map(|&x| x as u8).collect();
                w.write_all(&qb)?;
            }
        }
        Ok(())
    }

    /// Tensor by name (error when absent).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Mutable tensor by name.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map.get_mut(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Insert or replace a tensor.
    pub fn insert(&mut self, name: String, t: Tensor) {
        self.map.insert(name, t);
    }

    /// Quantized tensor by name (error when absent).
    pub fn quant_get(&self, name: &str) -> Result<&QuantTensor> {
        self.qmap
            .get(name)
            .ok_or_else(|| anyhow!("missing quantized tensor {name:?}"))
    }

    /// Quantized tensor by name, `None` when absent — the backend's
    /// per-layer kernel-dispatch probe.
    pub fn quant_opt(&self, name: &str) -> Option<&QuantTensor> {
        self.qmap.get(name)
    }

    /// Insert or replace a quantized tensor. The f32 tensor of the same
    /// name (if any) is removed — a name lives in exactly one section.
    pub fn insert_quant(&mut self, name: String, t: QuantTensor) {
        self.map.remove(&name);
        self.qmap.insert(name, t);
    }

    /// Quantized tensor names in sorted order.
    pub fn quant_names(&self) -> impl Iterator<Item = &String> {
        self.qmap.keys()
    }

    /// Number of quantized tensors.
    pub fn quant_len(&self) -> usize {
        self.qmap.len()
    }

    /// True when the checkpoint carries any int8-quantized tensors (i.e.
    /// it serializes as HCWT v2).
    pub fn is_quantized(&self) -> bool {
        !self.qmap.is_empty()
    }

    /// Tensor names in sorted order.
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Tensor count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when the checkpoint holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Tensors in sorted-name order (the HLO parameter order).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.map.values().collect()
    }

    /// Total parameter count (f32 and int8 elements both count as one).
    pub fn param_count(&self) -> usize {
        self.map.values().map(|t| t.len()).sum::<usize>()
            + self.qmap.values().map(|t| t.len()).sum::<usize>()
    }

    /// Total weight bytes: 4 per f32 param, 1 per int8 param plus 4 per
    /// row scale — the number the compression ratio is computed from.
    pub fn byte_size(&self) -> usize {
        let f32_bytes: usize = self.map.values().map(|t| t.len() * 4).sum();
        let q_bytes: usize =
            self.qmap.values().map(|t| t.len() + t.scales().len() * 4).sum();
        f32_bytes + q_bytes
    }

    /// A 64-bit content fingerprint over every tensor (names, shapes, f32
    /// bit patterns, int8 payloads and scales). Two weight sets hash
    /// equal iff they are value-identical, so the fingerprint
    /// distinguishes hot-swapped variants that share an expert mask but
    /// differ in merged weights — the KV-prefix-sharing key must never
    /// alias across them (see `kvpool`). Not a cryptographic hash;
    /// collision resistance is "good enough for a registry key", exactly
    /// like the sibling `variant_fingerprint` in the native backend.
    pub fn content_hash(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        for (name, t) in &self.map {
            name.hash(&mut h);
            t.shape().hash(&mut h);
            for &x in t.data() {
                x.to_bits().hash(&mut h);
            }
        }
        for (name, t) in &self.qmap {
            name.hash(&mut h);
            t.shape().hash(&mut h);
            for &s in t.scales() {
                s.to_bits().hash(&mut h);
            }
            t.q().hash(&mut h);
        }
        h.finish()
    }

    // -- expert accessors ---------------------------------------------------

    /// Canonical HCWT tensor key of a per-layer tensor (`layer{L:02}.{suffix}`)
    /// — the single source of truth for the checkpoint naming scheme, shared
    /// with the native backend.
    pub(crate) fn layer_key(layer: usize, suffix: &str) -> String {
        format!("layer{layer:02}.{suffix}")
    }

    /// Weight triple of expert `idx` in `layer`. Errors descriptively on a
    /// quantized variant — merging/calibration need the f32 source.
    pub fn expert(&self, layer: usize, idx: usize) -> Result<ExpertWeights> {
        let gate_key = Self::layer_key(layer, "exp.wg");
        if !self.map.contains_key(&gate_key) && self.qmap.contains_key(&gate_key) {
            bail!(
                "expert tensors of layer {layer} are int8-quantized; \
                 operate on the f32 source weights and re-quantize"
            );
        }
        Ok(ExpertWeights {
            wg: self.get(&Self::layer_key(layer, "exp.wg"))?.index(idx),
            wu: self.get(&Self::layer_key(layer, "exp.wu"))?.index(idx),
            wd: self.get(&Self::layer_key(layer, "exp.wd"))?.index(idx),
        })
    }

    /// Overwrite expert `idx` of `layer` with `e`.
    pub fn set_expert(&mut self, layer: usize, idx: usize, e: &ExpertWeights) -> Result<()> {
        self.get_mut(&Self::layer_key(layer, "exp.wg"))?.set_index(idx, &e.wg);
        self.get_mut(&Self::layer_key(layer, "exp.wu"))?.set_index(idx, &e.wu);
        self.get_mut(&Self::layer_key(layer, "exp.wd"))?.set_index(idx, &e.wd);
        Ok(())
    }

    /// Router weight matrix `[d, n]` of `layer`.
    pub fn router(&self, layer: usize) -> Result<&Tensor> {
        self.get(&Self::layer_key(layer, "router"))
    }

    /// Router weight column for one expert (W_R[:, i]) — used by the
    /// "weight" variant of the router-logits metric discussions.
    pub fn router_column(&self, layer: usize, idx: usize) -> Result<Vec<f32>> {
        let r = self.router(layer)?;
        let (d, n) = (r.shape()[0], r.shape()[1]);
        anyhow::ensure!(idx < n, "expert {idx} out of range {n}");
        Ok((0..d).map(|i| r.data()[i * n + idx]).collect())
    }

    /// Number of experts (from the layer-0 gate tensor, in whichever
    /// section it lives — f32 or int8-quantized).
    pub fn n_experts(&self) -> Result<usize> {
        if let Some(t) = self.map.get("layer00.exp.wg") {
            return Ok(t.shape()[0]);
        }
        if let Some(t) = self.qmap.get("layer00.exp.wg") {
            return Ok(t.shape()[0]);
        }
        Err(anyhow!("missing tensor \"layer00.exp.wg\""))
    }

    /// Number of transformer layers (from the layer-key prefixes).
    pub fn n_layers(&self) -> usize {
        self.map
            .keys()
            .chain(self.qmap.keys())
            .filter_map(|k| {
                k.strip_prefix("layer")
                    .and_then(|s| s.get(..2))
                    .and_then(|s| s.parse::<usize>().ok())
            })
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
    }

    /// Synthesize a deterministic random-init checkpoint for `cfg` — the
    /// same tensor names/shapes `python/compile/model.py::init_params`
    /// produces (N(0, 0.02²) weights, unit RMSNorm gains), so the native
    /// backend and the HCWT round-trip can be exercised with no Python or
    /// training in the loop. Identical `(cfg, seed)` always yields an
    /// identical checkpoint.
    pub fn synthesize(cfg: &ModelCfg, seed: u64) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let s = 0.02f32;
        let mut normal = |len: usize| -> Vec<f32> {
            (0..len).map(|_| s * rng.normal() as f32).collect()
        };
        let mut map = BTreeMap::new();
        let (d, m, n) = (cfg.d, cfg.m, cfg.n_exp);
        map.insert(
            "embed".to_string(),
            Tensor::new(vec![cfg.vocab, d], normal(cfg.vocab * d)).unwrap(),
        );
        map.insert(
            "pos".to_string(),
            Tensor::new(vec![cfg.t_max, d], normal(cfg.t_max * d)).unwrap(),
        );
        map.insert("ln_f".to_string(), Tensor::full(vec![d], 1.0));
        for l in 0..cfg.n_layer {
            for wname in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                map.insert(
                    Self::layer_key(l, wname),
                    Tensor::new(vec![d, d], normal(d * d)).unwrap(),
                );
            }
            map.insert(Self::layer_key(l, "ln1"), Tensor::full(vec![d], 1.0));
            map.insert(Self::layer_key(l, "ln2"), Tensor::full(vec![d], 1.0));
            map.insert(
                Self::layer_key(l, "router"),
                Tensor::new(vec![d, n], normal(d * n)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wg"),
                Tensor::new(vec![n, d, m], normal(n * d * m)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wu"),
                Tensor::new(vec![n, d, m], normal(n * d * m)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wd"),
                Tensor::new(vec![n, m, d], normal(n * m * d)).unwrap(),
            );
            if cfg.shared {
                let ms = cfg.m_shared;
                map.insert(
                    Self::layer_key(l, "shared.wg"),
                    Tensor::new(vec![d, ms], normal(d * ms)).unwrap(),
                );
                map.insert(
                    Self::layer_key(l, "shared.wu"),
                    Tensor::new(vec![d, ms], normal(d * ms)).unwrap(),
                );
                map.insert(
                    Self::layer_key(l, "shared.wd"),
                    Tensor::new(vec![ms, d], normal(ms * d)).unwrap(),
                );
            }
        }
        Self { map, qmap: BTreeMap::new() }
    }

    /// Build the compact r-expert weight set for `lm_logits_*_r{r}`:
    /// keeps `keep[l]` expert slots per layer in the given order.
    pub fn to_compact(&self, cfg: &ModelCfg, keep: &[Vec<usize>]) -> Result<Weights> {
        let r = keep[0].len();
        anyhow::ensure!(
            keep.iter().all(|k| k.len() == r),
            "compact variant needs a uniform expert count per layer"
        );
        let mut out = self.map.clone();
        for (l, keep_l) in keep.iter().enumerate().take(cfg.n_layer) {
            for suffix in ["exp.wg", "exp.wu", "exp.wd"] {
                let full = self.get(&Self::layer_key(l, suffix))?;
                let mut sh = full.shape().to_vec();
                sh[0] = r;
                let mut t = Tensor::zeros(sh);
                for (slot, &orig) in keep_l.iter().enumerate() {
                    t.set_index(slot, &full.index(orig));
                }
                out.insert(Self::layer_key(l, suffix), t);
            }
        }
        Ok(Weights { map: out, qmap: BTreeMap::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights() -> Weights {
        let mut map = BTreeMap::new();
        map.insert("embed".into(), Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect()).unwrap());
        for l in 0..2 {
            let pre = format!("layer{l:02}.");
            map.insert(
                format!("{pre}exp.wg"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| x as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}exp.wu"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| (x * 2) as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}exp.wd"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| (x * 3) as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}router"),
                Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap(),
            );
        }
        Weights::new(map)
    }

    #[test]
    fn save_load_roundtrip() {
        let w = tiny_weights();
        let tmp = std::env::temp_dir().join("hcwt_test.hcwt");
        w.save(&tmp).unwrap();
        let w2 = Weights::load(&tmp).unwrap();
        assert_eq!(w.len(), w2.len());
        for name in w.names() {
            assert_eq!(w.get(name).unwrap(), w2.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn v2_quantized_roundtrip() {
        let mut w = tiny_weights();
        for l in 0..2 {
            for suffix in ["exp.wg", "exp.wu", "exp.wd"] {
                let key = Weights::layer_key(l, suffix);
                let qt = QuantTensor::from_f32(w.get(&key).unwrap()).unwrap();
                w.insert_quant(key, qt);
            }
        }
        assert!(w.is_quantized());
        assert_eq!(w.quant_len(), 6);
        assert_eq!(w.n_experts().unwrap(), 3);
        assert_eq!(w.n_layers(), 2);
        let tmp = std::env::temp_dir().join("hcwt_v2_test.hcwt");
        w.save(&tmp).unwrap();
        let bytes = std::fs::read(&tmp).unwrap();
        assert_eq!(&bytes[4..8], &2u32.to_le_bytes(), "quantized file must be v2");
        let w2 = Weights::from_bytes(&bytes).unwrap();
        assert_eq!(w2.quant_len(), 6);
        for name in w.quant_names() {
            assert_eq!(w.quant_get(name).unwrap(), w2.quant_get(name).unwrap(), "{name}");
        }
        for name in w.names() {
            assert_eq!(w.get(name).unwrap(), w2.get(name).unwrap(), "{name}");
        }
        // expert accessor refuses the quantized variant descriptively
        let err = w.expert(0, 0).unwrap_err().to_string();
        assert!(err.contains("quantized"), "{err}");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn v1_files_stay_version_1_and_byte_exact() {
        let w = tiny_weights();
        let tmp = std::env::temp_dir().join("hcwt_v1_test.hcwt");
        w.save(&tmp).unwrap();
        let bytes = std::fs::read(&tmp).unwrap();
        assert_eq!(&bytes[4..8], &1u32.to_le_bytes(), "f32-only file must stay v1");
        let w2 = Weights::from_bytes(&bytes).unwrap();
        w2.save(&tmp).unwrap();
        assert_eq!(bytes, std::fs::read(&tmp).unwrap(), "v1 round-trip must be byte-exact");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn quant_tensor_shape_checks() {
        assert!(QuantTensor::new(vec![2, 3], vec![1.0; 2], vec![0i8; 6]).is_ok());
        assert!(QuantTensor::new(vec![2, 3], vec![1.0; 3], vec![0i8; 6]).is_err());
        assert!(QuantTensor::new(vec![], vec![], vec![]).is_err());
        let t = Tensor::new(vec![2, 2, 3], (0..12).map(|x| x as f32).collect()).unwrap();
        let qt = QuantTensor::from_f32(&t).unwrap();
        let (q, s) = qt.index_slices(1);
        assert_eq!(q.len(), 6);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn expert_accessors() {
        let mut w = tiny_weights();
        let e = w.expert(0, 1).unwrap();
        assert_eq!(e.wg.shape(), &[2, 2]);
        assert_eq!(e.wg.data(), &[4., 5., 6., 7.]);
        let mut e2 = e.clone();
        e2.wg.scale(0.0);
        w.set_expert(0, 1, &e2).unwrap();
        assert_eq!(w.expert(0, 1).unwrap().wg.data(), &[0., 0., 0., 0.]);
        assert_eq!(w.n_experts().unwrap(), 3);
        assert_eq!(w.n_layers(), 2);
    }

    #[test]
    fn content_hash_tracks_values() {
        let w = tiny_weights();
        let base = w.content_hash();
        assert_eq!(base, tiny_weights().content_hash(), "deterministic");
        // a single changed weight value changes the fingerprint
        let mut w2 = tiny_weights();
        let mut e = w2.expert(1, 2).unwrap();
        e.wg.scale(0.5);
        w2.set_expert(1, 2, &e).unwrap();
        assert_ne!(base, w2.content_hash());
        // quantizing moves tensors between sections => different hash
        let mut w3 = tiny_weights();
        let key = Weights::layer_key(0, "exp.wg");
        let qt = QuantTensor::from_f32(w3.get(&key).unwrap()).unwrap();
        w3.insert_quant(key, qt);
        assert_ne!(base, w3.content_hash());
    }

    #[test]
    fn router_column_extraction() {
        let w = tiny_weights();
        // router is [2, 3] row-major: [[0,1,2],[3,4,5]]; column 1 = [1, 4]
        assert_eq!(w.router_column(0, 1).unwrap(), vec![1.0, 4.0]);
        assert!(w.router_column(0, 5).is_err());
    }

    #[test]
    fn flat_concat_order() {
        let w = tiny_weights();
        let e = w.expert(1, 0).unwrap();
        let f = e.flat();
        assert_eq!(f.len(), 12);
        assert_eq!(&f[..4], e.wg.data());
        assert_eq!(&f[4..8], e.wu.data());
        assert_eq!(&f[8..], e.wd.data());
    }
}
