//! Model weights: HCWT binary IO (shared format with `python/compile/export.py`)
//! plus the expert-level accessors the merging/pruning algorithms operate on.
//!
//! Tensor order inside the file is sorted-by-name — the exact order the HLO
//! parameters were lowered in, so `Weights::ordered()` can be fed straight
//! into `runtime::Executable::run`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};

use crate::config::ModelCfg;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"HCWT";

/// Expert weight triple (Eq. 2): gate / up / down matrices.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    /// Gate projection, `[d, m]`.
    pub wg: Tensor,
    /// Up projection, `[d, m]`.
    pub wu: Tensor,
    /// Down projection, `[m, d]`.
    pub wd: Tensor,
}

impl ExpertWeights {
    /// Flattened concatenation [Wg | Wu | Wd] — the paper's "weight" metric.
    pub fn flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.wg.len() + self.wu.len() + self.wd.len());
        v.extend_from_slice(self.wg.data());
        v.extend_from_slice(self.wu.data());
        v.extend_from_slice(self.wd.data());
        v
    }
}

/// A named tensor set (one model checkpoint), sorted by name.
#[derive(Clone, Debug)]
pub struct Weights {
    map: BTreeMap<String, Tensor>,
}

impl Weights {
    /// Wrap an explicit name → tensor map.
    pub fn new(map: BTreeMap<String, Tensor>) -> Self {
        Self { map }
    }

    /// Load an HCWT checkpoint file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes)
    }

    /// Parse HCWT bytes (see `FORMATS.md`).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic {magic:?}");
        }
        let version = r.read_u32::<LittleEndian>()?;
        if version != 1 {
            bail!("unsupported HCWT version {version}");
        }
        let n = r.read_u32::<LittleEndian>()? as usize;
        let mut metas = Vec::with_capacity(n);
        for _ in 0..n {
            let nl = r.read_u32::<LittleEndian>()? as usize;
            let mut nb = vec![0u8; nl];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let ndim = r.read_u32::<LittleEndian>()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.read_u32::<LittleEndian>()? as usize);
            }
            metas.push((name, dims));
        }
        let mut map = BTreeMap::new();
        for (name, dims) in metas {
            let count: usize = dims.iter().product();
            let mut data = vec![0f32; count];
            r.read_f32_into::<LittleEndian>(&mut data)?;
            map.insert(name, Tensor::new(dims, data)?);
        }
        Ok(Self { map })
    }

    /// Write the HCWT serialisation of this weight set.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_u32::<LittleEndian>(1)?;
        w.write_u32::<LittleEndian>(self.map.len() as u32)?;
        for (name, t) in &self.map {
            w.write_u32::<LittleEndian>(name.len() as u32)?;
            w.write_all(name.as_bytes())?;
            w.write_u32::<LittleEndian>(t.shape().len() as u32)?;
            for &d in t.shape() {
                w.write_u32::<LittleEndian>(d as u32)?;
            }
        }
        for t in self.map.values() {
            for &x in t.data() {
                w.write_f32::<LittleEndian>(x)?;
            }
        }
        Ok(())
    }

    /// Tensor by name (error when absent).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Mutable tensor by name.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map.get_mut(name).ok_or_else(|| anyhow!("missing tensor {name:?}"))
    }

    /// Insert or replace a tensor.
    pub fn insert(&mut self, name: String, t: Tensor) {
        self.map.insert(name, t);
    }

    /// Tensor names in sorted order.
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Tensor count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when the checkpoint holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Tensors in sorted-name order (the HLO parameter order).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.map.values().collect()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Total bytes (f32).
    pub fn byte_size(&self) -> usize {
        self.param_count() * 4
    }

    // -- expert accessors ---------------------------------------------------

    /// Canonical HCWT tensor key of a per-layer tensor (`layer{L:02}.{suffix}`)
    /// — the single source of truth for the checkpoint naming scheme, shared
    /// with the native backend.
    pub(crate) fn layer_key(layer: usize, suffix: &str) -> String {
        format!("layer{layer:02}.{suffix}")
    }

    /// Weight triple of expert `idx` in `layer`.
    pub fn expert(&self, layer: usize, idx: usize) -> Result<ExpertWeights> {
        Ok(ExpertWeights {
            wg: self.get(&Self::layer_key(layer, "exp.wg"))?.index(idx),
            wu: self.get(&Self::layer_key(layer, "exp.wu"))?.index(idx),
            wd: self.get(&Self::layer_key(layer, "exp.wd"))?.index(idx),
        })
    }

    /// Overwrite expert `idx` of `layer` with `e`.
    pub fn set_expert(&mut self, layer: usize, idx: usize, e: &ExpertWeights) -> Result<()> {
        self.get_mut(&Self::layer_key(layer, "exp.wg"))?.set_index(idx, &e.wg);
        self.get_mut(&Self::layer_key(layer, "exp.wu"))?.set_index(idx, &e.wu);
        self.get_mut(&Self::layer_key(layer, "exp.wd"))?.set_index(idx, &e.wd);
        Ok(())
    }

    /// Router weight matrix `[d, n]` of `layer`.
    pub fn router(&self, layer: usize) -> Result<&Tensor> {
        self.get(&Self::layer_key(layer, "router"))
    }

    /// Router weight column for one expert (W_R[:, i]) — used by the
    /// "weight" variant of the router-logits metric discussions.
    pub fn router_column(&self, layer: usize, idx: usize) -> Result<Vec<f32>> {
        let r = self.router(layer)?;
        let (d, n) = (r.shape()[0], r.shape()[1]);
        anyhow::ensure!(idx < n, "expert {idx} out of range {n}");
        Ok((0..d).map(|i| r.data()[i * n + idx]).collect())
    }

    /// Number of experts (from the layer-0 gate tensor).
    pub fn n_experts(&self) -> Result<usize> {
        Ok(self.get("layer00.exp.wg")?.shape()[0])
    }

    /// Number of transformer layers (from the layer-key prefixes).
    pub fn n_layers(&self) -> usize {
        self.map
            .keys()
            .filter_map(|k| {
                k.strip_prefix("layer")
                    .and_then(|s| s.get(..2))
                    .and_then(|s| s.parse::<usize>().ok())
            })
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
    }

    /// Synthesize a deterministic random-init checkpoint for `cfg` — the
    /// same tensor names/shapes `python/compile/model.py::init_params`
    /// produces (N(0, 0.02²) weights, unit RMSNorm gains), so the native
    /// backend and the HCWT round-trip can be exercised with no Python or
    /// training in the loop. Identical `(cfg, seed)` always yields an
    /// identical checkpoint.
    pub fn synthesize(cfg: &ModelCfg, seed: u64) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let s = 0.02f32;
        let mut normal = |len: usize| -> Vec<f32> {
            (0..len).map(|_| s * rng.normal() as f32).collect()
        };
        let mut map = BTreeMap::new();
        let (d, m, n) = (cfg.d, cfg.m, cfg.n_exp);
        map.insert(
            "embed".to_string(),
            Tensor::new(vec![cfg.vocab, d], normal(cfg.vocab * d)).unwrap(),
        );
        map.insert(
            "pos".to_string(),
            Tensor::new(vec![cfg.t_max, d], normal(cfg.t_max * d)).unwrap(),
        );
        map.insert("ln_f".to_string(), Tensor::full(vec![d], 1.0));
        for l in 0..cfg.n_layer {
            for wname in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                map.insert(
                    Self::layer_key(l, wname),
                    Tensor::new(vec![d, d], normal(d * d)).unwrap(),
                );
            }
            map.insert(Self::layer_key(l, "ln1"), Tensor::full(vec![d], 1.0));
            map.insert(Self::layer_key(l, "ln2"), Tensor::full(vec![d], 1.0));
            map.insert(
                Self::layer_key(l, "router"),
                Tensor::new(vec![d, n], normal(d * n)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wg"),
                Tensor::new(vec![n, d, m], normal(n * d * m)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wu"),
                Tensor::new(vec![n, d, m], normal(n * d * m)).unwrap(),
            );
            map.insert(
                Self::layer_key(l, "exp.wd"),
                Tensor::new(vec![n, m, d], normal(n * m * d)).unwrap(),
            );
            if cfg.shared {
                let ms = cfg.m_shared;
                map.insert(
                    Self::layer_key(l, "shared.wg"),
                    Tensor::new(vec![d, ms], normal(d * ms)).unwrap(),
                );
                map.insert(
                    Self::layer_key(l, "shared.wu"),
                    Tensor::new(vec![d, ms], normal(d * ms)).unwrap(),
                );
                map.insert(
                    Self::layer_key(l, "shared.wd"),
                    Tensor::new(vec![ms, d], normal(ms * d)).unwrap(),
                );
            }
        }
        Self { map }
    }

    /// Build the compact r-expert weight set for `lm_logits_*_r{r}`:
    /// keeps `keep[l]` expert slots per layer in the given order.
    pub fn to_compact(&self, cfg: &ModelCfg, keep: &[Vec<usize>]) -> Result<Weights> {
        let r = keep[0].len();
        anyhow::ensure!(
            keep.iter().all(|k| k.len() == r),
            "compact variant needs a uniform expert count per layer"
        );
        let mut out = self.map.clone();
        for (l, keep_l) in keep.iter().enumerate().take(cfg.n_layer) {
            for suffix in ["exp.wg", "exp.wu", "exp.wd"] {
                let full = self.get(&Self::layer_key(l, suffix))?;
                let mut sh = full.shape().to_vec();
                sh[0] = r;
                let mut t = Tensor::zeros(sh);
                for (slot, &orig) in keep_l.iter().enumerate() {
                    t.set_index(slot, &full.index(orig));
                }
                out.insert(Self::layer_key(l, suffix), t);
            }
        }
        Ok(Weights { map: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights() -> Weights {
        let mut map = BTreeMap::new();
        map.insert("embed".into(), Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect()).unwrap());
        for l in 0..2 {
            let pre = format!("layer{l:02}.");
            map.insert(
                format!("{pre}exp.wg"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| x as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}exp.wu"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| (x * 2) as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}exp.wd"),
                Tensor::new(vec![3, 2, 2], (0..12).map(|x| (x * 3) as f32).collect()).unwrap(),
            );
            map.insert(
                format!("{pre}router"),
                Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap(),
            );
        }
        Weights::new(map)
    }

    #[test]
    fn save_load_roundtrip() {
        let w = tiny_weights();
        let tmp = std::env::temp_dir().join("hcwt_test.hcwt");
        w.save(&tmp).unwrap();
        let w2 = Weights::load(&tmp).unwrap();
        assert_eq!(w.len(), w2.len());
        for name in w.names() {
            assert_eq!(w.get(name).unwrap(), w2.get(name).unwrap(), "{name}");
        }
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn expert_accessors() {
        let mut w = tiny_weights();
        let e = w.expert(0, 1).unwrap();
        assert_eq!(e.wg.shape(), &[2, 2]);
        assert_eq!(e.wg.data(), &[4., 5., 6., 7.]);
        let mut e2 = e.clone();
        e2.wg.scale(0.0);
        w.set_expert(0, 1, &e2).unwrap();
        assert_eq!(w.expert(0, 1).unwrap().wg.data(), &[0., 0., 0., 0.]);
        assert_eq!(w.n_experts().unwrap(), 3);
        assert_eq!(w.n_layers(), 2);
    }

    #[test]
    fn router_column_extraction() {
        let w = tiny_weights();
        // router is [2, 3] row-major: [[0,1,2],[3,4,5]]; column 1 = [1, 4]
        assert_eq!(w.router_column(0, 1).unwrap(), vec![1.0, 4.0]);
        assert!(w.router_column(0, 5).is_err());
    }

    #[test]
    fn flat_concat_order() {
        let w = tiny_weights();
        let e = w.expert(1, 0).unwrap();
        let f = e.flat();
        assert_eq!(f.len(), 12);
        assert_eq!(&f[..4], e.wg.data());
        assert_eq!(&f[4..8], e.wu.data());
        assert_eq!(&f[8..], e.wd.data());
    }
}
