//! Pruning baselines (Section 2.2 / Table 1): O-prune (Lu et al. 2024),
//! S-prune (He et al. 2024) and F-prune (frequency criterion).
//!
//! Pruning removes experts outright; in the runtime this is an additive
//! router mask of -inf on dropped experts (tokens re-route to the surviving
//! top-k — see DESIGN.md "Key design decisions").

use anyhow::Result;

use crate::calib::{CalibStats, LayerStats};
use crate::util::Rng;

/// Per-layer keep sets.
#[derive(Debug, Clone)]
pub struct PruneResult {
    /// keep[l] = sorted kept expert indices of layer l.
    pub keep: Vec<Vec<usize>>,
}

impl PruneResult {
    /// Check every layer keeps >= `min_keep` sorted, in-range experts.
    pub fn validate(&self, n: usize, min_keep: usize) -> Result<()> {
        for (l, k) in self.keep.iter().enumerate() {
            anyhow::ensure!(k.len() >= min_keep, "layer {l} keeps {} < {min_keep}", k.len());
            anyhow::ensure!(k.iter().all(|&e| e < n), "layer {l} index out of range");
            anyhow::ensure!(k.windows(2).all(|w| w[0] < w[1]), "layer {l} not sorted/unique");
        }
        Ok(())
    }
}

/// Global score-based pruning with a per-layer floor (S-prune dynamic
/// retention: keep the globally top `r * L` scores, >= min_keep per layer).
fn global_topk(scores: &[Vec<f32>], r_avg: usize, min_keep: usize) -> PruneResult {
    let nl = scores.len();
    let n = scores[0].len();
    let total = r_avg * nl;
    let mut pairs: Vec<(usize, usize, f32)> = Vec::new();
    for (l, row) in scores.iter().enumerate() {
        for (e, &s) in row.iter().enumerate() {
            pairs.push((l, e, s));
        }
    }
    pairs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then((a.0, a.1).cmp(&(b.0, b.1))));
    let mut keep: Vec<Vec<usize>> = vec![Vec::new(); nl];
    // first pass: guarantee the floor with each layer's own best experts
    for l in 0..nl {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            scores[l][b].partial_cmp(&scores[l][a]).unwrap().then(a.cmp(&b))
        });
        keep[l] = idx[..min_keep].to_vec();
    }
    let mut used: usize = nl * min_keep;
    for &(l, e, _) in &pairs {
        if used == total {
            break;
        }
        if keep[l].contains(&e) || keep[l].len() >= n {
            continue;
        }
        keep[l].push(e);
        used += 1;
    }
    for k in &mut keep {
        k.sort_unstable();
    }
    PruneResult { keep }
}

/// S-prune: accumulate full-softmax router scores P(x) globally, retain
/// top-scoring experts (variable per layer).
pub fn s_prune(stats: &CalibStats, r_avg: usize, min_keep: usize) -> PruneResult {
    let scores: Vec<Vec<f32>> = stats.layers.iter().map(|l| l.probs_sum.clone()).collect();
    global_topk(&scores, r_avg, min_keep)
}

/// F-prune: same mechanism with activation frequency as the criterion.
pub fn f_prune(stats: &CalibStats, r_avg: usize, min_keep: usize) -> PruneResult {
    let scores: Vec<Vec<f32>> = stats.layers.iter().map(|l| l.counts.clone()).collect();
    global_topk(&scores, r_avg, min_keep)
}

// ---------------------------------------------------------------------------
// O-prune
// ---------------------------------------------------------------------------

/// Replay one SMoE layer over the subsampled calibration tokens with only
/// `subset` experts routable; returns Σ_t ||y_orig(t) - y_subset(t)||².
///
/// Uses the per-expert raw outputs and router-logit profiles captured by the
/// calibration pass, so no PJRT execution is needed in the inner loop (the
/// paper evaluates ~1e4 subsets per layer — this must be cheap).
pub fn layer_output_deviation(layer: &LayerStats, subset: &[usize], k: usize) -> f64 {
    let t_sub = layer.rl_sub.shape()[0];
    let n = layer.rl_sub.shape()[1];
    let d = layer.raw_sub.shape()[2];
    let mut keep_mask = vec![false; n];
    for &e in subset {
        keep_mask[e] = true;
    }
    let mut total = 0f64;
    let raw = layer.raw_sub.data(); // [n, t_sub, d]
    let rl = layer.rl_sub.data(); // [t_sub, n]
    let mut y_orig = vec![0f32; d];
    let mut y_new = vec![0f32; d];
    for t in 0..t_sub {
        let logits = &rl[t * n..(t + 1) * n];
        topk_combine(logits, None, k, raw, t, t_sub, d, &mut y_orig);
        topk_combine(logits, Some(&keep_mask), k, raw, t, t_sub, d, &mut y_new);
        let mut err = 0f64;
        for j in 0..d {
            let diff = (y_orig[j] - y_new[j]) as f64;
            err += diff * diff;
        }
        total += err;
    }
    total
}

/// Top-k softmax combine of per-expert outputs for one token.
#[allow(clippy::too_many_arguments)]
fn topk_combine(
    logits: &[f32],
    keep: Option<&[bool]>,
    k: usize,
    raw: &[f32],
    t: usize,
    t_sub: usize,
    d: usize,
    out: &mut [f32],
) {
    let n = logits.len();
    // select top-k among allowed experts
    let mut idx: Vec<usize> = (0..n)
        .filter(|&e| keep.map_or(true, |m| m[e]))
        .collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    // softmax over the selected logits
    let mx = idx.iter().map(|&e| logits[e]).fold(f32::NEG_INFINITY, f32::max);
    let mut ws: Vec<f32> = idx.iter().map(|&e| (logits[e] - mx).exp()).collect();
    let s: f32 = ws.iter().sum();
    for w in &mut ws {
        *w /= s;
    }
    out.iter_mut().for_each(|x| *x = 0.0);
    for (pos, &e) in idx.iter().enumerate() {
        let row = &raw[(e * t_sub + t) * d..(e * t_sub + t) * d + d];
        let w = ws[pos];
        for j in 0..d {
            out[j] += w * row[j];
        }
    }
}

/// O-prune: per layer, search subsets of size `r` minimising the layer
/// output deviation. Enumerates exhaustively when C(n, r) <= `samples`,
/// otherwise samples `samples` random subsets (the paper's O-prune(1e5)
/// fallback for Qwen).
pub fn o_prune(stats: &CalibStats, r: usize, k: usize, samples: usize, seed: u64) -> PruneResult {
    let n = stats.n_experts();
    let keep = stats
        .layers
        .iter()
        .enumerate()
        .map(|(li, layer)| {
            let mut best: (f64, Vec<usize>) = (f64::INFINITY, (0..r).collect());
            let mut consider = |subset: &[usize]| {
                let dev = layer_output_deviation(layer, subset, k);
                if dev < best.0 {
                    best = (dev, subset.to_vec());
                }
            };
            if n_choose_r(n, r) <= samples as u128 {
                enumerate_subsets(n, r, &mut consider);
            } else {
                let mut rng = Rng::new(seed ^ (li as u64).wrapping_mul(0x9E37));
                for _ in 0..samples {
                    let mut s = rng.choose_distinct(n, r);
                    s.sort_unstable();
                    consider(&s);
                }
            }
            best.1
        })
        .collect();
    PruneResult { keep }
}

/// Binomial coefficient C(n, r) (saturating).
pub fn n_choose_r(n: usize, r: usize) -> u128 {
    if r > n {
        return 0;
    }
    let r = r.min(n - r);
    let mut num = 1u128;
    let mut den = 1u128;
    for i in 0..r {
        num = num.saturating_mul((n - i) as u128);
        den = den.saturating_mul((i + 1) as u128);
    }
    num / den
}

fn enumerate_subsets<F: FnMut(&[usize])>(n: usize, r: usize, f: &mut F) {
    let mut cur = Vec::with_capacity(r);
    fn rec<F: FnMut(&[usize])>(start: usize, n: usize, r: usize, cur: &mut Vec<usize>, f: &mut F) {
        if cur.len() == r {
            f(cur);
            return;
        }
        for i in start..n {
            if n - i < r - cur.len() {
                break;
            }
            cur.push(i);
            rec(i + 1, n, r, cur, f);
            cur.pop();
        }
    }
    rec(0, n, r, &mut cur, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_grouped;
    use crate::tensor::Tensor;

    fn stats_with(counts: Vec<Vec<f32>>, probs: Vec<Vec<f32>>) -> CalibStats {
        let layers = counts
            .into_iter()
            .zip(probs)
            .map(|(c, p)| {
                let n = c.len();
                let mut l = synthetic_grouped(n, 4, &[(0..n).collect()], 0.0, 1);
                l.counts = c;
                l.probs_sum = p;
                l
            })
            .collect();
        CalibStats { domain: "test".into(), layers, n_tokens: 100 }
    }

    #[test]
    fn f_prune_keeps_frequent() {
        let st = stats_with(
            vec![vec![10., 1., 8., 1.], vec![1., 9., 1., 7.]],
            vec![vec![0.; 4]; 2],
        );
        let p = f_prune(&st, 2, 2);
        assert_eq!(p.keep[0], vec![0, 2]);
        assert_eq!(p.keep[1], vec![1, 3]);
        p.validate(4, 2).unwrap();
    }

    #[test]
    fn s_prune_dynamic_retention() {
        // layer 0 has globally dominant scores -> keeps 3; layer 1 floor 2
        let st = stats_with(
            vec![vec![0.; 4]; 2],
            vec![vec![10., 9., 8., 0.1], vec![1., 0.9, 0.2, 0.1]],
        );
        let p = s_prune(&st, 2, 2); // wait: r_avg=2, total=4? adjust below
        let total: usize = p.keep.iter().map(|k| k.len()).sum();
        assert_eq!(total, 4);
        assert!(p.keep[0].len() >= 2 && p.keep[1].len() >= 2);
        p.validate(4, 2).unwrap();
    }

    #[test]
    fn s_prune_shifts_budget_to_hot_layer() {
        let st = stats_with(
            vec![vec![0.; 4]; 2],
            vec![vec![10., 9., 8., 7.], vec![1., 0.9, 0.2, 0.1]],
        );
        let p = s_prune(&st, 3, 2);
        assert_eq!(p.keep[0].len(), 4, "hot layer takes the spare budget");
        assert_eq!(p.keep[1].len(), 2);
    }

    #[test]
    fn choose_counts() {
        assert_eq!(n_choose_r(8, 4), 70);
        assert_eq!(n_choose_r(16, 8), 12870);
        assert_eq!(n_choose_r(4, 0), 1);
    }

    #[test]
    fn enumerate_matches_choose() {
        let mut cnt = 0usize;
        enumerate_subsets(6, 3, &mut |_| cnt += 1);
        assert_eq!(cnt as u128, n_choose_r(6, 3));
    }

    #[test]
    fn o_prune_finds_redundant_experts_droppable() {
        // Build a layer where experts {0,1} are identical and {2,3} are
        // identical: dropping one of each pair gives ~zero deviation, so
        // O-prune at r=2 must keep one from each pair.
        let n = 4;
        let t_sub = 8;
        let d = 3;
        let mut l = synthetic_grouped(n, d, &[vec![0, 1], vec![2, 3]], 0.0, 2);
        let mut raw = vec![0f32; n * t_sub * d];
        for e in 0..n {
            let base = if e < 2 { 1.0 } else { -1.0 };
            for t in 0..t_sub {
                for j in 0..d {
                    raw[(e * t_sub + t) * d + j] = base * (t as f32 + 1.0) * (j as f32 + 1.0);
                }
            }
        }
        l.raw_sub = Tensor::new(vec![n, t_sub, d], raw).unwrap();
        // router prefers expert 0 and 2 but sometimes 1 and 3
        let mut rl = vec![0f32; t_sub * n];
        for t in 0..t_sub {
            rl[t * n] = 2.0;
            rl[t * n + 1] = 1.5;
            rl[t * n + 2] = 1.8;
            rl[t * n + 3] = 1.2;
        }
        l.rl_sub = Tensor::new(vec![t_sub, n], rl).unwrap();
        let st = CalibStats { domain: "t".into(), layers: vec![l], n_tokens: 8 };
        let p = o_prune(&st, 2, 2, 100, 7);
        let kept = &p.keep[0];
        assert_eq!(kept.len(), 2);
        assert!(kept.contains(&0) || kept.contains(&1), "one of the first pair");
        assert!(kept.contains(&2) || kept.contains(&3), "one of the second pair");
        // deviation at the chosen subset should be ~0
        let dev = layer_output_deviation(&st.layers[0], kept, 2);
        assert!(dev < 1e-6, "deviation {dev}");
    }
}
