//! Paged KV-cache pool: budgeted block storage for decode sequences.
//!
//! The flat [`crate::backend::KvCache`] grows one unbounded `Vec<f32>` pair
//! per layer per sequence, so a burst of long-context generations simply
//! OOMs the process the merged model was supposed to fit — the exact
//! deployment failure the paper's memory pitch (PAPER.md §1) is about.
//! This module replaces that with the vLLM-style paged design:
//!
//! * **One arena, fixed-size blocks.** A [`KvPool`] owns a single `Vec<f32>`
//!   arena carved into blocks of [`DEFAULT_BLOCK_TOKENS`] token positions ×
//!   `2 · n_layer · d` floats (all layers' K and V rows for those
//!   positions live in one block). The arena size is the *budget*: the
//!   serving executor sizes it from `HCSMOE_KV_BUDGET_MB` and admission
//!   control guarantees allocations never exceed it.
//! * **Block tables.** A sequence is a [`PagedSeq`]: an ordered table of
//!   block ids plus its token count. Attention reads K/V through the
//!   table (per-block gather) instead of assuming contiguity.
//! * **Prefix sharing + copy-on-write.** Full prompt blocks are registered
//!   in a sharing map keyed by the exact token prefix (plus a variant
//!   fingerprint); a later prefill with an identical prefix attaches to
//!   the existing blocks (refcount++) instead of storing a copy — repeated
//!   system prompts cost one copy. Shared blocks are never written:
//!   appending into a shared tail first copies it ([`PagedSeq::prepare_append`]),
//!   and [`PagedSeq::fork`] clones a sequence in O(blocks) by sharing
//!   everything and copying lazily.
//! * **Reservations.** Admission reserves a sequence's worst-case block
//!   count up front ([`KvPool::try_reserve`]); its allocations then draw
//!   from the reservation, so an admitted sequence can never fail an
//!   allocation mid-decode and the executor can make a hard
//!   admit-or-queue decision before prefilling.
//! * **Free-list recycling.** Releasing the last reference to a block
//!   pushes it on a free list; nothing is ever returned to the OS while
//!   the pool lives, so steady-state serving does zero allocator traffic.
//!
//! Sharing safety: K/V values at a position depend only on the token
//! prefix up to it *and*, through the expert-capacity drop rule, on the
//! prefill's total length (capacity grows with `t`). Blocks are therefore
//! only shared between **drop-free** prefills — where dispatch equals the
//! unconstrained dense dispatch and the prefix K/V are bit-identical
//! regardless of prompt length. The native backend checks its dispatch
//! counts per prefill and skips the sharing map entirely when any token
//! was capacity-dropped (the synthesized artifact sets are structurally
//! drop-free, so sharing is always live there).
//!
//! The pool is in-memory only, like the flat cache — there is deliberately
//! no on-disk format for it (FORMATS.md). It is single-threaded by design
//! (the serving executor owns all execution state; [`PoolHandle`] is an
//! `Rc<RefCell<..>>`), matching the single-executor architecture in
//! `SERVING.md`.

use std::cell::{Ref, RefCell, RefMut};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelCfg;

/// Token positions per block (the paging granularity). 16 tokens keeps
/// per-sequence waste under one block (≤ 15 positions) while making the
/// per-block attention gather long enough to amortise the table walk.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Environment variable holding the pool budget in MiB (re-exported from
/// [`crate::config::env`], where every runtime knob parses).
pub use crate::config::env::{DEFAULT_KV_BUDGET_MB, KV_BUDGET_ENV};

/// Sharing-map key: a variant fingerprint (mask, remap, slot count,
/// quantization AND weight content — so different model variants never
/// alias, including two hot-swapped variants with identical structure
/// but different merged weights) plus the exact token prefix the block's
/// K/V were computed from. Using the tokens themselves — not a hash of
/// them — makes false sharing impossible.
type SharedKey = (u64, Vec<i32>);

/// Per-block bookkeeping: reference count plus the sharing-map key (so the
/// entry can be dropped when the block is freed).
struct BlockMeta {
    refs: u32,
    shared_key: Option<SharedKey>,
}

/// Point-in-time pool counters (the serving metrics gauges read these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Blocks in the arena (the budget).
    pub total_blocks: usize,
    /// Physical blocks currently referenced by at least one sequence.
    pub in_use: usize,
    /// Physical blocks referenced by more than one sequence (prefix
    /// sharing / forks in effect).
    pub shared: usize,
    /// Reserved-but-not-yet-allocated blocks (admission headroom already
    /// promised to admitted sequences).
    pub reserved: usize,
    /// High-water mark of `in_use` over the pool's lifetime.
    pub peak_in_use: usize,
    /// Bytes per block.
    pub block_bytes: usize,
}

impl PoolStats {
    /// Physical blocks not referenced by any sequence.
    pub fn free(&self) -> usize {
        self.total_blocks - self.in_use
    }

    /// Bytes currently resident in referenced blocks.
    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.block_bytes
    }
}

/// The budgeted block arena. See the module docs for the design. One pool
/// safely spans every variant a server hot-swaps through: the sharing map
/// is fingerprint-scoped (and the fingerprint covers weight content), so
/// only block geometry — bound to one `(n_layer, d)` — limits reuse.
pub struct KvPool {
    n_layer: usize,
    d: usize,
    block_tokens: usize,
    arena: Vec<f32>,
    meta: Vec<BlockMeta>,
    free: Vec<usize>,
    in_use: usize,
    reserved: usize,
    peak_in_use: usize,
    /// Blocks with refcount > 1, maintained incrementally by
    /// [`Self::retain`]/[`Self::release`] so [`Self::stats`] is O(1) (the
    /// serving executor publishes gauges every loop iteration).
    shared_count: usize,
    sharing: HashMap<SharedKey, usize>,
}

impl KvPool {
    /// A pool of `total_blocks` blocks for the given geometry.
    pub fn new(n_layer: usize, d: usize, block_tokens: usize, total_blocks: usize) -> Result<Self> {
        ensure!(
            n_layer >= 1 && d >= 1 && block_tokens >= 1,
            "kv pool geometry must be non-zero (n_layer={n_layer}, d={d}, block_tokens={block_tokens})"
        );
        ensure!(total_blocks >= 1, "kv pool needs at least one block");
        let block_floats = block_tokens * 2 * n_layer * d;
        let mut meta = Vec::with_capacity(total_blocks);
        for _ in 0..total_blocks {
            meta.push(BlockMeta { refs: 0, shared_key: None });
        }
        Ok(Self {
            n_layer,
            d,
            block_tokens,
            arena: vec![0f32; total_blocks * block_floats],
            meta,
            // pop() takes from the back; seed in reverse so blocks hand
            // out in ascending order (stable, debuggable layouts)
            free: (0..total_blocks).rev().collect(),
            in_use: 0,
            reserved: 0,
            peak_in_use: 0,
            shared_count: 0,
            sharing: HashMap::new(),
        })
    }

    /// A pool for one model config under a byte budget: as many blocks as
    /// fit in `budget_bytes`. Errors when the budget cannot hold even one
    /// block (an unserviceable configuration, better rejected at startup
    /// than deadlocking admission later).
    pub fn for_model(cfg: &ModelCfg, budget_bytes: usize, block_tokens: usize) -> Result<Self> {
        let block_bytes = cfg.kv_block_bytes(block_tokens);
        let blocks = budget_bytes / block_bytes;
        ensure!(
            blocks >= 1,
            "kv budget of {budget_bytes} B cannot hold a single {block_bytes} B block \
             (raise {KV_BUDGET_ENV})"
        );
        Self::new(cfg.n_layer, cfg.d, block_tokens, blocks)
    }

    /// Layers per block (the model's layer count).
    pub fn n_layer(&self) -> usize {
        self.n_layer
    }

    /// Hidden size of each K/V row.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Token positions per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks in the arena (the budget).
    pub fn total_blocks(&self) -> usize {
        self.meta.len()
    }

    /// f32 elements per block.
    pub fn block_floats(&self) -> usize {
        self.block_tokens * 2 * self.n_layer * self.d
    }

    /// Bytes per block.
    pub fn block_bytes(&self) -> usize {
        self.block_floats() * std::mem::size_of::<f32>()
    }

    /// Blocks needed to hold `tokens` positions (ceiling division).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Blocks promised or in use — the admission-control quantity.
    fn committed(&self) -> usize {
        self.in_use + self.reserved
    }

    /// Whether a `blocks`-sized reservation fits the remaining budget.
    pub fn can_reserve(&self, blocks: usize) -> bool {
        self.committed() + blocks <= self.total_blocks()
    }

    /// Promise `blocks` future allocations (admission control). Paired
    /// with per-allocation draws (`alloc(true)`) and [`Self::unreserve`]
    /// for the unused remainder.
    pub fn try_reserve(&mut self, blocks: usize) -> Result<()> {
        ensure!(
            self.can_reserve(blocks),
            "kv pool cannot reserve {blocks} blocks ({} in use, {} reserved, {} total)",
            self.in_use,
            self.reserved,
            self.total_blocks()
        );
        self.reserved += blocks;
        Ok(())
    }

    /// Return an unused reservation remainder.
    pub fn unreserve(&mut self, blocks: usize) {
        debug_assert!(blocks <= self.reserved, "unreserve exceeds outstanding reservation");
        self.reserved = self.reserved.saturating_sub(blocks);
    }

    /// Whether `reserved_backed` reservation-drawing allocations plus
    /// `unreserved` best-effort allocations can all succeed right now.
    /// Used by the batched decode step to verify the whole batch *before*
    /// mutating any sequence.
    pub fn can_alloc(&self, reserved_backed: usize, unreserved: usize) -> bool {
        reserved_backed + unreserved <= self.free.len()
            && unreserved <= self.total_blocks().saturating_sub(self.committed())
    }

    /// Allocate one block (refcount 1). `from_reservation` draws from the
    /// outstanding reservation (guaranteed to succeed for an admitted
    /// sequence); otherwise the allocation is best-effort against the
    /// unreserved remainder of the budget.
    pub fn alloc(&mut self, from_reservation: bool) -> Result<usize> {
        if from_reservation {
            debug_assert!(self.reserved > 0, "reservation draw with none outstanding");
            self.reserved = self.reserved.saturating_sub(1);
        } else {
            ensure!(
                self.committed() < self.total_blocks(),
                "kv pool exhausted ({} blocks: {} in use, {} reserved)",
                self.total_blocks(),
                self.in_use,
                self.reserved
            );
        }
        let b = self
            .free
            .pop()
            .ok_or_else(|| anyhow!("kv pool free list empty with {} in use", self.in_use))?;
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        self.meta[b] = BlockMeta { refs: 1, shared_key: None };
        Ok(b)
    }

    /// Add a reference to an existing block (prefix sharing / fork).
    pub fn retain(&mut self, block: usize) {
        debug_assert!(self.meta[block].refs > 0, "retain of a free block");
        self.meta[block].refs += 1;
        if self.meta[block].refs == 2 {
            self.shared_count += 1;
        }
    }

    /// Drop one reference; the last release recycles the block onto the
    /// free list and removes its sharing-map entry.
    pub fn release(&mut self, block: usize) {
        let m = &mut self.meta[block];
        debug_assert!(m.refs > 0, "release of a free block");
        m.refs -= 1;
        if m.refs == 1 {
            self.shared_count -= 1;
        }
        if m.refs == 0 {
            if let Some(key) = m.shared_key.take() {
                self.sharing.remove(&key);
            }
            self.free.push(block);
            self.in_use -= 1;
        }
    }

    /// Current reference count of a block.
    pub fn refs(&self, block: usize) -> u32 {
        self.meta[block].refs
    }

    /// Look up a registered shared block for an exact prefix key.
    pub fn lookup_shared(&self, fingerprint: u64, prefix: &[i32]) -> Option<usize> {
        // allocation-free probe: HashMap::get with a borrowed key needs the
        // owned key type here (tuple key), so build it once — prefill-only
        // path, not the decode hot loop
        self.sharing.get(&(fingerprint, prefix.to_vec())).copied()
    }

    /// Register a full block as shareable under an exact prefix key.
    pub fn register_shared(&mut self, fingerprint: u64, prefix: &[i32], block: usize) {
        let key = (fingerprint, prefix.to_vec());
        self.meta[block].shared_key = Some(key.clone());
        self.sharing.insert(key, block);
    }

    /// Remove a block's sharing-map registration, if any. Existing
    /// references are untouched — the block just stops being discoverable
    /// by future prefills. Needed when a truncation turns a registered
    /// *full* block into a writable partial tail: the sharing map only
    /// ever serves full, never-written-again blocks, and an in-place
    /// append into a still-registered block would hand later sequences
    /// rows from a different suffix.
    pub fn unregister_shared(&mut self, block: usize) {
        if let Some(key) = self.meta[block].shared_key.take() {
            self.sharing.remove(&key);
        }
    }

    /// Arena start index of the K rows of `layer` in `block` (rows for
    /// local positions `0..block_tokens`, each `d` floats, contiguous).
    pub fn k_start(&self, block: usize, layer: usize) -> usize {
        block * self.block_floats() + layer * 2 * self.block_tokens * self.d
    }

    /// Arena start index of the V rows of `layer` in `block`.
    pub fn v_start(&self, block: usize, layer: usize) -> usize {
        self.k_start(block, layer) + self.block_tokens * self.d
    }

    /// Write one K row at local position `local` of a block/layer.
    pub fn write_k(&mut self, block: usize, layer: usize, local: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        let s = self.k_start(block, layer) + local * self.d;
        self.arena[s..s + self.d].copy_from_slice(row);
    }

    /// Write one V row at local position `local` of a block/layer.
    pub fn write_v(&mut self, block: usize, layer: usize, local: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        let s = self.v_start(block, layer) + local * self.d;
        self.arena[s..s + self.d].copy_from_slice(row);
    }

    /// The raw arena (attention gathers through [`Self::k_start`] /
    /// [`Self::v_start`] offsets into this).
    pub fn arena(&self) -> &[f32] {
        &self.arena
    }

    /// Copy the first `tokens` positions of every layer's K and V rows
    /// from `src` into `dst` (the copy-on-write primitive).
    pub fn copy_block(&mut self, src: usize, dst: usize, tokens: usize) {
        debug_assert!(tokens <= self.block_tokens);
        let n = tokens * self.d;
        for layer in 0..self.n_layer {
            let ks = self.k_start(src, layer);
            let kd = self.k_start(dst, layer);
            self.arena.copy_within(ks..ks + n, kd);
            let vs = self.v_start(src, layer);
            let vd = self.v_start(dst, layer);
            self.arena.copy_within(vs..vs + n, vd);
        }
    }

    /// Current counters (O(1) — `shared` is maintained incrementally, so
    /// per-iteration gauge publishing never scans the block table).
    pub fn stats(&self) -> PoolStats {
        debug_assert_eq!(
            self.shared_count,
            self.meta.iter().filter(|m| m.refs > 1).count(),
            "incremental shared counter out of sync"
        );
        PoolStats {
            total_blocks: self.total_blocks(),
            in_use: self.in_use,
            shared: self.shared_count,
            reserved: self.reserved,
            peak_in_use: self.peak_in_use,
            block_bytes: self.block_bytes(),
        }
    }
}

/// Shared, clonable handle to a [`KvPool`] — the executor creates one and
/// every [`PagedSeq`] carved from it keeps a clone, so dropping a sequence
/// releases its blocks with no explicit free call (the executor-leak class
/// of bug becomes unrepresentable).
#[derive(Clone)]
pub struct PoolHandle(Rc<RefCell<KvPool>>);

impl PoolHandle {
    /// Wrap a pool.
    pub fn new(pool: KvPool) -> Self {
        Self(Rc::new(RefCell::new(pool)))
    }

    /// Immutable access.
    pub fn borrow(&self) -> Ref<'_, KvPool> {
        self.0.borrow()
    }

    /// Mutable access.
    pub fn borrow_mut(&self) -> RefMut<'_, KvPool> {
        self.0.borrow_mut()
    }

    /// Current counters.
    pub fn stats(&self) -> PoolStats {
        self.borrow().stats()
    }

    /// Blocks needed for `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.borrow().blocks_for(tokens)
    }

    /// Blocks in the arena (the budget).
    pub fn total_blocks(&self) -> usize {
        self.borrow().total_blocks()
    }

    /// Whether a reservation of `blocks` fits right now.
    pub fn can_reserve(&self, blocks: usize) -> bool {
        self.borrow().can_reserve(blocks)
    }

    /// Identity of the underlying pool (pointer-derived): two handles with
    /// equal ids share one arena. Used to group per-pool feasibility
    /// checks in the batched decode step.
    pub fn id(&self) -> usize {
        Rc::as_ptr(&self.0) as *const () as usize
    }
}

/// One sequence's view of the pool: its block table, token count, and the
/// remainder of its admission reservation. Dropping the value releases
/// every block reference and returns the unused reservation.
pub struct PagedSeq {
    pool: PoolHandle,
    table: Vec<usize>,
    t: usize,
    reserved: usize,
}

impl PagedSeq {
    /// Start an empty sequence, reserving `reserve_blocks` future
    /// allocations (0 = best-effort, allocations may fail at append time).
    pub fn new(pool: &PoolHandle, reserve_blocks: usize) -> Result<Self> {
        if reserve_blocks > 0 {
            pool.borrow_mut().try_reserve(reserve_blocks)?;
        }
        Ok(Self {
            pool: pool.clone(),
            table: Vec::new(),
            t: 0,
            reserved: reserve_blocks,
        })
    }

    /// The pool this sequence allocates from.
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Block ids in position order.
    pub fn table(&self) -> &[usize] {
        &self.table
    }

    /// Tokens stored.
    pub fn seq_len(&self) -> usize {
        self.t
    }

    /// Unused reservation blocks still held.
    pub fn reserved_remaining(&self) -> usize {
        self.reserved
    }

    /// Resident bytes attributed to this sequence (whole blocks; shared
    /// blocks are counted by every sequence referencing them, so summing
    /// over sequences can exceed the arena's physical use).
    pub fn byte_size(&self) -> usize {
        self.table.len() * self.pool.borrow().block_bytes()
    }

    /// One block allocation, drawing from this sequence's reservation
    /// while any remains.
    fn alloc_block(&mut self) -> Result<usize> {
        let from_res = self.reserved > 0;
        let b = self.pool.borrow_mut().alloc(from_res)?;
        if from_res {
            self.reserved -= 1;
        }
        Ok(b)
    }

    /// What appending one token needs from the pool: `None` when the tail
    /// has a free exclusive slot, `Some(false)` for a fresh block (drawn
    /// from the reservation while one remains), `Some(true)` for a
    /// copy-on-write of a shared tail (always a best-effort allocation —
    /// see [`Self::prepare_append`]). The batched decode step aggregates
    /// this over the batch to verify feasibility before mutating anything.
    pub fn append_block_need(&self) -> Option<bool> {
        let bt = self.pool.borrow().block_tokens();
        if self.t % bt == 0 {
            return Some(false); // tail full (or empty table)
        }
        let tail = *self.table.last().expect("partial tail implies a block");
        if self.pool.borrow().refs(tail) > 1 {
            Some(true)
        } else {
            None
        }
    }

    /// Whether appending one token needs a fresh physical block (fresh
    /// tail, or copy-on-write of a shared tail).
    pub fn append_needs_block(&self) -> bool {
        self.append_block_need().is_some()
    }

    /// Make the tail writable with one free local slot and return
    /// `(block, local)` for the new token's rows. Copy-on-write: a shared
    /// partial tail is first copied into a fresh exclusive block. Does
    /// **not** advance the token count — write the K/V rows for every
    /// layer, then call [`Self::commit_append`].
    pub fn prepare_append(&mut self) -> Result<(usize, usize)> {
        let bt = self.pool.borrow().block_tokens();
        let local = self.t % bt;
        if local == 0 {
            let b = self.alloc_block()?;
            self.table.push(b);
            return Ok((b, 0));
        }
        let tail = *self.table.last().expect("partial tail implies a block");
        if self.pool.borrow().refs(tail) > 1 {
            // Copy-on-write takes a best-effort allocation, NOT a
            // reservation draw: the reservation was sized for the
            // sequence's planned growth (blocks_for of its final length),
            // and a COW is an extra physical block forced by a fork —
            // consuming the reservation here would let a later planned
            // append fail on an admitted sequence.
            let nb = self.pool.borrow_mut().alloc(false)?;
            let mut p = self.pool.borrow_mut();
            p.copy_block(tail, nb, local);
            p.release(tail);
            drop(p);
            *self.table.last_mut().expect("tail exists") = nb;
            return Ok((nb, local));
        }
        Ok((tail, local))
    }

    /// Advance the token count after the rows for a prepared slot were
    /// written for every layer.
    pub fn commit_append(&mut self) {
        self.t += 1;
    }

    /// Fill an empty sequence from per-layer prefill rows (`k[l]`/`v[l]`
    /// are `[t, d]` flattened). Full blocks are deduplicated through the
    /// sharing map when `share` is set (the caller's drop-free check);
    /// partial tails are always exclusive.
    pub fn fill_from_rows(
        &mut self,
        ids: &[i32],
        fingerprint: u64,
        share: bool,
        k: &[Vec<f32>],
        v: &[Vec<f32>],
    ) -> Result<()> {
        ensure!(self.t == 0 && self.table.is_empty(), "fill_from_rows needs an empty sequence");
        let (bt, d, n_layer) = {
            let p = self.pool.borrow();
            (p.block_tokens(), p.d(), p.n_layer())
        };
        let t = ids.len();
        ensure!(k.len() == n_layer && v.len() == n_layer, "prefill rows must cover every layer");
        ensure!(
            k.iter().all(|kb| kb.len() == t * d) && v.iter().all(|vb| vb.len() == t * d),
            "prefill rows must be [t, d] per layer"
        );
        let n_blocks = self.pool.borrow().blocks_for(t);
        for bi in 0..n_blocks {
            let start = bi * bt;
            let end = ((bi + 1) * bt).min(t);
            let tokens = end - start;
            let full = tokens == bt;
            if full && share {
                let existing = self.pool.borrow().lookup_shared(fingerprint, &ids[..end]);
                if let Some(b) = existing {
                    let mut p = self.pool.borrow_mut();
                    p.retain(b);
                    // an attached block consumes admission headroom like an
                    // allocation would, keeping the reservation invariant
                    // (committed never grows past the admission check)
                    if self.reserved > 0 {
                        p.unreserve(1);
                        drop(p);
                        self.reserved -= 1;
                    }
                    self.table.push(b);
                    continue;
                }
            }
            let b = self.alloc_block()?;
            {
                let mut p = self.pool.borrow_mut();
                for (layer, (kb, vb)) in k.iter().zip(v).enumerate() {
                    for local in 0..tokens {
                        let tok = start + local;
                        p.write_k(b, layer, local, &kb[tok * d..(tok + 1) * d]);
                        p.write_v(b, layer, local, &vb[tok * d..(tok + 1) * d]);
                    }
                }
                if full && share {
                    p.register_shared(fingerprint, &ids[..end], b);
                }
            }
            self.table.push(b);
        }
        self.t = t;
        Ok(())
    }

    /// Shrink the sequence to `tokens` positions, releasing every
    /// now-unused tail block and restoring the reservation the released
    /// growth originally consumed — the speculative-decoding rollback
    /// primitive (a rejected draft run must return the sequence to its
    /// pre-draft length without leaking its reserved tail blocks).
    ///
    /// Reservation accounting: every block this sequence *physically
    /// frees* (it held the only reference) is re-added to its
    /// reservation, which can never overcommit — the free decremented
    /// `in_use` by one, so `committed` is unchanged by the
    /// release+re-reserve pair. A released *shared* reference (refcount
    /// still > 0 afterwards) frees no physical block and restores no
    /// reservation: re-growing over those positions will copy-on-write,
    /// which draws best-effort exactly as the original COW did.
    ///
    /// If the new tail is a partial block that was registered in the
    /// sharing map (it was full before the truncation), it is
    /// unregistered: future appends write into it in place, and the map
    /// must never serve a block whose contents can still change.
    pub fn truncate_to(&mut self, tokens: usize) -> Result<()> {
        ensure!(
            tokens <= self.t,
            "truncate_to({tokens}) beyond current length {}",
            self.t
        );
        if tokens == self.t {
            return Ok(());
        }
        let keep = self.pool.borrow().blocks_for(tokens);
        let mut freed = 0usize;
        {
            let mut p = self.pool.borrow_mut();
            for &b in &self.table[keep..] {
                if p.refs(b) == 1 {
                    freed += 1;
                }
                p.release(b);
            }
            if tokens % p.block_tokens() != 0 {
                p.unregister_shared(self.table[keep - 1]);
            }
            p.try_reserve(freed).expect("freed blocks re-reserve infallibly");
        }
        self.reserved += freed;
        self.table.truncate(keep);
        self.t = tokens;
        Ok(())
    }

    /// Clone this sequence in O(blocks): every block (including a partial
    /// tail) is shared by reference; the first append to either clone's
    /// shared tail copies it (copy-on-write). The fork carries no
    /// reservation — its future allocations are best-effort.
    pub fn fork(&self) -> PagedSeq {
        let mut p = self.pool.borrow_mut();
        for &b in &self.table {
            p.retain(b);
        }
        drop(p);
        PagedSeq {
            pool: self.pool.clone(),
            table: self.table.clone(),
            t: self.t,
            reserved: 0,
        }
    }
}

impl Drop for PagedSeq {
    fn drop(&mut self) {
        let mut p = self.pool.borrow_mut();
        for &b in &self.table {
            p.release(b);
        }
        p.unreserve(self.reserved);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: usize) -> PoolHandle {
        PoolHandle::new(KvPool::new(2, 4, 4, blocks).unwrap())
    }

    fn rows(t: usize, d: usize, base: f32) -> Vec<Vec<f32>> {
        (0..2)
            .map(|l| (0..t * d).map(|i| base + (l * 1000 + i) as f32).collect())
            .collect()
    }

    #[test]
    fn geometry_and_blocks_for() {
        let p = pool(8);
        let b = p.borrow();
        assert_eq!(b.block_floats(), 4 * 2 * 2 * 4);
        assert_eq!(b.blocks_for(0), 0);
        assert_eq!(b.blocks_for(1), 1);
        assert_eq!(b.blocks_for(4), 1);
        assert_eq!(b.blocks_for(5), 2);
    }

    #[test]
    fn alloc_free_recycles() {
        let p = pool(2);
        let mut b = p.borrow_mut();
        let x = b.alloc(false).unwrap();
        let y = b.alloc(false).unwrap();
        assert_ne!(x, y);
        assert!(b.alloc(false).is_err(), "pool must refuse past its budget");
        b.release(x);
        assert_eq!(b.stats().in_use, 1);
        let z = b.alloc(false).unwrap();
        assert_eq!(z, x, "freed block must be recycled");
        assert_eq!(b.stats().peak_in_use, 2);
        b.release(y);
        b.release(z);
        assert_eq!(b.stats().in_use, 0);
    }

    #[test]
    fn reservations_gate_unreserved_allocs() {
        let p = pool(3);
        let mut b = p.borrow_mut();
        b.try_reserve(2).unwrap();
        assert!(!b.can_reserve(2));
        assert!(b.can_reserve(1));
        // only one unreserved block remains even though all 3 are free
        let _x = b.alloc(false).unwrap();
        assert!(b.alloc(false).is_err(), "reservation must shield its blocks");
        // reservation draws still succeed
        let y = b.alloc(true).unwrap();
        let z = b.alloc(true).unwrap();
        assert_eq!(b.stats().reserved, 0);
        b.release(y);
        b.release(z);
    }

    #[test]
    fn seq_fill_share_and_release() {
        let p = pool(8);
        let ids: Vec<i32> = (0..6).collect(); // 2 blocks: one full, one partial
        let (k, v) = (rows(6, 4, 0.5), rows(6, 4, 9.5));
        let mut a = PagedSeq::new(&p, 2).unwrap();
        a.fill_from_rows(&ids, 7, true, &k, &v).unwrap();
        assert_eq!(a.seq_len(), 6);
        assert_eq!(a.table().len(), 2);
        assert_eq!(p.stats().in_use, 2);

        // identical prefix: the full block is shared, the tail is not
        let mut b = PagedSeq::new(&p, 2).unwrap();
        b.fill_from_rows(&ids, 7, true, &k, &v).unwrap();
        assert_eq!(p.stats().in_use, 3, "full block deduplicated");
        assert_eq!(p.stats().shared, 1);
        assert_eq!(a.table()[0], b.table()[0]);
        assert_ne!(a.table()[1], b.table()[1]);

        // a different fingerprint must not alias
        let mut c = PagedSeq::new(&p, 2).unwrap();
        c.fill_from_rows(&ids, 8, true, &k, &v).unwrap();
        assert_ne!(c.table()[0], a.table()[0]);

        drop(b);
        assert_eq!(p.stats().in_use, 4, "b's tail freed, shared block retained");
        drop(a);
        drop(c);
        let s = p.stats();
        assert_eq!(s.in_use, 0, "all blocks returned");
        assert_eq!(s.reserved, 0, "all reservations returned");
    }

    #[test]
    fn fork_copies_on_write() {
        let p = pool(8);
        let ids: Vec<i32> = (0..5).collect(); // full block + 1-token tail
        let (k, v) = (rows(5, 4, 1.0), rows(5, 4, 2.0));
        let mut a = PagedSeq::new(&p, 4).unwrap();
        a.fill_from_rows(&ids, 1, true, &k, &v).unwrap();
        let b = a.fork();
        assert_eq!(p.stats().in_use, 2, "fork shares everything");
        assert_eq!(p.stats().shared, 2);

        let tail_before = *a.table().last().unwrap();
        assert!(a.append_needs_block(), "shared partial tail needs COW");
        let (blk, local) = a.prepare_append().unwrap();
        assert_ne!(blk, tail_before, "COW must move the writer to a fresh block");
        assert_eq!(local, 1);
        // the copied prefix rows match the original
        {
            let pl = p.borrow();
            let d = pl.d();
            let old = pl.k_start(tail_before, 0);
            let new = pl.k_start(blk, 0);
            assert_eq!(pl.arena()[old..old + d], pl.arena()[new..new + d]);
        }
        a.commit_append();
        assert_eq!(a.seq_len(), 6);
        assert_eq!(b.seq_len(), 5);
        assert_eq!(p.stats().in_use, 3);
        // the reader's tail is exclusive again; appending needs no copy
        assert!(!b.append_needs_block());
        drop(a);
        drop(b);
        assert_eq!(p.stats().in_use, 0);
    }

    #[test]
    fn truncate_releases_tail_and_restores_reservation() {
        let p = pool(8);
        let ids: Vec<i32> = (0..4).collect(); // exactly one full block
        let (k, v) = (rows(4, 4, 0.0), rows(4, 4, 5.0));
        let mut a = PagedSeq::new(&p, 3).unwrap();
        a.fill_from_rows(&ids, 3, false, &k, &v).unwrap();
        assert_eq!(a.reserved_remaining(), 2);
        // grow into a second and third block (5 more tokens)
        for _ in 0..5 {
            let (b, local) = a.prepare_append().unwrap();
            let row = vec![1.0; 4];
            let mut pl = p.borrow_mut();
            for layer in 0..2 {
                pl.write_k(b, layer, local, &row);
                pl.write_v(b, layer, local, &row);
            }
            drop(pl);
            a.commit_append();
        }
        assert_eq!(a.seq_len(), 9);
        assert_eq!(a.table().len(), 3);
        assert_eq!(a.reserved_remaining(), 0);
        assert_eq!(p.stats().in_use, 3);

        // roll back to 5 tokens: the third block frees, its reservation
        // returns, and the kept partial tail stays usable
        a.truncate_to(5).unwrap();
        assert_eq!(a.seq_len(), 5);
        assert_eq!(a.table().len(), 2);
        assert_eq!(a.reserved_remaining(), 1);
        assert_eq!(p.stats().in_use, 2);
        assert_eq!(p.stats().reserved, 1);

        // re-growing over the rolled-back positions draws the restored
        // reservation — the admission guarantee survives the rollback
        for _ in 0..4 {
            let (b, local) = a.prepare_append().unwrap();
            let row = vec![2.0; 4];
            let mut pl = p.borrow_mut();
            for layer in 0..2 {
                pl.write_k(b, layer, local, &row);
                pl.write_v(b, layer, local, &row);
            }
            drop(pl);
            a.commit_append();
        }
        assert_eq!(a.seq_len(), 9);
        assert_eq!(p.stats().in_use, 3);

        // truncate to a block boundary, then to zero
        a.truncate_to(4).unwrap();
        assert_eq!(a.table().len(), 1);
        a.truncate_to(0).unwrap();
        assert_eq!(a.table().len(), 0);
        assert_eq!(p.stats().in_use, 0);
        drop(a);
        assert_eq!(p.stats().reserved, 0, "drop returns the restored reservation");
    }

    #[test]
    fn truncate_unregisters_partial_tail_and_keeps_shared_refs() {
        let p = pool(8);
        let ids: Vec<i32> = (0..8).collect(); // two full blocks
        let (k, v) = (rows(8, 4, 0.0), rows(8, 4, 5.0));
        let mut a = PagedSeq::new(&p, 2).unwrap();
        a.fill_from_rows(&ids, 7, true, &k, &v).unwrap();
        assert!(p.borrow().lookup_shared(7, &ids[..4]).is_some());
        assert!(p.borrow().lookup_shared(7, &ids).is_some());

        // truncating into block 0 makes it a writable partial tail: it
        // must leave the sharing map (and block 1's registration goes
        // with its free)
        a.truncate_to(2).unwrap();
        assert!(p.borrow().lookup_shared(7, &ids[..4]).is_none());
        assert!(p.borrow().lookup_shared(7, &ids).is_none());
        assert_eq!(p.stats().in_use, 1);

        // a shared (refs > 1) tail released by truncation frees nothing
        // and restores no reservation, but the sharer stays intact
        drop(a);
        let mut b = PagedSeq::new(&p, 2).unwrap();
        b.fill_from_rows(&ids, 9, true, &k, &v).unwrap();
        let c = b.fork();
        let reserved_before = p.stats().reserved;
        b.truncate_to(4).unwrap();
        assert_eq!(p.stats().reserved, reserved_before, "shared release restores nothing");
        assert_eq!(c.seq_len(), 8, "the fork still owns both blocks");
        assert_eq!(p.stats().in_use, 2);
        drop(b);
        drop(c);
        assert_eq!(p.stats().in_use, 0);
        assert_eq!(p.stats().reserved, 0);
    }

    #[test]
    fn budget_too_small_is_rejected_at_construction() {
        let cfg = ModelCfg {
            name: "t".into(),
            n_layer: 2,
            d: 32,
            m: 32,
            n_exp: 4,
            k: 2,
            heads: 2,
            vocab: 64,
            t_max: 64,
            shared: false,
            m_shared: 32,
            cap_factor: 4.0,
            block_c: 4,
        };
        assert!(KvPool::for_model(&cfg, 1, DEFAULT_BLOCK_TOKENS).is_err());
        let p = KvPool::for_model(&cfg, 1 << 20, DEFAULT_BLOCK_TOKENS).unwrap();
        assert_eq!(p.block_bytes(), cfg.kv_block_bytes(DEFAULT_BLOCK_TOKENS));
        assert_eq!(p.total_blocks(), (1 << 20) / p.block_bytes());
    }
}
