//! `ModelContext`: one loaded simulated SMoE model — config, trained
//! weights, and the compiled PJRT executables for its HLO artifacts.
//!
//! A *variant* (merged/pruned model) is represented by [`LoadedModel`]:
//! resident device buffers for its weight set plus its router mask, so the
//! eval/serving hot path never re-uploads weights (DESIGN.md §Perf L3).

use std::sync::{Arc, OnceLock};

use anyhow::{ensure, Context, Result};

use crate::config::{Artifacts, Manifest, ModelCfg};
use crate::data::TokenStream;
use crate::runtime::{Executable, Input, Runtime};
use crate::tensor::Tensor;
use crate::weights::Weights;

pub struct ModelContext {
    pub arts: Artifacts,
    pub manifest: Manifest,
    pub cfg: ModelCfg,
    pub rt: Arc<Runtime>,
    pub base: Weights,
    lm_exe: OnceLock<Executable>,
    calib_exe: OnceLock<Executable>,
}

/// `OnceLock::get_or_try_init` is unstable; this free function provides the
/// same fallible memoisation (a lost init race recomputes, then discards).
fn exe_cached(
    cell: &OnceLock<Executable>,
    load: impl FnOnce() -> Result<Executable>,
) -> Result<&Executable> {
    if let Some(exe) = cell.get() {
        return Ok(exe);
    }
    let exe = load()?;
    Ok(cell.get_or_init(|| exe))
}

/// A model variant ready for execution: weights resident on device + mask.
pub struct LoadedModel {
    pub bufs: Vec<xla::PjRtBuffer>,
    pub mask: Vec<f32>, // [L * n] additive router mask
    pub label: String,
}

impl ModelContext {
    pub fn load(arts: &Artifacts, model: &str) -> Result<Self> {
        let manifest = arts.manifest()?;
        let cfg = arts.model_cfg(model)?;
        let rt = Runtime::cpu()?;
        let base = Weights::load(arts.weights_path(model))
            .with_context(|| format!("loading weights for {model}"))?;
        ensure!(base.n_experts()? == cfg.n_exp, "weights/config expert mismatch");
        Ok(Self {
            arts: arts.clone(),
            manifest,
            cfg,
            rt,
            base,
            lm_exe: OnceLock::new(),
            calib_exe: OnceLock::new(),
        })
    }

    pub fn lm_exe(&self) -> Result<&Executable> {
        exe_cached(&self.lm_exe, || {
            self.rt.load_hlo(self.arts.lm_logits_hlo(&self.cfg.name))
        })
    }

    pub fn calib_exe(&self) -> Result<&Executable> {
        exe_cached(&self.calib_exe, || {
            self.rt.load_hlo(self.arts.calib_hlo(&self.cfg.name))
        })
    }

    /// Zero (keep-everything) router mask.
    pub fn full_mask(&self) -> Vec<f32> {
        vec![0.0; self.cfg.n_layer * self.cfg.n_exp]
    }

    /// Upload a weight set as a resident model variant.
    pub fn load_model(&self, w: &Weights, mask: Vec<f32>, label: &str) -> Result<LoadedModel> {
        ensure!(mask.len() == self.cfg.n_layer * self.cfg.n_exp, "mask size");
        let bufs = self.lm_exe()?.upload_weights(w)?;
        Ok(LoadedModel { bufs, mask, label: label.to_string() })
    }

    /// The original (uncompressed) model as a variant.
    pub fn load_original(&self) -> Result<LoadedModel> {
        self.load_model(&self.base, self.full_mask(), "original")
    }

    /// One scoring execution: ids [B*T] -> logits [B, T, V].
    pub fn run_logits(&self, model: &LoadedModel, ids: &[i32]) -> Result<Tensor> {
        let (b, t) = (self.manifest.eval_b, self.manifest.eval_t);
        ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
        let mask = Tensor::new(
            vec![self.cfg.n_layer, self.cfg.n_exp],
            model.mask.clone(),
        )?;
        let outs = self.lm_exe()?.run_with(
            &model.bufs,
            &[Input::I32(ids.to_vec(), vec![b, t]), Input::F32(mask)],
        )?;
        ensure!(outs.len() == 1, "lm_logits returns a 1-tuple");
        Ok(outs.into_iter().next().unwrap())
    }

    /// Raw calibration pass on the ORIGINAL weights over one token batch
    /// of shape [calib_b, calib_t]. Returns the 8-tuple of stat tensors.
    pub fn run_calib(&self, ids: &[i32]) -> Result<Vec<Tensor>> {
        let (b, t) = (self.manifest.calib_b, self.manifest.calib_t);
        ensure!(ids.len() == b * t, "calib ids must be exactly [{b}, {t}]");
        let exe = self.calib_exe()?;
        let bufs = exe.upload_weights(&self.base)?;
        exe.run_with(&bufs, &[Input::I32(ids.to_vec(), vec![b, t])])
    }

    /// Convenience: calibration statistics over a named domain stream.
    pub fn calibrate(&self, domain: &str) -> Result<crate::calib::CalibStats> {
        let ts = TokenStream::load(self.arts.calib_tokens_path(domain))?;
        crate::calib::CalibStats::collect(self, &ts)
    }

    /// Load the true r-expert compact executable with a compact weight set
    /// and router remap table (Table 20 efficiency path).
    pub fn load_compact(
        &self,
        r: usize,
        weights: &Weights,
        remap: Vec<i32>,
        label: &str,
    ) -> Result<CompactModel> {
        ensure!(remap.len() == self.cfg.n_layer * self.cfg.n_exp, "remap size");
        let exe = self
            .rt
            .load_hlo(self.arts.lm_logits_compact_hlo(&self.cfg.name, r))?;
        let bufs = exe.upload_weights(weights)?;
        Ok(CompactModel { exe, bufs, remap, label: label.to_string(), r })
    }

    /// One scoring execution on a compact variant: ids [B*T] -> [B, T, V].
    pub fn run_logits_compact(&self, model: &CompactModel, ids: &[i32]) -> Result<Tensor> {
        let (b, t) = (self.manifest.eval_b, self.manifest.eval_t);
        ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
        let mask = Tensor::zeros(vec![self.cfg.n_layer, self.cfg.n_exp]);
        let outs = self.exe_run_compact(model, ids, b, t, mask)?;
        ensure!(outs.len() == 1, "compact lm_logits returns a 1-tuple");
        Ok(outs.into_iter().next().unwrap())
    }

    fn exe_run_compact(
        &self,
        model: &CompactModel,
        ids: &[i32],
        b: usize,
        t: usize,
        mask: Tensor,
    ) -> Result<Vec<Tensor>> {
        model.exe.run_with(
            &model.bufs,
            &[
                Input::I32(ids.to_vec(), vec![b, t]),
                Input::F32(mask),
                Input::I32(model.remap.clone(), vec![self.cfg.n_layer, self.cfg.n_exp]),
            ],
        )
    }
}

/// A compact r-expert variant with its own executable.
pub struct CompactModel {
    pub exe: Executable,
    pub bufs: Vec<xla::PjRtBuffer>,
    pub remap: Vec<i32>,
    pub label: String,
    pub r: usize,
}
