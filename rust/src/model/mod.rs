//! `ModelContext`: one loaded simulated SMoE model — config, trained
//! weights, and the execution [`Backend`] that runs it.
//!
//! A *variant* (merged/pruned model) is represented by [`LoadedModel`]: a
//! backend-resident weight set plus its router mask, prepared once and
//! reused across every execution (weights never re-upload on the eval or
//! serving hot path — DESIGN.md §"Key design decisions"). Which engine
//! actually executes — the native CPU interpreter or PJRT — is selected at
//! runtime by `HCSMOE_BACKEND` (see [`crate::backend`]); nothing at this
//! layer or above changes between the two.

use std::sync::OnceLock;

use anyhow::{ensure, Context, Result};

use crate::backend::{
    self, Backend, CacheSnapshot, KvCache, ModelState, PrefillOpts, VerifyOut,
};
use crate::config::{Artifacts, Manifest, ModelCfg};
use crate::data::TokenStream;
use crate::kvpool::{KvPool, PoolHandle, DEFAULT_BLOCK_TOKENS};
use crate::tensor::Tensor;
use crate::weights::Weights;

/// One loaded model: artifacts, config, base weights and the execution
/// backend. All execution flows through the methods below.
pub struct ModelContext {
    /// Artifact directory the model was loaded from.
    pub arts: Artifacts,
    /// Global artifact geometry (batch shapes, task list, reductions).
    pub manifest: Manifest,
    /// Model architecture config.
    pub cfg: ModelCfg,
    /// Original (uncompressed) weights — the merging/pruning input.
    pub base: Weights,
    backend: Box<dyn Backend>,
    base_state: OnceLock<Box<dyn ModelState>>,
}

/// A model variant ready for execution: backend-resident weights + the
/// additive router mask and a display label.
pub struct LoadedModel {
    state: Box<dyn ModelState>,
    /// Additive router mask, `[n_layer * n_exp]` (0 = keep, −1e30 = prune).
    pub mask: Vec<f32>,
    /// Human-readable variant label (method string or "original").
    pub label: String,
}

/// A compact r-expert variant: backend-resident compact weights plus the
/// expert→slot remap table (Table 20 efficiency path).
pub struct CompactModel {
    state: Box<dyn ModelState>,
    /// `[n_layer * n_exp]` original-expert → compact-slot table.
    pub remap: Vec<i32>,
    /// Human-readable variant label.
    pub label: String,
    /// Physical expert slots per layer.
    pub r: usize,
}

impl LoadedModel {
    /// Assemble a variant from an already-resident backend state — the
    /// [`crate::variant`] registry's test seam; normal construction goes
    /// through [`ModelContext::load_model`].
    pub(crate) fn from_parts(
        state: Box<dyn ModelState>,
        mask: Vec<f32>,
        label: &str,
    ) -> Self {
        Self { state, mask, label: label.to_string() }
    }
}

impl ModelContext {
    /// Load a model (config + weights) from an artifact directory and bind
    /// the runtime-selected execution backend.
    pub fn load(arts: &Artifacts, model: &str) -> Result<Self> {
        let manifest = arts.manifest()?;
        let cfg = arts.model_cfg(model)?;
        let base = Weights::load(arts.weights_path(model))
            .with_context(|| format!("loading weights for {model}"))?;
        ensure!(base.n_experts()? == cfg.n_exp, "weights/config expert mismatch");
        let backend = backend::from_env(arts, &cfg)?;
        Ok(Self {
            arts: arts.clone(),
            manifest,
            cfg,
            base,
            backend,
            base_state: OnceLock::new(),
        })
    }

    /// Name of the execution backend in use (`"native"` / `"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Zero (keep-everything) router mask.
    pub fn full_mask(&self) -> Vec<f32> {
        vec![0.0; self.cfg.n_layer * self.cfg.n_exp]
    }

    /// Prepare a weight set as a resident model variant.
    pub fn load_model(&self, w: &Weights, mask: Vec<f32>, label: &str) -> Result<LoadedModel> {
        ensure!(mask.len() == self.cfg.n_layer * self.cfg.n_exp, "mask size");
        let state = self.backend.load_model(w, self.cfg.n_exp)?;
        Ok(LoadedModel { state, mask, label: label.to_string() })
    }

    /// The original (uncompressed) model as a variant.
    pub fn load_original(&self) -> Result<LoadedModel> {
        self.load_model(&self.base, self.full_mask(), "original")
    }

    /// One scoring execution: ids [B*T] -> logits [B, T, V].
    pub fn run_logits(&self, model: &LoadedModel, ids: &[i32]) -> Result<Tensor> {
        let (b, t) = (self.manifest.eval_b, self.manifest.eval_t);
        ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
        self.backend
            .run_logits(model.state.as_ref(), ids, b, t, &model.mask, None)
    }

    /// Start an incremental sequence on a variant: forward the whole
    /// `prompt` once, returning the sequence's KV cache and the last
    /// position's next-token logits (`[vocab]`). The cache is owned by the
    /// caller; any number of sequences can be in flight against one
    /// variant. See [`crate::generate::generate`] for the full loop.
    pub fn prefill(
        &self,
        model: &LoadedModel,
        prompt: &[i32],
    ) -> Result<(Box<dyn KvCache>, Vec<f32>)> {
        ensure!(
            prompt.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            prompt.len(),
            self.cfg.t_max
        );
        let (cache, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            prompt,
            PrefillOpts::new(&model.mask),
        )?;
        Ok((cache.expect("fresh prefill returns a cache"), logits))
    }

    /// A paged KV-cache pool sized for this model under a byte budget
    /// ([`DEFAULT_BLOCK_TOKENS`]-token blocks). The serving executor
    /// creates one per served variant; see `SERVING.md` §"KV memory
    /// model".
    pub fn kv_pool(&self, budget_bytes: usize) -> Result<PoolHandle> {
        Ok(PoolHandle::new(KvPool::for_model(
            &self.cfg,
            budget_bytes,
            DEFAULT_BLOCK_TOKENS,
        )?))
    }

    /// [`Self::prefill`] into the paged block pool: K/V rows live in
    /// fixed-size pool blocks (prefix-shared and refcounted) instead of
    /// per-sequence buffers, and `reserve_tokens` blocks of headroom are
    /// reserved up front so decode can never fail an allocation. The
    /// returned cache works with [`Self::decode`] /
    /// [`Self::decode_batch`] unchanged and is bit-identical to the flat
    /// path.
    pub fn prefill_paged(
        &self,
        model: &LoadedModel,
        prompt: &[i32],
        pool: &PoolHandle,
        reserve_tokens: usize,
    ) -> Result<(Box<dyn KvCache>, Vec<f32>)> {
        ensure!(
            prompt.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            prompt.len(),
            self.cfg.t_max
        );
        let (cache, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            prompt,
            PrefillOpts::new(&model.mask).paged(pool, reserve_tokens),
        )?;
        Ok((cache.expect("fresh prefill returns a cache"), logits))
    }

    /// [`Self::prefill_paged`] on a compact r-expert variant.
    pub fn prefill_paged_compact(
        &self,
        model: &CompactModel,
        prompt: &[i32],
        pool: &PoolHandle,
        reserve_tokens: usize,
    ) -> Result<(Box<dyn KvCache>, Vec<f32>)> {
        ensure!(
            prompt.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            prompt.len(),
            self.cfg.t_max
        );
        let mask = self.full_mask();
        let (cache, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            prompt,
            PrefillOpts::new(&mask)
                .remap(&model.remap)
                .paged(pool, reserve_tokens),
        )?;
        Ok((cache.expect("fresh prefill returns a cache"), logits))
    }

    /// Continue a **chunked prefill**: forward the next `chunk` of prompt
    /// tokens and append their K/V rows to `cache` (flat or paged),
    /// returning the logits after the chunk's last token. Feeding a
    /// prompt through [`Self::prefill`] on its first chunk and
    /// `prefill_resume` on the rest yields a cache and final logits
    /// bit-identical to one whole-prompt [`Self::prefill`] (see the
    /// [`crate::backend::Backend::run_prefill`] contract); the serving
    /// scheduler uses this to interleave decode steps between chunks.
    pub fn prefill_resume(
        &self,
        model: &LoadedModel,
        chunk: &[i32],
        cache: &mut dyn KvCache,
    ) -> Result<Vec<f32>> {
        ensure!(
            cache.seq_len() + chunk.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            cache.seq_len() + chunk.len(),
            self.cfg.t_max
        );
        let (_, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            chunk,
            PrefillOpts::new(&model.mask).resume(cache),
        )?;
        Ok(logits)
    }

    /// [`Self::prefill_resume`] on a compact r-expert variant.
    pub fn prefill_resume_compact(
        &self,
        model: &CompactModel,
        chunk: &[i32],
        cache: &mut dyn KvCache,
    ) -> Result<Vec<f32>> {
        ensure!(
            cache.seq_len() + chunk.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            cache.seq_len() + chunk.len(),
            self.cfg.t_max
        );
        let mask = self.full_mask();
        let (_, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            chunk,
            PrefillOpts::new(&mask).remap(&model.remap).resume(cache),
        )?;
        Ok(logits)
    }

    /// Append one token to an incremental sequence, returning the
    /// next-token logits at the new position (O(t) per call — the KV-cached
    /// decode path).
    pub fn decode(
        &self,
        model: &LoadedModel,
        cache: &mut dyn KvCache,
        token: i32,
    ) -> Result<Vec<f32>> {
        self.backend
            .run_decode(model.state.as_ref(), cache, token, &model.mask, None)
    }

    /// Advance a set of incremental sequences by one token each in a
    /// single batched call (`Backend::run_decode_batch`) — the serving
    /// executor's continuous-batching hot path. Returns one `[vocab]`
    /// logits row per cache, index-aligned with `caches`/`tokens`; each
    /// row is bit-identical to what a standalone [`Self::decode`] on that
    /// cache would produce.
    pub fn decode_batch(
        &self,
        model: &LoadedModel,
        caches: &mut [&mut dyn KvCache],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        self.backend
            .run_decode_batch(model.state.as_ref(), caches, tokens, &model.mask, None)
    }

    /// [`Self::prefill`] on a compact r-expert variant.
    pub fn prefill_compact(
        &self,
        model: &CompactModel,
        prompt: &[i32],
    ) -> Result<(Box<dyn KvCache>, Vec<f32>)> {
        ensure!(
            prompt.len() <= self.cfg.t_max,
            "prompt length {} exceeds t_max {}",
            prompt.len(),
            self.cfg.t_max
        );
        let mask = self.full_mask();
        let (cache, logits) = self.backend.run_prefill(
            model.state.as_ref(),
            prompt,
            PrefillOpts::new(&mask).remap(&model.remap),
        )?;
        Ok((cache.expect("fresh prefill returns a cache"), logits))
    }

    /// [`Self::decode`] on a compact r-expert variant.
    pub fn decode_compact(
        &self,
        model: &CompactModel,
        cache: &mut dyn KvCache,
        token: i32,
    ) -> Result<Vec<f32>> {
        let mask = self.full_mask();
        self.backend
            .run_decode(model.state.as_ref(), cache, token, &mask, Some(&model.remap))
    }

    /// [`Self::decode_batch`] on a compact r-expert variant.
    pub fn decode_batch_compact(
        &self,
        model: &CompactModel,
        caches: &mut [&mut dyn KvCache],
        tokens: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        let mask = self.full_mask();
        self.backend.run_decode_batch(
            model.state.as_ref(),
            caches,
            tokens,
            &mask,
            Some(&model.remap),
        )
    }

    /// Multi-position verify — the speculative-decoding scoring step
    /// ([`crate::backend::Backend::run_verify`]): feed `tokens[i]` (a
    /// short run of proposed tokens) to sequence `i` in one batched
    /// forward, returning the next-token logits after every fed position
    /// plus a per-position cache checkpoint for
    /// [`Self::rollback_cache`]. Logits at each position are
    /// bit-identical to sequential [`Self::decode`] calls; a plain
    /// decode step is just a 1-token run, so speculative and plain
    /// sequences interleave in one call.
    pub fn verify(
        &self,
        model: &LoadedModel,
        caches: &mut [&mut dyn KvCache],
        tokens: &[&[i32]],
    ) -> Result<Vec<VerifyOut>> {
        self.backend
            .run_verify(model.state.as_ref(), caches, tokens, &model.mask, None)
    }

    /// [`Self::verify`] on a compact r-expert variant.
    pub fn verify_compact(
        &self,
        model: &CompactModel,
        caches: &mut [&mut dyn KvCache],
        tokens: &[&[i32]],
    ) -> Result<Vec<VerifyOut>> {
        let mask = self.full_mask();
        self.backend.run_verify(
            model.state.as_ref(),
            caches,
            tokens,
            &mask,
            Some(&model.remap),
        )
    }

    /// Live routing statistics accumulated by a resident serving variant
    /// ([`crate::backend::Backend::routing_stats`]): per-layer per-slot
    /// executed-dispatch counts plus routed-token total, recorded by
    /// every prefill/decode/verify call against `model`. `None` on
    /// backends that cannot observe routing (PJRT). The adaptive serving
    /// loop windows these snapshots into a recompression signal.
    pub fn routing_stats(
        &self,
        model: &LoadedModel,
    ) -> Option<crate::backend::RoutingSnapshot> {
        self.backend.routing_stats(model.state.as_ref())
    }

    /// Capture a cache's logical state (length + dispatch bookkeeping)
    /// for a later [`Self::rollback_cache`] — O(n_layer · n_slots), no
    /// K/V rows copied.
    pub fn snapshot_cache(&self, cache: &dyn KvCache) -> Result<CacheSnapshot> {
        self.backend.snapshot_cache(cache)
    }

    /// Shrink a cache back to a snapshot, restoring dispatch bookkeeping
    /// and releasing now-unused paged blocks (with their reservation) —
    /// the speculative-rejection rollback primitive.
    pub fn rollback_cache(&self, cache: &mut dyn KvCache, snap: &CacheSnapshot) -> Result<()> {
        self.backend.rollback_cache(cache, snap)
    }

    /// The base weights as a lazily prepared resident variant (the
    /// calibration input; prepared once, shared by every calib batch).
    fn base_state(&self) -> Result<&dyn ModelState> {
        if let Some(s) = self.base_state.get() {
            return Ok(s.as_ref());
        }
        let s = self.backend.load_model(&self.base, self.cfg.n_exp)?;
        Ok(self.base_state.get_or_init(|| s).as_ref())
    }

    /// Raw calibration pass on the ORIGINAL weights over one token batch
    /// of shape [calib_b, calib_t]. Returns the 8-tuple of stat tensors.
    pub fn run_calib(&self, ids: &[i32]) -> Result<Vec<Tensor>> {
        let (b, t) = (self.manifest.calib_b, self.manifest.calib_t);
        ensure!(ids.len() == b * t, "calib ids must be exactly [{b}, {t}]");
        self.backend.run_calib(
            self.base_state()?,
            ids,
            b,
            t,
            self.manifest.t_sub,
            self.manifest.t_act,
        )
    }

    /// Convenience: calibration statistics over a named domain stream.
    pub fn calibrate(&self, domain: &str) -> Result<crate::calib::CalibStats> {
        let ts = TokenStream::load(self.arts.calib_tokens_path(domain))?;
        crate::calib::CalibStats::collect(self, &ts)
    }

    /// Prepare a true r-expert compact variant from a compact weight set
    /// and router remap table (Table 20 efficiency path).
    pub fn load_compact(
        &self,
        r: usize,
        weights: &Weights,
        remap: Vec<i32>,
        label: &str,
    ) -> Result<CompactModel> {
        ensure!(remap.len() == self.cfg.n_layer * self.cfg.n_exp, "remap size");
        let state = self.backend.load_model(weights, r)?;
        Ok(CompactModel { state, remap, label: label.to_string(), r })
    }

    /// One scoring execution on a compact variant: ids [B*T] -> [B, T, V].
    pub fn run_logits_compact(&self, model: &CompactModel, ids: &[i32]) -> Result<Tensor> {
        let (b, t) = (self.manifest.eval_b, self.manifest.eval_t);
        ensure!(ids.len() == b * t, "ids must be exactly [{b}, {t}]");
        let mask = self.full_mask();
        self.backend.run_logits(
            model.state.as_ref(),
            ids,
            b,
            t,
            &mask,
            Some(&model.remap),
        )
    }
}
