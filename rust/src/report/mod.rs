//! Markdown table emission so every bench target prints paper-shaped rows
//! (and EXPERIMENTS.md can paste them verbatim).

use std::fmt::Write as _;

/// An aligned markdown table under a `###` title.
#[derive(Debug, Clone)]
pub struct Table {
    /// Rendered as a `### title` heading.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (each row matches the header arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (panics on arity mismatch).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Convenience: label + f64 columns rendered at 4 decimals.
    pub fn row_scores(&mut self, label: &str, scores: &[f64]) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(scores.iter().map(|s| format!("{s:.4}")));
        self.row(cells)
    }

    /// Render as column-aligned markdown.
    pub fn to_markdown(&self) -> String {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:<width$}", h, width = w[i]))
            .collect();
        let _ = writeln!(out, "| {} |", hdr.join(" | "));
        let sep: Vec<String> = w.iter().map(|&x| "-".repeat(x)).collect();
        let _ = writeln!(out, "| {} |", sep.join(" | "));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        out
    }

    /// Print the markdown rendering to stdout.
    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }

    /// Append to a results file (used by the bench harness).
    pub fn append_to(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(self.to_markdown().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Method", "acc"]);
        t.row_scores("HC-SMoE", &[0.5716]);
        t.row_scores("M-SMoE", &[0.3221]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| HC-SMoE | 0.5716 |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
