//! Expert similarity metrics (Section 3.2.1) and distances (Eq. 5).
//!
//! The paper's central metric claim: **average expert outputs** capture
//! functional equivalence better than router logits (task-biased) or
//! flattened weights (O(3d²) memory, redundancy-dominated). All three are
//! implemented so the Table 4/5/6 ablations can run.

use anyhow::{ensure, Result};

use crate::calib::LayerStats;
use crate::parallel;
use crate::tensor;
use crate::weights::Weights;

/// Expert-similarity feature choice (Section 3.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// o_j = E_x[E_j(x)] (Eq. 4) — ours.
    ExpertOutput,
    /// Router-logit profile over calibration tokens (M-SMoE).
    RouterLogits,
    /// Flattened [Wg | Wu | Wd] concatenation.
    Weight,
}

impl Metric {
    /// Short label used in method strings and cache keys.
    pub fn short(&self) -> &'static str {
        match self {
            Metric::ExpertOutput => "eo",
            Metric::RouterLogits => "rl",
            Metric::Weight => "weight",
        }
    }

    /// Parse a metric name (`eo` / `rl` / `weight`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "eo" | "expert-output" => Metric::ExpertOutput,
            "rl" | "router-logits" => Metric::RouterLogits,
            "weight" | "w" => Metric::Weight,
            other => anyhow::bail!("unknown metric {other:?}"),
        })
    }
}

/// Pairwise distance function (Eq. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distance {
    /// L2 distance.
    Euclidean,
    /// `1 - cosine similarity`.
    Cosine,
}

/// Per-expert feature vectors for one layer under a metric.
pub fn features(
    metric: Metric,
    weights: &Weights,
    stats: &LayerStats,
    layer: usize,
) -> Result<Vec<Vec<f32>>> {
    let n = stats.counts.len();
    match metric {
        Metric::ExpertOutput => {
            ensure!(stats.mean_out.shape()[0] == n);
            Ok((0..n).map(|i| stats.mean_out.row(i).to_vec()).collect())
        }
        Metric::RouterLogits => Ok((0..n).map(|i| stats.rl_profile(i)).collect()),
        Metric::Weight => (0..n)
            .map(|i| Ok(weights.expert(layer, i)?.flat()))
            .collect(),
    }
}

/// Pairwise distance matrix [n, n] between feature vectors.
///
/// Auto-dispatches between [`distance_matrix_serial`] and
/// [`distance_matrix_with`] on the O(E²·d) work estimate; both produce
/// bit-identical matrices, so the choice is purely a wall-clock decision.
pub fn distance_matrix(feats: &[Vec<f32>], dist: Distance) -> Vec<Vec<f32>> {
    let threads = parallel::default_threads();
    let n = feats.len();
    let work = n * n * feats.first().map_or(0, |f| f.len());
    if threads > 1 && work >= parallel::PAR_AUTO_WORK {
        distance_matrix_with(feats, dist, threads)
    } else {
        distance_matrix_serial(feats, dist)
    }
}

/// Serial reference implementation: upper triangle + mirror.
pub fn distance_matrix_serial(feats: &[Vec<f32>], dist: Distance) -> Vec<Vec<f32>> {
    let n = feats.len();
    let mut d = vec![vec![0f32; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = pair_dist(dist, &feats[i], &feats[j]);
            d[i][j] = v;
            d[j][i] = v;
        }
    }
    d
}

/// Thread-parallel construction: worker w computes the upper-triangle rows
/// i ≡ w (mod threads) — round-robin balances the shrinking rows — and the
/// main thread mirrors. Each entry is evaluated by exactly the serial
/// expression, so the result is bit-identical to
/// [`distance_matrix_serial`] at any thread count.
pub fn distance_matrix_with(feats: &[Vec<f32>], dist: Distance, threads: usize) -> Vec<Vec<f32>> {
    let n = feats.len();
    if threads <= 1 || n < 2 {
        return distance_matrix_serial(feats, dist);
    }
    let t = threads.min(n);
    let per_worker: Vec<Vec<(usize, Vec<f32>)>> = parallel::par_map_chunks(t, t, |workers| {
        let mut rows = Vec::new();
        for w in workers {
            let mut i = w;
            while i < n {
                let fi = &feats[i];
                let mut row = Vec::with_capacity(n - i - 1);
                for fj in &feats[i + 1..] {
                    row.push(pair_dist(dist, fi, fj));
                }
                rows.push((i, row));
                i += t;
            }
        }
        rows
    });
    let mut d = vec![vec![0f32; n]; n];
    for rows in per_worker {
        for (i, row) in rows {
            for (off, v) in row.into_iter().enumerate() {
                let j = i + 1 + off;
                d[i][j] = v;
                d[j][i] = v;
            }
        }
    }
    d
}

#[inline]
fn pair_dist(dist: Distance, a: &[f32], b: &[f32]) -> f32 {
    match dist {
        Distance::Euclidean => tensor::l2_dist(a, b),
        Distance::Cosine => tensor::cosine_dist(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_grouped;
    use crate::util::proptest;

    #[test]
    fn eo_features_match_mean_out_rows() {
        let st = synthetic_grouped(4, 6, &[vec![0, 1], vec![2, 3]], 0.0, 1);
        let w = Weights::new(Default::default());
        let f = features(Metric::ExpertOutput, &w, &st, 0).unwrap();
        assert_eq!(f.len(), 4);
        assert_eq!(f[2], st.mean_out.row(2).to_vec());
        // grouped experts have identical features at zero noise
        assert_eq!(f[0], f[1]);
        assert_ne!(f[0], f[2]);
    }

    #[test]
    fn distance_matrix_properties() {
        proptest::check("dist-matrix", 3, 20, |rng| {
            let n = 2 + rng.below(6);
            let d = 3 + rng.below(5);
            let feats: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
                .collect();
            for dist in [Distance::Euclidean, Distance::Cosine] {
                let m = distance_matrix(&feats, dist);
                for i in 0..n {
                    proptest::ensure(m[i][i] == 0.0, "diagonal zero")?;
                    for j in 0..n {
                        proptest::ensure(m[i][j] == m[j][i], "symmetry")?;
                        proptest::ensure(
                            m[i][j] >= -1e-6,
                            format!("non-negative, got {}", m[i][j]),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rl_profile_extraction() {
        let mut st = synthetic_grouped(3, 4, &[vec![0], vec![1], vec![2]], 0.0, 2);
        // rl_sub [t_sub=16, n=3]: fill with token*10 + expert
        let (t, n) = (16, 3);
        let data: Vec<f32> = (0..t * n).map(|i| ((i / n) * 10 + i % n) as f32).collect();
        st.rl_sub = crate::tensor::Tensor::new(vec![t, n], data).unwrap();
        let p1 = st.rl_profile(1);
        assert_eq!(p1.len(), t);
        assert_eq!(p1[0], 1.0);
        assert_eq!(p1[3], 31.0);
    }
}
