//! The HC-SMoE compression pipeline (the paper's contribution, end to end):
//!
//! calibrate → similarity features → group (HC / K-means / FCM /
//! single-shot / non-uniform) → merge (average / frequency / Fix-Dom /
//! ZipIt) or prune (O/S/F) → a [`CompressedModel`] ready for the runtime.
//!
//! Merging never touches the router (Fig. 3): each cluster's merged expert
//! is written back into *every member slot*, so tokens previously routed to
//! any member now reach the merged expert. Pruning masks router logits with
//! -inf. A uniform merge plan can additionally be exported as a true
//! r-expert compact weight set + remap table for the efficiency experiments
//! (Table 20).

use anyhow::{ensure, Result};

use crate::calib::CalibStats;
use crate::clustering::{
    fcm, hierarchical, kmeans, nonuniform_budgets, single_shot, KmeansInit, Linkage,
};
use crate::merging::{merge_cluster, MergeStrategy};
use crate::model::{LoadedModel, ModelContext};
use crate::pruning::{f_prune, o_prune, s_prune};
use crate::similarity::{distance_matrix, features, Distance, Metric};
use crate::weights::{QuantTensor, Weights};

/// Every compression method of the paper's evaluation.
#[derive(Debug, Clone, PartialEq)]
pub enum Method {
    /// Ours (Section 3.2): HC on a similarity metric + weight-space merge.
    HcSmoe {
        /// Agglomerative linkage criterion.
        linkage: Linkage,
        /// Similarity feature space.
        metric: Metric,
        /// Within-cluster combination rule.
        merge: MergeStrategy,
    },
    /// Non-uniform layer budgets (Appendix B.1).
    HcNonUniform {
        /// Agglomerative linkage criterion.
        linkage: Linkage,
        /// Similarity feature space.
        metric: Metric,
        /// Within-cluster combination rule.
        merge: MergeStrategy,
    },
    /// K-means grouping baseline (Table 5).
    KMeans {
        /// Centroid initialisation.
        init: KmeansInit,
        /// Similarity feature space.
        metric: Metric,
        /// Within-cluster combination rule.
        merge: MergeStrategy,
    },
    /// Fuzzy C-Means soft clustering (Appendix B.5).
    Fcm {
        /// Membership-initialisation seed.
        seed: u64,
    },
    /// One-pass grouping (Table 6); M-SMoE = this with RouterLogits+Frequency.
    SingleShot {
        /// Similarity feature space.
        metric: Metric,
        /// Within-cluster combination rule.
        merge: MergeStrategy,
    },
    /// M-SMoE baseline (Li et al. 2024).
    MSmoe,
    /// O-prune (Lu et al. 2024): subset search on layer-output deviation.
    OPrune {
        /// Subsets sampled per layer when exhaustive search is too big.
        samples: usize,
        /// Subset-sampling seed.
        seed: u64,
    },
    /// S-prune (He et al. 2024): global router-score pruning.
    SPrune,
    /// F-prune: frequency-criterion pruning.
    FPrune,
}

impl Method {
    /// Human-readable method label (also the results cache key).
    pub fn label(&self) -> String {
        match self {
            Method::HcSmoe { linkage, metric, merge } => {
                format!("HC-SMoE({},{},{})", linkage.short(), metric.short(), merge.short())
            }
            Method::HcNonUniform { linkage, metric, merge } => {
                format!("HC-NU({},{},{})", linkage.short(), metric.short(), merge.short())
            }
            Method::KMeans { init, metric, merge } => {
                let i = match init {
                    KmeansInit::Fixed => "fix",
                    KmeansInit::Random { .. } => "rnd",
                };
                format!("K-{}({},{})", i, metric.short(), merge.short())
            }
            Method::Fcm { .. } => "Fuzzy-CMeans".into(),
            Method::SingleShot { metric, merge } => {
                format!("SingleShot({},{})", metric.short(), merge.short())
            }
            Method::MSmoe => "M-SMoE".into(),
            Method::OPrune { samples, .. } => format!("O-prune({samples})"),
            Method::SPrune => "S-prune".into(),
            Method::FPrune => "F-prune".into(),
        }
    }

    /// True for the pruning baselines (no weight merging involved).
    pub fn is_pruning(&self) -> bool {
        matches!(self, Method::OPrune { .. } | Method::SPrune | Method::FPrune)
    }
}

/// A concrete per-layer compression decision.
#[derive(Debug, Clone)]
pub enum PlanKind {
    /// Hard clusters merged in weight space.
    Merge {
        /// groups[l] = clusters of expert indices for layer l.
        groups: Vec<Vec<Vec<usize>>>,
        /// Within-cluster combination rule.
        strategy: MergeStrategy,
    },
    /// FCM soft merge: memberships[l][i][j] of expert i in cluster j,
    /// applied to experts *and router columns* (Appendix B.5).
    SoftMerge {
        /// memberships[l][i][j] of expert i in cluster j.
        memberships: Vec<Vec<Vec<f32>>>,
        /// Retained slots per layer.
        r: usize,
    },
    /// Experts outside the keep sets are masked off in the router.
    Prune {
        /// keep[l] = surviving expert indices of layer l.
        keep: Vec<Vec<usize>>,
    },
}

/// A planned compression: the per-layer decision plus its label.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The concrete per-layer decision.
    pub kind: PlanKind,
    /// Method label (for tables and caches).
    pub label: String,
    /// Requested experts per layer.
    pub r_target: usize,
}

/// Planner turning a [`Method`] + calibration statistics into a [`Plan`].
pub struct Pipeline {
    /// The compression method to plan for.
    pub method: Method,
}

impl Pipeline {
    /// Pipeline for one method.
    pub fn new(method: Method) -> Self {
        Self { method }
    }

    /// Decide the per-layer grouping/pruning for target `r` experts/layer.
    pub fn plan(&self, ctx: &ModelContext, stats: &CalibStats, r: usize) -> Result<Plan> {
        let cfg = &ctx.cfg;
        ensure!(r >= 1 && r <= cfg.n_exp, "target r out of range");
        ensure!(stats.n_layers() == cfg.n_layer, "stats/model layer mismatch");
        let label = self.method.label();
        let kind = match &self.method {
            Method::HcSmoe { linkage, metric, merge } => {
                let groups = (0..cfg.n_layer)
                    .map(|l| {
                        let feats = features(*metric, &ctx.base, &stats.layers[l], l)?;
                        let dist = distance_matrix(&feats, Distance::Euclidean);
                        let c = hierarchical(&dist, r, *linkage);
                        c.validate()?;
                        Ok(c.groups())
                    })
                    .collect::<Result<Vec<_>>>()?;
                PlanKind::Merge { groups, strategy: *merge }
            }
            Method::HcNonUniform { linkage, metric, merge } => {
                let freqs: Vec<Vec<f32>> =
                    stats.layers.iter().map(|l| l.counts.clone()).collect();
                let budgets = nonuniform_budgets(&freqs, r, cfg.k.max(1));
                let groups = (0..cfg.n_layer)
                    .map(|l| {
                        let feats = features(*metric, &ctx.base, &stats.layers[l], l)?;
                        let dist = distance_matrix(&feats, Distance::Euclidean);
                        let c = hierarchical(&dist, budgets[l], *linkage);
                        c.validate()?;
                        Ok(c.groups())
                    })
                    .collect::<Result<Vec<_>>>()?;
                PlanKind::Merge { groups, strategy: *merge }
            }
            Method::KMeans { init, metric, merge } => {
                let groups = (0..cfg.n_layer)
                    .map(|l| {
                        let feats = features(*metric, &ctx.base, &stats.layers[l], l)?;
                        let c = kmeans(&feats, r, *init, 100);
                        c.validate()?;
                        Ok(c.groups())
                    })
                    .collect::<Result<Vec<_>>>()?;
                PlanKind::Merge { groups, strategy: *merge }
            }
            Method::Fcm { seed } => {
                let memberships = (0..cfg.n_layer)
                    .map(|l| {
                        let feats =
                            features(Metric::ExpertOutput, &ctx.base, &stats.layers[l], l)?;
                        Ok(fcm(&feats, r, 2.0, 50, *seed).membership)
                    })
                    .collect::<Result<Vec<_>>>()?;
                PlanKind::SoftMerge { memberships, r }
            }
            Method::SingleShot { metric, merge } => {
                let groups = (0..cfg.n_layer)
                    .map(|l| {
                        let feats = features(*metric, &ctx.base, &stats.layers[l], l)?;
                        let c = single_shot(&feats, &stats.layers[l].counts, r);
                        c.validate()?;
                        Ok(c.groups())
                    })
                    .collect::<Result<Vec<_>>>()?;
                PlanKind::Merge { groups, strategy: *merge }
            }
            Method::MSmoe => {
                return Pipeline::new(Method::SingleShot {
                    metric: Metric::RouterLogits,
                    merge: MergeStrategy::Frequency,
                })
                .plan(ctx, stats, r)
                .map(|mut p| {
                    p.label = "M-SMoE".into();
                    p
                });
            }
            Method::OPrune { samples, seed } => {
                let p = o_prune(stats, r, cfg.k, *samples, *seed);
                p.validate(cfg.n_exp, cfg.k)?;
                PlanKind::Prune { keep: p.keep }
            }
            Method::SPrune => {
                let p = s_prune(stats, r, cfg.k);
                p.validate(cfg.n_exp, cfg.k)?;
                PlanKind::Prune { keep: p.keep }
            }
            Method::FPrune => {
                let p = f_prune(stats, r, cfg.k);
                p.validate(cfg.n_exp, cfg.k)?;
                PlanKind::Prune { keep: p.keep }
            }
        };
        Ok(Plan { kind, label, r_target: r })
    }
}

/// A compressed model: weight set + router mask in the n-slot layout.
pub struct CompressedModel {
    /// Compressed weights in the full n-slot layout.
    pub weights: Weights,
    /// Additive router mask (0 keep, [`MASK_OFF`] pruned).
    pub mask: Vec<f32>,
    /// Method label.
    pub label: String,
    /// The plan that produced this model.
    pub plan: Plan,
}

/// Additive router-mask value that disables an expert.
pub const MASK_OFF: f32 = -1e30;

impl Plan {
    /// Materialise the plan into weights + router mask.
    pub fn apply(&self, ctx: &ModelContext, stats: &CalibStats) -> Result<CompressedModel> {
        let cfg = &ctx.cfg;
        let mut weights = ctx.base.clone();
        let mut mask = vec![0f32; cfg.n_layer * cfg.n_exp];
        match &self.kind {
            PlanKind::Merge { groups, strategy } => {
                for (l, layer_groups) in groups.iter().enumerate() {
                    for members in layer_groups {
                        let merged =
                            merge_cluster(&ctx.base, &stats.layers[l], l, members, *strategy)?;
                        for &e in members {
                            weights.set_expert(l, e, &merged)?;
                        }
                    }
                }
            }
            PlanKind::SoftMerge { memberships, r } => {
                apply_soft_merge(ctx, &mut weights, &mut mask, memberships, *r)?;
            }
            PlanKind::Prune { keep } => {
                for (l, kept) in keep.iter().enumerate() {
                    for e in 0..cfg.n_exp {
                        if !kept.contains(&e) {
                            mask[l * cfg.n_exp + e] = MASK_OFF;
                        }
                    }
                }
            }
        }
        Ok(CompressedModel {
            weights,
            mask,
            label: self.label.clone(),
            plan: self.clone(),
        })
    }

    /// Effective expert count per layer (for parameter accounting).
    pub fn experts_per_layer(&self) -> Vec<usize> {
        match &self.kind {
            PlanKind::Merge { groups, .. } => groups.iter().map(|g| g.len()).collect(),
            PlanKind::SoftMerge { memberships, r } => {
                memberships.iter().map(|_| *r).collect()
            }
            PlanKind::Prune { keep } => keep.iter().map(|k| k.len()).collect(),
        }
    }
}

/// FCM soft merge (Appendix B.5): expert j of the reduced layer is the
/// membership-weighted sum of all experts (Eq. 15, normalised) and the
/// router columns are merged with the same weights; slots >= r are masked.
fn apply_soft_merge(
    ctx: &ModelContext,
    weights: &mut Weights,
    mask: &mut [f32],
    memberships: &[Vec<Vec<f32>>],
    r: usize,
) -> Result<()> {
    let cfg = &ctx.cfg;
    for (l, u) in memberships.iter().enumerate() {
        let n = u.len();
        ensure!(n == cfg.n_exp, "membership rows");
        // merged experts into slots 0..r
        for j in 0..r {
            let col: Vec<f32> = (0..n).map(|i| u[i][j]).collect();
            let s: f32 = col.iter().sum();
            let alphas: Vec<f32> = col.iter().map(|&x| x / s.max(1e-9)).collect();
            let experts: Vec<_> = (0..n)
                .map(|i| ctx.base.expert(l, i))
                .collect::<Result<Vec<_>>>()?;
            let merged = crate::merging::merge_weighted(&experts, &alphas)?;
            weights.set_expert(l, j, &merged)?;
        }
        // merged router columns with the same weights
        let orig_router = ctx.base.router(l)?.clone();
        let (d, n_cols) = (orig_router.shape()[0], orig_router.shape()[1]);
        let router = weights.get_mut(&format!("layer{l:02}.router"))?;
        for j in 0..r {
            let col: Vec<f32> = (0..n).map(|i| u[i][j]).collect();
            let s: f32 = col.iter().sum::<f32>().max(1e-9);
            for row in 0..d {
                let mut v = 0f32;
                for (i, &uij) in col.iter().enumerate() {
                    v += uij * orig_router.data()[row * n_cols + i];
                }
                router.data_mut()[row * n_cols + j] = v / s;
            }
        }
        // dead slots
        for e in r..cfg.n_exp {
            mask[l * cfg.n_exp + e] = MASK_OFF;
        }
    }
    Ok(())
}

impl CompressedModel {
    /// Upload as a runnable variant.
    pub fn load(&self, ctx: &ModelContext) -> Result<LoadedModel> {
        ctx.load_model(&self.weights, self.mask.clone(), &self.label)
    }

    /// The post-merge `quantize` stage ("Merge, Then Compress"): the same
    /// compressed model with every expert triple converted to per-row-
    /// scaled int8. Router mask and plan are unchanged; the label gains a
    /// `+int8` suffix so eval tables and caches keep the variants apart.
    pub fn quantize(&self) -> Result<CompressedModel> {
        Ok(CompressedModel {
            weights: quantize_expert_weights(&self.weights)?,
            mask: self.mask.clone(),
            label: format!("{}+int8", self.label),
            plan: self.plan.clone(),
        })
    }

    /// [`Self::to_compact`] followed by expert quantization: the true
    /// r-expert compact weight set with int8 expert triples, plus the
    /// router remap. This is the serving deployment form of a merged +
    /// compressed variant (smallest bytes, fastest expert GEMMs).
    pub fn to_compact_quantized(&self, ctx: &ModelContext) -> Result<(Weights, Vec<i32>)> {
        let (compact, remap) = self.to_compact(ctx)?;
        Ok((quantize_expert_weights(&compact)?, remap))
    }

    /// Export the true r-expert compact weights + router remap (uniform
    /// merge plans only) for the `lm_logits_*_r{r}` executables.
    pub fn to_compact(&self, ctx: &ModelContext) -> Result<(Weights, Vec<i32>)> {
        let cfg = &ctx.cfg;
        let PlanKind::Merge { groups, .. } = &self.plan.kind else {
            anyhow::bail!("compact export needs a merge plan");
        };
        let r = groups[0].len();
        ensure!(groups.iter().all(|g| g.len() == r), "non-uniform plan");
        let mut keep: Vec<Vec<usize>> = Vec::with_capacity(cfg.n_layer);
        let mut remap = vec![0i32; cfg.n_layer * cfg.n_exp];
        for (l, layer_groups) in groups.iter().enumerate() {
            // slot s holds the merged expert of group s (take any member's
            // slot in the merged n-slot weights — they are identical)
            let mut reps = Vec::with_capacity(r);
            for (s, members) in layer_groups.iter().enumerate() {
                reps.push(members[0]);
                for &e in members {
                    remap[l * cfg.n_exp + e] = s as i32;
                }
            }
            keep.push(reps);
        }
        let compact = self.weights.to_compact(cfg, &keep)?;
        Ok((compact, remap))
    }
}

/// Post-merge int8 weight quantization ("Merge, Then Compress", arXiv
/// 2310.01334: HC-style merging is the gateway to further compression):
/// every layer's `exp.wg/wu/wd` triple moves into the per-row-scaled int8
/// section, while router/attention/norm/shared tensors stay f32. The
/// result serializes as HCWT v2 and the native backend dispatches the
/// quantized SwiGLU kernel for it per layer.
pub fn quantize_expert_weights(w: &Weights) -> Result<Weights> {
    ensure!(!w.is_quantized(), "weights already carry a quantized section");
    let n_layers = w.n_layers();
    ensure!(n_layers > 0, "no layer tensors to quantize");
    let mut out = w.clone();
    for l in 0..n_layers {
        for suffix in ["exp.wg", "exp.wu", "exp.wd"] {
            let key = format!("layer{l:02}.{suffix}");
            let qt = QuantTensor::from_f32(out.get(&key)?)?;
            out.insert_quant(key, qt);
        }
    }
    Ok(out)
}

/// Parameter count after compression (expert slots actually retained).
pub fn compressed_params(cfg: &crate::config::ModelCfg, experts_per_layer: &[usize]) -> usize {
    let embed = cfg.vocab * cfg.d + cfg.t_max * cfg.d + cfg.d;
    let mut total = embed;
    for &r in experts_per_layer {
        let mut per = 4 * cfg.d * cfg.d + 2 * cfg.d + cfg.d * cfg.n_exp;
        per += r * cfg.expert_params();
        if cfg.shared {
            per += 3 * cfg.d * cfg.m_shared;
        }
        total += per;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_distinct() {
        let methods = [
            Method::HcSmoe {
                linkage: Linkage::Average,
                metric: Metric::ExpertOutput,
                merge: MergeStrategy::Frequency,
            },
            Method::MSmoe,
            Method::SPrune,
            Method::FPrune,
            Method::OPrune { samples: 100, seed: 1 },
            Method::Fcm { seed: 1 },
        ];
        let labels: std::collections::HashSet<String> =
            methods.iter().map(|m| m.label()).collect();
        assert_eq!(labels.len(), methods.len());
    }

    #[test]
    fn pruning_flag() {
        assert!(Method::SPrune.is_pruning());
        assert!(!Method::MSmoe.is_pruning());
    }
}
