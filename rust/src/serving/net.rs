//! Streaming HTTP/1.1 front end over a [`Dispatcher`] fleet.
//!
//! Dependency-free by design: a `std::net::TcpListener` accept loop, a
//! hand-rolled request parser for the three routes below, and chunked
//! transfer encoding for live token streams. The protocol is
//! deliberately plain text — this front end exists so the replica fleet
//! can be driven (and its drain semantics pinned) over a real socket,
//! not to be a general API gateway.
//!
//! Routes:
//!
//! - `POST /generate` — body is `key=value` lines:
//!   `prompt=<space-separated token ids>` (required),
//!   `max_new=<n>` (default 16), `eos=<id>`, `draft_k=<k>`,
//!   `priority=interactive|batch`, and optionally
//!   `top_k=<k>` + `temperature=<t>` + `seed=<s>` (all three or none;
//!   default greedy). Responds `200` with a chunked body: one decimal
//!   token id per line as each decode step lands, then a final
//!   `done <finish-reason>` line. Sampling is seeded, so the streamed
//!   sequence is bit-identical to the blocking reply and to offline
//!   [`crate::generate::generate`].
//! - `GET /metrics` — fleet-merged then per-replica counters,
//!   `name value` per line.
//! - `GET /health` — `200 ok`.
//!
//! Backpressure: at most `max_conns` connections are served
//! concurrently; excess connections receive an immediate `503` and are
//! closed, so a burst degrades loudly instead of queueing unboundedly
//! in the accept backlog.
//!
//! Graceful drain ([`HttpServer::shutdown`]): stop accepting, let every
//! in-flight connection run its stream to natural completion, join the
//! accept thread, and only then stop the dispatcher (whose own shutdown
//! answers anything still queued inside an executor). The ordering
//! guarantees every admitted HTTP stream ends with its `done` line —
//! `scripts/check_serve.sh` gates on zero dropped streams.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::{Dispatcher, GenerateRequest, Priority};
use crate::generate::SamplingParams;

/// How long the accept loop sleeps when no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
/// Per-connection socket read timeout (a stalled client cannot pin a
/// connection slot forever).
const READ_TIMEOUT: Duration = Duration::from_secs(5);
/// Stream-receiver poll interval while a generation decodes.
const STREAM_POLL: Duration = Duration::from_millis(100);

/// Parsed `POST /generate` body.
#[derive(Debug, PartialEq)]
struct GenSpec {
    prompt: Vec<i32>,
    max_new: usize,
    eos: Option<i32>,
    draft_k: Option<usize>,
    class: Priority,
    /// `Some((top_k, temperature, seed))` = seeded sampling; `None` =
    /// greedy.
    sample: Option<(usize, f32, u64)>,
}

impl GenSpec {
    fn params(&self) -> SamplingParams {
        match self.sample {
            None => SamplingParams::greedy(self.max_new, self.eos),
            Some((k, temp, seed)) => {
                SamplingParams::top_k(k, temp, seed, self.max_new, self.eos)
            }
        }
    }
}

/// Parse the `key=value`-lines body of `POST /generate`. Pure (no I/O)
/// so the wire grammar is unit-testable without sockets.
fn parse_gen_body(body: &str) -> Result<GenSpec> {
    let mut spec = GenSpec {
        prompt: Vec::new(),
        max_new: 16,
        eos: None,
        draft_k: None,
        class: Priority::Interactive,
        sample: None,
    };
    let (mut top_k, mut temperature, mut seed) = (None, None, None);
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) =
            line.split_once('=').ok_or_else(|| anyhow!("malformed line {line:?}"))?;
        match key {
            "prompt" => {
                spec.prompt = value
                    .split_whitespace()
                    .map(|t| t.parse::<i32>().with_context(|| format!("bad token {t:?}")))
                    .collect::<Result<_>>()?;
            }
            "max_new" => spec.max_new = value.parse().context("bad max_new")?,
            "eos" => spec.eos = Some(value.parse().context("bad eos")?),
            "draft_k" => spec.draft_k = Some(value.parse().context("bad draft_k")?),
            "priority" => {
                spec.class = match value {
                    "interactive" => Priority::Interactive,
                    "batch" => Priority::Batch,
                    other => return Err(anyhow!("unknown priority {other:?}")),
                }
            }
            "top_k" => top_k = Some(value.parse::<usize>().context("bad top_k")?),
            "temperature" => {
                temperature = Some(value.parse::<f32>().context("bad temperature")?)
            }
            "seed" => seed = Some(value.parse::<u64>().context("bad seed")?),
            other => return Err(anyhow!("unknown key {other:?}")),
        }
    }
    if spec.prompt.is_empty() {
        return Err(anyhow!("prompt= is required and must be non-empty"));
    }
    spec.sample = match (top_k, temperature, seed) {
        (None, None, None) => None,
        (Some(k), Some(t), Some(s)) => Some((k, t, s)),
        _ => return Err(anyhow!("top_k/temperature/seed must be given together")),
    };
    Ok(spec)
}

/// Read one HTTP/1.1 request: returns (method, path, body).
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line).context("read request line")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(anyhow!("malformed request line {request_line:?}"));
    }
    // headers: only Content-Length matters for this protocol
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).context("read header")?;
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("read body")?;
    Ok((method, path, String::from_utf8(body).context("non-utf8 body")?))
}

/// Write a plain (non-chunked) response and flush.
fn write_response(stream: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}

/// Write one chunk of a chunked-transfer body.
fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{data}\r\n", data.len())?;
    stream.flush()
}

/// Serve one `POST /generate`: submit through the dispatcher with a
/// live token stream and relay every token as its own chunk the moment
/// it lands, ending with a `done <finish>` line. The generation keeps
/// its bit-identity contract — streaming only changes *when* tokens
/// leave the server, never which tokens.
fn handle_generate(stream: &mut TcpStream, dispatcher: &Dispatcher, body: &str) -> Result<()> {
    let spec = match parse_gen_body(body) {
        Ok(s) => s,
        Err(e) => {
            let _ = write_response(stream, "400 Bad Request", &format!("{e:#}\n"));
            return Ok(());
        }
    };
    let mut req = GenerateRequest::new(&spec.prompt, spec.params()).priority(spec.class);
    if let Some(k) = spec.draft_k {
        req = req.drafter(k);
    }
    let (req, tokens) = req.streaming();
    let (_, reply) = match dispatcher.submit(req) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_response(stream, "503 Service Unavailable", &format!("{e:#}\n"));
            return Ok(());
        }
    };
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    // relay tokens until the stream side closes (the executor drops the
    // sender after the final flush); a client hangup surfaces as a write
    // error here, the executor notices via its reply channel and evicts
    loop {
        match tokens.recv_timeout(STREAM_POLL) {
            Ok(Some(t)) => write_chunk(stream, &format!("{t}\n"))?,
            Ok(None) => continue, // poll tick: generation still decoding
            Err(_) => break,      // sender dropped = end of stream
        }
    }
    let tail = match reply.expect("streaming request owns its receiver").recv() {
        Ok(Ok(g)) => format!("done {:?}\n", g.finish),
        Ok(Err(e)) | Err(e) => format!("error {e:#}\n"),
    };
    write_chunk(stream, &tail)?;
    // terminating zero-length chunk
    write!(stream, "0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Render the fleet metrics page: merged aggregate first, then each
/// replica, `name value` per line.
fn metrics_page(dispatcher: &Dispatcher) -> String {
    let mut out = String::new();
    let render = |out: &mut String, prefix: &str, s: &super::MetricsSnapshot| {
        use std::fmt::Write as _;
        let _ = writeln!(out, "{prefix}gen_requests {}", s.gen_requests);
        let _ = writeln!(out, "{prefix}gen_tokens {}", s.gen_tokens);
        let _ = writeln!(out, "{prefix}prefill_tokens {}", s.prefill_tokens);
        let _ = writeln!(out, "{prefix}decode_steps {}", s.decode_steps);
        let _ = writeln!(out, "{prefix}kv_blocks_in_use {}", s.kv_blocks_in_use);
        let _ = writeln!(out, "{prefix}kv_blocks_total {}", s.kv_blocks_total);
        let _ = writeln!(out, "{prefix}preemptions {}", s.preemptions);
        let _ = writeln!(out, "{prefix}deadline_misses {}", s.deadline_misses);
        let _ = writeln!(out, "{prefix}itl_p50_ms {:.3}", s.itl_p50_ms);
        let _ = writeln!(out, "{prefix}itl_p99_ms {:.3}", s.itl_p99_ms);
        let _ = writeln!(out, "{prefix}swaps {}", s.swaps);
    };
    render(&mut out, "fleet_", &dispatcher.merged());
    for (i, s) in dispatcher.metrics().iter().enumerate() {
        render(&mut out, &format!("replica{i}_"), s);
        use std::fmt::Write as _;
        let _ = writeln!(out, "replica{i}_committed_blocks {}", dispatcher.committed_blocks(i));
    }
    out
}

/// Serve one accepted connection end to end.
fn handle_conn(mut stream: TcpStream, dispatcher: &Dispatcher) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let (method, path, body) = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_response(&mut stream, "400 Bad Request", &format!("{e:#}\n"));
            return;
        }
    };
    let result = match (method.as_str(), path.as_str()) {
        ("POST", "/generate") => handle_generate(&mut stream, dispatcher, &body),
        ("GET", "/metrics") => {
            write_response(&mut stream, "200 OK", &metrics_page(dispatcher)).map_err(Into::into)
        }
        ("GET", "/health") => {
            write_response(&mut stream, "200 OK", "ok\n").map_err(Into::into)
        }
        _ => write_response(&mut stream, "404 Not Found", "no such route\n").map_err(Into::into),
    };
    // write errors mean the client went away — nothing left to tell it
    let _: Result<()> = result;
}

/// Handle to a running HTTP front end.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    dispatcher: Arc<Dispatcher>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// The actually-bound address (resolves port 0, so tests can bind
    /// `127.0.0.1:0` and dial back).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain, strictly ordered: (1) stop accepting — late
    /// connections are no longer picked up; (2) wait for every in-flight
    /// connection to finish its stream naturally (the accept thread
    /// joins only when `live == 0`); (3) stop the dispatcher, whose own
    /// shutdown answers anything still queued inside an executor. Every
    /// stream admitted before the drain therefore ends with its `done`
    /// line, never mid-air.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("http accept thread panicked"))?;
        }
        self.dispatcher.shutdown()
    }
}

/// Bind `addr` and serve the dispatcher fleet over HTTP. `max_conns`
/// bounds concurrent connections — excess arrivals get an immediate
/// `503` (loud backpressure instead of silent backlog growth).
pub fn serve_http(
    dispatcher: Arc<Dispatcher>,
    addr: &str,
    max_conns: usize,
) -> Result<HttpServer> {
    anyhow::ensure!(max_conns > 0, "max_conns must be >= 1");
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let addr = listener.local_addr().context("local_addr")?;
    listener.set_nonblocking(true).context("set_nonblocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let l2 = Arc::new(AtomicU64::new(0));
    let (s2, d2) = (Arc::clone(&stop), Arc::clone(&dispatcher));
    let join = std::thread::Builder::new().name("hcsmoe-http".into()).spawn(move || {
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !s2.load(Ordering::SeqCst) {
            workers.retain(|w| !w.is_finished());
            match listener.accept() {
                Ok((mut conn, _)) => {
                    if l2.load(Ordering::Relaxed) >= max_conns as u64 {
                        let _ = write_response(
                            &mut conn,
                            "503 Service Unavailable",
                            "connection limit reached\n",
                        );
                        continue;
                    }
                    l2.fetch_add(1, Ordering::Relaxed);
                    let (live, disp) = (Arc::clone(&l2), Arc::clone(&d2));
                    let w = std::thread::spawn(move || {
                        handle_conn(conn, &disp);
                        live.fetch_sub(1, Ordering::Relaxed);
                    });
                    workers.push(w);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
        // drain: every admitted connection finishes its stream before
        // the accept thread exits (shutdown joins on this)
        for w in workers {
            let _ = w.join();
        }
    })?;
    Ok(HttpServer { addr, stop, dispatcher, join: Some(join) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_body_parses_full_spec() {
        let spec = parse_gen_body(
            "prompt=1 2 3\nmax_new=8\neos=5\ndraft_k=4\npriority=batch\n",
        )
        .unwrap();
        assert_eq!(
            spec,
            GenSpec {
                prompt: vec![1, 2, 3],
                max_new: 8,
                eos: Some(5),
                draft_k: Some(4),
                class: Priority::Batch,
                sample: None,
            }
        );
    }

    #[test]
    fn gen_body_defaults_and_sampling_triple() {
        let spec = parse_gen_body("prompt=7\ntop_k=3\ntemperature=0.5\nseed=42\n").unwrap();
        assert_eq!(spec.max_new, 16);
        assert_eq!(spec.class, Priority::Interactive);
        assert_eq!(spec.sample, Some((3, 0.5, 42)));
    }

    #[test]
    fn gen_body_rejects_bad_input() {
        assert!(parse_gen_body("max_new=4\n").is_err(), "missing prompt");
        assert!(parse_gen_body("prompt=1\nseed=1\n").is_err(), "partial sampling triple");
        assert!(parse_gen_body("prompt=1\npriority=turbo\n").is_err(), "unknown priority");
        assert!(parse_gen_body("prompt=1\nnope=2\n").is_err(), "unknown key");
        assert!(parse_gen_body("prompt=one two\n").is_err(), "non-numeric tokens");
    }
}
