//! Serving layer: a threaded scoring server with a dynamic batcher.
//!
//! The paper's deployment motivation (Section 1) is memory-constrained
//! *serving* of SMoE models; this module demonstrates the merged models on
//! a live request path: clients submit multiple-choice scoring requests,
//! a dynamic batcher packs rows up to the model's batch size or a
//! deadline (vLLM-router-style size/deadline policy), and a single
//! executor thread owns all execution state (required for the PJRT
//! backend, whose xla handles are not `Send`; the native backend simply
//! inherits the same single-executor design) — everything else is
//! channels. Used by `examples/serve_merged.rs` and the Table 20
//! throughput/latency measurements. Runs offline end to end on the
//! native backend.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::calib::CalibStats;
use crate::config::Artifacts;
use crate::eval::log_softmax_at;
use crate::model::ModelContext;
use crate::pipeline::{Method, Pipeline};

/// One scoring request: score `rows` (token sequences) and return the
/// length-normalised logprob of positions [start, end) per row.
pub struct ScoreRequest {
    /// Rows to score.
    pub rows: Vec<RowSpec>,
    /// Channel receiving the per-row normalised logprobs.
    pub reply: Sender<Vec<f64>>,
    /// Submission time (drives queue-latency metrics).
    pub enqueued: Instant,
}

/// One scored row: a token sequence plus the `[start, end)` span whose
/// logprob is accumulated.
#[derive(Debug, Clone)]
pub struct RowSpec {
    /// Token sequence (padded by the batcher).
    pub seq: Vec<i32>,
    /// First predicted position (prompt length).
    pub start: usize,
    /// One past the last predicted position.
    pub end: usize,
}

/// Live serving counters (shared with clients via `Arc`).
#[derive(Default)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Rows accepted.
    pub rows: AtomicU64,
    /// Device batches executed.
    pub batches: AtomicU64,
    /// Nanoseconds spent executing batches.
    pub busy_ns: AtomicU64,
    /// Nanoseconds requests spent queued (enqueue -> reply).
    pub queue_ns: AtomicU64,
}

impl Metrics {
    /// Consistent-enough copy of the counters for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            busy_s: self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            queue_s: self.queue_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// Point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    /// Requests accepted.
    pub requests: u64,
    /// Rows accepted.
    pub rows: u64,
    /// Device batches executed.
    pub batches: u64,
    /// Seconds spent executing batches.
    pub busy_s: f64,
    /// Seconds requests spent queued.
    pub queue_s: f64,
}

impl MetricsSnapshot {
    /// Rows scored per busy second.
    pub fn rows_per_sec(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.rows as f64 / self.busy_s
        } else {
            0.0
        }
    }

    /// Mean batch occupancy in [0, 1] at the given batch size.
    pub fn mean_batch_fill(&self, batch_size: usize) -> f64 {
        if self.batches > 0 {
            self.rows as f64 / (self.batches as f64 * batch_size as f64)
        } else {
            0.0
        }
    }
}

/// Dynamic-batcher flush policy (size or deadline, whichever first).
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many rows are queued (= executable batch size).
    pub max_rows: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

/// What the executor thread should serve.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Artifact directory the executor loads from.
    pub artifacts_root: String,
    /// Model family name to serve.
    pub model: String,
    /// None = serve the original model; Some = compress first.
    pub compress: Option<(Method, usize, String)>, // (method, r, calib domain)
}

/// Client-side handle to a running scoring server.
pub struct ServerHandle {
    tx: Sender<ScoreRequest>,
    /// Live serving counters.
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    /// Submit one multiple-choice item; returns per-choice normalised
    /// logprobs (blocking).
    pub fn score_item(&self, prompt: &[i32], choices: &[Vec<i32>]) -> Result<Vec<f64>> {
        let rows = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        let (reply, rx) = channel();
        self.tx
            .send(ScoreRequest { rows, reply, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx.recv()?)
    }

    /// A clonable submission channel for client threads.
    pub fn sender(&self) -> Sender<ScoreRequest> {
        self.tx.clone()
    }

    /// Stop the server and join the executor thread. Robust against
    /// still-alive cloned senders: an explicit stop flag breaks the
    /// executor loop even if the channel never disconnects.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

/// Start the executor thread. All PJRT state lives inside it.
pub fn serve(spec: ServeSpec, batcher: BatcherConfig) -> Result<ServerHandle> {
    let (tx, rx) = channel::<ScoreRequest>();
    let metrics = Arc::new(Metrics::default());
    let m2 = Arc::clone(&metrics);
    let stop = Arc::new(AtomicBool::new(false));
    let s2 = Arc::clone(&stop);
    let join = std::thread::Builder::new()
        .name("hcsmoe-executor".into())
        .spawn(move || executor_loop(spec, batcher, rx, m2, s2))?;
    Ok(ServerHandle { tx, metrics, stop, join: Some(join) })
}

fn executor_loop(
    spec: ServeSpec,
    batcher: BatcherConfig,
    rx: Receiver<ScoreRequest>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let arts = Artifacts::new(&spec.artifacts_root);
    let ctx = ModelContext::load(&arts, &spec.model)?;
    let model = match &spec.compress {
        None => ctx.load_original()?,
        Some((method, r, domain)) => {
            let stats: CalibStats = ctx.calibrate(domain)?;
            let plan = Pipeline::new(method.clone()).plan(&ctx, &stats, *r)?;
            plan.apply(&ctx, &stats)?.load(&ctx)?
        }
    };
    let (bsz, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);

    // pending rows with backrefs: (request-id, row-in-request)
    struct Pending {
        req: ScoreRequest,
        scores: Vec<f64>,
        remaining: usize,
    }
    let mut pendings: Vec<Pending> = Vec::new();
    let mut queue: Vec<(usize, usize, RowSpec)> = Vec::new(); // (pending idx, row idx, row)

    let flush = |pendings: &mut Vec<Pending>,
                 queue: &mut Vec<(usize, usize, RowSpec)>|
     -> Result<()> {
        while !queue.is_empty() {
            let take = queue.len().min(bsz);
            let chunk: Vec<_> = queue.drain(..take).collect();
            let mut ids = vec![crate::data::vocab::PAD; bsz * t];
            for (bi, (_, _, row)) in chunk.iter().enumerate() {
                for (p, &tok) in row.seq.iter().enumerate().take(t) {
                    ids[bi * t + p] = tok;
                }
            }
            let t0 = Instant::now();
            let logits = ctx.run_logits(&model, &ids)?;
            metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            let v = logits.shape()[2];
            let ld = logits.data();
            for (bi, (pi, ri, row)) in chunk.iter().enumerate() {
                let mut lp = 0f64;
                for pos in row.start..row.end.min(t) {
                    let lrow = &ld[(bi * t + pos - 1) * v..(bi * t + pos) * v];
                    lp += log_softmax_at(lrow, row.seq[pos] as usize);
                }
                lp /= (row.end - row.start).max(1) as f64;
                let p = &mut pendings[*pi];
                p.scores[*ri] = lp;
                p.remaining -= 1;
            }
        }
        // deliver finished requests
        for p in pendings.iter_mut() {
            if p.remaining == 0 {
                let scores = std::mem::take(&mut p.scores);
                metrics
                    .queue_ns
                    .fetch_add(p.req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = p.req.reply.send(scores);
            }
        }
        pendings.retain(|p| p.remaining > 0);
        Ok(())
    };

    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // wait for work (or shutdown)
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(req) => Some(req),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let deadline = Instant::now() + batcher.max_wait;
        let enqueue = |req: ScoreRequest,
                           pendings: &mut Vec<Pending>,
                           queue: &mut Vec<(usize, usize, RowSpec)>| {
            metrics.requests.fetch_add(1, Ordering::Relaxed);
            metrics.rows.fetch_add(req.rows.len() as u64, Ordering::Relaxed);
            let pi = pendings.len();
            let rows = req.rows.clone();
            pendings.push(Pending {
                scores: vec![0.0; rows.len()],
                remaining: rows.len(),
                req,
            });
            for (ri, row) in rows.into_iter().enumerate() {
                queue.push((pi, ri, row));
            }
        };
        if let Some(req) = first {
            enqueue(req, &mut pendings, &mut queue);
        }
        // keep filling until the batch is full or the deadline passes
        while queue.len() < batcher.max_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => enqueue(req, &mut pendings, &mut queue),
                Err(_) => break,
            }
        }
        if !queue.is_empty() {
            flush(&mut pendings, &mut queue)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_snapshot_math() {
        let m = Metrics::default();
        m.rows.store(64, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.busy_ns.store(2_000_000_000, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.rows_per_sec(), 32.0);
        assert_eq!(s.mean_batch_fill(32), 1.0);
    }

    #[test]
    fn rowspec_construction() {
        let prompt = [1, 2, 3];
        let choices = vec![vec![7], vec![8, 9]];
        let rows: Vec<RowSpec> = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        assert_eq!(rows[0].end, 4);
        assert_eq!(rows[1].end, 5);
        assert_eq!(rows[1].start, 3);
    }
}
