//! Serving layer: a threaded scoring **and generation** server.
//!
//! The paper's deployment motivation (Section 1) is memory-constrained
//! *serving* of SMoE models; this module demonstrates the merged models on
//! a live request path with two coexisting workloads:
//!
//! * **Score requests** (multiple-choice scoring) ride a *dynamic batcher*:
//!   rows are packed up to the model's batch size or a deadline, whichever
//!   comes first (vLLM-router-style size/deadline policy).
//! * **Generate requests** ride a *continuous batcher* (vLLM-style): each
//!   accepted request is prefilled into its own KV cache and joins the
//!   running decode set; every executor iteration advances **all** active
//!   sequences by one token in a **single batched call**
//!   ([`crate::backend::Backend::run_decode_batch`] — shared projection
//!   GEMMs, per-expert grouped SwiGLU), and sequences leave the set the
//!   moment they hit a stop condition — no sequence waits for a "batch"
//!   to finish. Score batches interleave between decode steps.
//!   Admission is governed by the [`scheduler`]: two priority classes
//!   ([`Priority::Interactive`] before [`Priority::Batch`], FIFO within
//!   each), prefills split into `HCSMOE_PREFILL_CHUNK`-token **chunks**
//!   with decode steps interleaved (Sarathi-style, so a long prompt
//!   cannot stall in-flight decodes for more than one chunk), and
//!   KV-pool-aware **preemption**: an Interactive arrival that cannot
//!   reserve its worst-case blocks swaps out Batch work (drop the cache,
//!   retain the token prefix, re-prefill on resume — bit-identical
//!   streams either way). Requests may additionally opt into
//!   **speculative decoding** ([`GenerateRequest::drafter`]) when the
//!   server holds a compact drafter variant ([`ServeSpec::drafter`]):
//!   such sequences keep a paired full/drafter cache in the pool (2×
//!   the block reservation), draft on the compact model and verify on
//!   the full one in the same continuous batch as plain sequences — one
//!   multi-position verify forward serves both kinds, and every output
//!   stream stays bit-identical to plain decoding.
//!
//! Each executor is one thread owning all of its execution state
//! (required for the PJRT backend, whose xla handles are not `Send`; the
//! native backend simply inherits the same design) — everything else is
//! channels. Single-executor is a *policy*, not the architecture:
//! [`dispatch::Dispatcher`] scales out to `HCSMOE_REPLICAS` executors
//! (each with its own `ModelContext`, variant pins and KV pool) placed by
//! KV occupancy and shared-prefix affinity, [`net`] puts an HTTP/1.1
//! streaming front end with backpressure and graceful drain over it, and
//! `HCSMOE_EXPERT_SHARDS` partitions each MoE layer's experts across
//! worker threads inside the native backend — all three bit-identical to
//! the serial single-executor path. Used by `examples/serve_merged.rs`,
//! `examples/generate_merged.rs` and the Table 20 throughput/latency
//! measurements. Runs offline end to end on the native backend. The full
//! architecture (request lifecycle, batching policies, KV-cache memory
//! accounting, execution topology, metrics definitions) is documented in
//! `SERVING.md`.

pub mod dispatch;
pub mod net;
pub mod scheduler;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{CacheSnapshot, KvCache, RoutingSnapshot};
use crate::config::Artifacts;
use crate::eval::log_softmax_at;
use crate::generate::{Generated, SamplingParams, Session};
use crate::kvpool::{PoolHandle, KV_BUDGET_ENV};
use crate::model::ModelContext;
use crate::pipeline::{CompressedModel, Method};
use crate::variant::{self, SwapOutcome, Variant, VariantRegistry};

pub use dispatch::Dispatcher;
pub use scheduler::{LatencyHisto, Priority};
use scheduler::{ActiveGen, DraftSeq, PrefillInFlight, Queued, SchedQueues};

/// Shared state of a [`reply_channel`] pair.
struct ReplyShared<T> {
    state: Mutex<ReplyState<T>>,
    cv: Condvar,
}

struct ReplyState<T> {
    queue: VecDeque<T>,
    senders: usize,
    rx_alive: bool,
}

/// Sending half of a [`reply_channel`]: like an `mpsc::Sender`, plus
/// [`ReplyTx::is_closed`] — the executor probes it at step boundaries to
/// evict generations whose client vanished, instead of decoding to
/// `max_tokens` for nobody and holding the sequence's KV blocks the whole
/// time (`std::sync::mpsc` cannot express this: a disconnected receiver is
/// only observable by consuming a send).
pub struct ReplyTx<T>(Arc<ReplyShared<T>>);

/// Receiving half of a [`reply_channel`]. Dropping it marks the channel
/// closed, which the executor observes via [`ReplyTx::is_closed`].
pub struct ReplyRx<T>(Arc<ReplyShared<T>>);

/// A multi-producer reply channel with disconnect detection. Several
/// requests may share one channel (replies arrive in the executor's
/// completion order — the admission-ordering tests rely on this).
pub fn reply_channel<T>() -> (ReplyTx<T>, ReplyRx<T>) {
    let shared = Arc::new(ReplyShared {
        state: Mutex::new(ReplyState { queue: VecDeque::new(), senders: 1, rx_alive: true }),
        cv: Condvar::new(),
    });
    (ReplyTx(Arc::clone(&shared)), ReplyRx(shared))
}

impl<T> ReplyTx<T> {
    /// Deliver one value; returns it back when the receiver is gone.
    pub fn send(&self, value: T) -> std::result::Result<(), T> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        if !st.rx_alive {
            return Err(value);
        }
        st.queue.push_back(value);
        drop(st);
        self.0.cv.notify_one();
        Ok(())
    }

    /// True once the receiving half was dropped — no send can ever be
    /// observed again, so work producing one is wasted.
    pub fn is_closed(&self) -> bool {
        !self.0.state.lock().expect("reply channel poisoned").rx_alive
    }
}

impl<T> Clone for ReplyTx<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().expect("reply channel poisoned").senders += 1;
        ReplyTx(Arc::clone(&self.0))
    }
}

impl<T> Drop for ReplyTx<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        st.senders -= 1;
        let last = st.senders == 0;
        drop(st);
        if last {
            // unblock a receiver waiting on a channel that can no longer
            // produce values
            self.0.cv.notify_all();
        }
    }
}

impl<T> ReplyRx<T> {
    /// Block until a value arrives; errors once every sender is gone and
    /// the queue is drained (mirrors `mpsc::Receiver::recv`).
    pub fn recv(&self) -> Result<T> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(anyhow!("reply channel disconnected"));
            }
            st = self.0.cv.wait(st).expect("reply channel poisoned");
        }
    }

    /// Non-blocking receive: `Ok(None)` when the queue is empty but
    /// senders remain.
    pub fn try_recv(&self) -> Result<Option<T>> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        if let Some(v) = st.queue.pop_front() {
            return Ok(Some(v));
        }
        if st.senders == 0 {
            return Err(anyhow!("reply channel disconnected"));
        }
        Ok(None)
    }

    /// Bounded-wait receive: `Ok(None)` after `timeout` with no value,
    /// `Err` once every sender is gone and the queue is drained. The HTTP
    /// streaming loop polls tokens through this so a connection can keep
    /// honouring its write deadline while a long decode step runs.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<T>> {
        let deadline = Instant::now() + timeout;
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Ok(Some(v));
            }
            if st.senders == 0 {
                return Err(anyhow!("reply channel disconnected"));
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Ok(None);
            }
            let (guard, _) =
                self.0.cv.wait_timeout(st, left).expect("reply channel poisoned");
            st = guard;
        }
    }
}

impl<T> Drop for ReplyRx<T> {
    fn drop(&mut self) {
        self.0.state.lock().expect("reply channel poisoned").rx_alive = false;
    }
}

/// How long the executor sleeps on an empty queue before re-checking the
/// stop flag.
const POLL: Duration = Duration::from_millis(50);

/// One scoring request: score `rows` (token sequences) and return the
/// length-normalised logprob of positions [start, end) per row.
pub struct ScoreRequest {
    /// Rows to score.
    pub rows: Vec<RowSpec>,
    /// Channel receiving the per-row normalised logprobs.
    pub reply: Sender<Vec<f64>>,
    /// Submission time (drives queue-latency metrics).
    pub enqueued: Instant,
}

/// One text-generation request, served by the continuous batcher under
/// the [`scheduler`]'s priority policy.
///
/// Built with [`GenerateRequest::new`] plus the chainable
/// [`priority`](Self::priority) / [`deadline`](Self::deadline) /
/// [`reply_to`](Self::reply_to) setters, then submitted via
/// [`ServerHandle::submit`] (or, for the common blocking cases,
/// [`ServerHandle::generate`] / [`ServerHandle::generate_opts`], which
/// build it for you). A plain `new(..)` request is
/// [`Priority::Interactive`] with no deadline — exactly what `generate`
/// always submitted.
pub struct GenerateRequest {
    /// Prompt token ids (must be non-empty and fit in `t_max`).
    pub prompt: Vec<i32>,
    /// Sampling strategy + stop conditions.
    pub params: SamplingParams,
    /// Scheduling class (default [`Priority::Interactive`]).
    pub class: Priority,
    /// Optional completion SLO measured from submission. Purely
    /// *accounting*: a request finishing later bumps the
    /// `deadline_misses` counter; it is never reordered or cancelled for
    /// missing it (FIFO within class stays starvation-free).
    pub deadline: Option<Duration>,
    /// Speculative decoding opt-in: propose up to this many tokens per
    /// verify round on the server's compact drafter variant
    /// ([`ServeSpec::drafter`]). `None` = plain decoding. The output
    /// stream is bit-identical either way — the drafter only changes how
    /// many full-model forwards it takes.
    pub draft_k: Option<usize>,
    /// Channel receiving the finished generation (or the error). A
    /// [`ReplyTx`] rather than a plain `Sender` so the executor can detect
    /// a vanished client ([`ReplyTx::is_closed`]) and evict the sequence —
    /// releasing its KV blocks — instead of decoding to `max_tokens` into
    /// the void.
    reply: ReplyTx<Result<Generated>>,
    /// The receiving half paired with `reply`; taken by
    /// [`ServerHandle::submit`]. `None` after [`Self::reply_to`] routed
    /// replies to a caller-owned channel.
    rx: Option<ReplyRx<Result<Generated>>>,
    /// Live token stream (`None` = reply-only): the executor pushes every
    /// committed token here the moment its decode step lands, in emission
    /// order; the final [`Generated`] reply still arrives on `reply`.
    /// The stream closing (all senders dropped) marks end-of-stream.
    pub(crate) stream: Option<ReplyTx<i32>>,
    /// Dispatcher occupancy lease (see [`dispatch::Lease`]); `None` for
    /// direct [`ServerHandle`] submissions. Travels with the request
    /// through every scheduler state so each terminal path releases it.
    pub(crate) lease: Option<dispatch::Lease>,
    /// Submission time (drives queue-latency metrics).
    enqueued: Instant,
}

impl GenerateRequest {
    /// A request with today's defaults: [`Priority::Interactive`], no
    /// deadline, and a fresh private reply channel.
    pub fn new(prompt: &[i32], params: SamplingParams) -> Self {
        let (reply, rx) = reply_channel();
        Self {
            prompt: prompt.to_vec(),
            params,
            class: Priority::default(),
            deadline: None,
            draft_k: None,
            reply,
            rx: Some(rx),
            stream: None,
            lease: None,
            enqueued: Instant::now(),
        }
    }

    /// Set the scheduling class.
    pub fn priority(mut self, class: Priority) -> Self {
        self.class = class;
        self
    }

    /// Set the completion deadline (measured from submission).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Opt into speculative decoding on the server's compact drafter
    /// variant, proposing up to `draft_k` tokens per verify round. The
    /// token stream stays bit-identical to a plain request (same
    /// [`Session`], same RNG draws — see [`crate::generate::speculative`]);
    /// only the number of full-model forwards changes. Requires the
    /// server to be configured with [`ServeSpec::drafter`] and
    /// `draft_k >= 1` — both are checked at intake and violations are
    /// answered with an error instead of entering a scheduler lane.
    /// Memory note: a speculative sequence reserves KV blocks for BOTH
    /// caches of its full/drafter pair (2× the plain reservation).
    pub fn drafter(mut self, draft_k: usize) -> Self {
        self.draft_k = Some(draft_k);
        self
    }

    /// Route the reply to a caller-owned channel instead of the private
    /// one — several requests sharing a channel observe the executor's
    /// completion order (the scheduler-ordering tests rely on this).
    /// [`ServerHandle::submit`] then returns `None` for the receiver.
    pub fn reply_to(mut self, tx: ReplyTx<Result<Generated>>) -> Self {
        self.reply = tx;
        self.rx = None;
        self
    }

    /// Opt into live token streaming: returns the request plus a receiver
    /// that yields every committed token in emission order as decode
    /// steps land (first token included). The channel closes (`recv`
    /// errors) after the last token; the final [`Generated`] still
    /// arrives on the reply channel, so the stream is purely additive —
    /// a streamed request's token sequence is bit-identical to the
    /// non-streamed reply (`rust/tests/dispatch.rs` pins it).
    pub fn streaming(mut self) -> (Self, ReplyRx<i32>) {
        let (tx, rx) = reply_channel();
        self.stream = Some(tx);
        (self, rx)
    }
}

/// Anything a client can submit to the executor.
pub enum Request {
    /// Multiple-choice scoring (dynamic batcher).
    Score(ScoreRequest),
    /// Autoregressive generation (continuous batcher).
    Generate(GenerateRequest),
}

impl From<ScoreRequest> for Request {
    fn from(r: ScoreRequest) -> Self {
        Request::Score(r)
    }
}

impl From<GenerateRequest> for Request {
    fn from(r: GenerateRequest) -> Self {
        Request::Generate(r)
    }
}

/// One scored row: a token sequence plus the `[start, end)` span whose
/// logprob is accumulated.
#[derive(Debug, Clone)]
pub struct RowSpec {
    /// Token sequence (padded by the batcher).
    pub seq: Vec<i32>,
    /// First predicted position (prompt length).
    pub start: usize,
    /// One past the last predicted position.
    pub end: usize,
}

/// Live serving counters (shared with clients via `Arc`).
///
/// Scoring traffic is tracked by `requests`/`rows`/`batches`/`busy_ns`;
/// generation traffic by `gen_requests`/`prefill_tokens`/`gen_tokens` with
/// its time split into `prefill_ns` and `decode_ns` (so per-token decode
/// latency is measurable independently of prompt length). `queue_ns`
/// covers both workloads (submit → reply).
///
/// # Examples
///
/// ```
/// use std::sync::atomic::Ordering;
/// use hc_smoe::serving::Metrics;
///
/// let m = Metrics::default();
/// m.gen_tokens.store(500, Ordering::Relaxed);
/// m.decode_ns.store(2_000_000_000, Ordering::Relaxed); // 2 s
/// m.prefill_tokens.store(64, Ordering::Relaxed);
/// m.prefill_ns.store(8_000_000, Ordering::Relaxed); // 8 ms
///
/// let s = m.snapshot();
/// assert_eq!(s.decode_tok_s(), 250.0);
/// assert_eq!(s.ms_per_token(), 4.0);
/// assert_eq!(s.prefill_tok_s(), 8000.0);
/// ```
#[derive(Default)]
pub struct Metrics {
    /// Score requests accepted.
    pub requests: AtomicU64,
    /// Score rows accepted.
    pub rows: AtomicU64,
    /// Score batches executed.
    pub batches: AtomicU64,
    /// Nanoseconds spent executing score batches.
    pub busy_ns: AtomicU64,
    /// Nanoseconds requests spent queued (enqueue -> reply), both kinds.
    pub queue_ns: AtomicU64,
    /// Generate requests accepted.
    pub gen_requests: AtomicU64,
    /// Prompt tokens prefilled for generate requests.
    pub prefill_tokens: AtomicU64,
    /// Tokens emitted by decode steps (incl. EOS when sampled). Each
    /// sequence's *first* token is sampled from the prefill logits — its
    /// compute sits in `prefill_ns`, so it is deliberately not counted
    /// here; `decode_ns / gen_tokens` is then an honest per-decode-step
    /// latency.
    pub gen_tokens: AtomicU64,
    /// Nanoseconds spent in prompt prefills.
    pub prefill_ns: AtomicU64,
    /// Nanoseconds spent in decode steps.
    pub decode_ns: AtomicU64,
    /// Batched decode iterations executed (each advances every active
    /// sequence by one token). `gen_tokens / decode_steps` is therefore
    /// the mean decode-batch occupancy — how much concurrency the batched
    /// step actually captured.
    pub decode_steps: AtomicU64,
    /// Generations evicted because the client dropped its reply channel
    /// (queued or mid-decode); their KV blocks return to the pool.
    pub gen_disconnects: AtomicU64,
    /// Gauge: paged KV blocks currently referenced by live sequences.
    pub kv_blocks_in_use: AtomicU64,
    /// Gauge: paged KV blocks referenced by more than one sequence
    /// (prefix sharing in effect).
    pub kv_blocks_shared: AtomicU64,
    /// Gauge: high-water mark of `kv_blocks_in_use` over the pool's life.
    pub kv_blocks_peak: AtomicU64,
    /// Gauge: total block capacity of this executor's KV pool (set once
    /// at startup). The dispatcher reads it to bound its committed-block
    /// placement estimates; `kv_blocks_in_use / kv_blocks_total` is the
    /// replica's occupancy.
    pub kv_blocks_total: AtomicU64,
    /// Batch-class work swapped out (cache dropped, prefix retained) so
    /// an Interactive arrival could reserve its KV blocks.
    pub preemptions: AtomicU64,
    /// Prefills that took more than one chunk (i.e. were actually split
    /// by `HCSMOE_PREFILL_CHUNK` and interleaved with decode steps).
    pub chunked_prefills: AtomicU64,
    /// Generations that finished after their requested deadline.
    pub deadline_misses: AtomicU64,
    /// Gauge: the most prompt tokens ever prefilled between two
    /// consecutive decode steps while at least one sequence was actively
    /// decoding — the *observed* stall bound. Unchunked, this reaches the
    /// longest admitted prompt; chunked it stays ≤ the chunk size (the
    /// deterministic stall-bound pin in `rust/tests/scheduler.rs`).
    pub prefill_stall_tokens_max: AtomicU64,
    /// Draft tokens proposed by speculative sequences (excludes the
    /// committed token heading each verify run).
    pub spec_drafted: AtomicU64,
    /// Draft tokens the verifier's own sampling accepted.
    /// `spec_accepted / spec_drafted` is the fleet acceptance rate —
    /// the live readout of how close the merged drafter tracks the full
    /// model (the paper's functional-similarity claim, measured in
    /// decode forwards saved).
    pub spec_accepted: AtomicU64,
    /// Decode iterations that ran the multi-position verify path (at
    /// least one speculative sequence in the batch).
    pub spec_rounds: AtomicU64,
    /// Inter-token latency histogram over Interactive-class decode steps
    /// (time between consecutive token emissions of one sequence).
    pub itl: LatencyHisto,
    /// Variant hot-swaps performed by the adaptive recompression loop
    /// (deduplicated candidates — identical fingerprints — don't count).
    pub swaps: AtomicU64,
    /// Gauge: weight-content fingerprint of the currently active variant.
    /// New sequences admitted after a swap provably run this fingerprint
    /// (`rust/tests/adapt.rs` pins it against an offline rebuild).
    pub active_variant: AtomicU64,
    /// Nanoseconds spent in background recompressions (wall-clock from
    /// spawn to the executor landing the result; the executor keeps
    /// serving throughout — this is NOT executor stall time).
    pub recompress_ns: AtomicU64,
    /// Gauge: Shannon entropy (bits × 1000) of the current routing
    /// window's layer-0 dispatch distribution. Falling entropy means
    /// traffic is concentrating on few experts — exactly the condition
    /// adaptive recompression exploits.
    pub dispatch_entropy_milli: AtomicU64,
}

impl Metrics {
    /// Consistent-enough copy of the counters for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            busy_s: self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            queue_s: self.queue_ns.load(Ordering::Relaxed) as f64 / 1e9,
            gen_requests: self.gen_requests.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            gen_tokens: self.gen_tokens.load(Ordering::Relaxed),
            prefill_s: self.prefill_ns.load(Ordering::Relaxed) as f64 / 1e9,
            decode_s: self.decode_ns.load(Ordering::Relaxed) as f64 / 1e9,
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            gen_disconnects: self.gen_disconnects.load(Ordering::Relaxed),
            kv_blocks_in_use: self.kv_blocks_in_use.load(Ordering::Relaxed),
            kv_blocks_shared: self.kv_blocks_shared.load(Ordering::Relaxed),
            kv_blocks_peak: self.kv_blocks_peak.load(Ordering::Relaxed),
            kv_blocks_total: self.kv_blocks_total.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            chunked_prefills: self.chunked_prefills.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            prefill_stall_tokens_max: self.prefill_stall_tokens_max.load(Ordering::Relaxed),
            spec_drafted: self.spec_drafted.load(Ordering::Relaxed),
            spec_accepted: self.spec_accepted.load(Ordering::Relaxed),
            spec_rounds: self.spec_rounds.load(Ordering::Relaxed),
            itl_p50_ms: self.itl.quantile_ms(0.50),
            itl_p99_ms: self.itl.quantile_ms(0.99),
            swaps: self.swaps.load(Ordering::Relaxed),
            active_variant: self.active_variant.load(Ordering::Relaxed),
            recompress_s: self.recompress_ns.load(Ordering::Relaxed) as f64 / 1e9,
            dispatch_entropy: self.dispatch_entropy_milli.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }

    /// Fleet-wide aggregate over per-replica metrics, so multi-executor
    /// numbers never silently report only replica 0: **sums** for
    /// counters and capacity gauges (requests, tokens, seconds, KV
    /// blocks in use / shared / total), **max** for high-water marks
    /// (`kv_blocks_peak`, `prefill_stall_tokens_max`), and a **bucket
    /// union** for the inter-token latency histogram
    /// ([`LatencyHisto::quantile_ms_across`] — averaging per-replica
    /// quantiles would be meaningless). The remaining point-in-time
    /// gauges (`active_variant`, `dispatch_entropy`) are replica 0's:
    /// replicas adapt independently, and "the fleet's variant" is only
    /// well-defined when they agree — per-replica snapshots remain the
    /// authoritative view for those.
    pub fn merged(replicas: &[&Metrics]) -> MetricsSnapshot {
        let sum = |f: fn(&Metrics) -> &AtomicU64| -> u64 {
            replicas.iter().map(|m| f(m).load(Ordering::Relaxed)).sum()
        };
        let max = |f: fn(&Metrics) -> &AtomicU64| -> u64 {
            replicas.iter().map(|m| f(m).load(Ordering::Relaxed)).max().unwrap_or(0)
        };
        let histos: Vec<&LatencyHisto> = replicas.iter().map(|m| &m.itl).collect();
        MetricsSnapshot {
            requests: sum(|m| &m.requests),
            rows: sum(|m| &m.rows),
            batches: sum(|m| &m.batches),
            busy_s: sum(|m| &m.busy_ns) as f64 / 1e9,
            queue_s: sum(|m| &m.queue_ns) as f64 / 1e9,
            gen_requests: sum(|m| &m.gen_requests),
            prefill_tokens: sum(|m| &m.prefill_tokens),
            gen_tokens: sum(|m| &m.gen_tokens),
            prefill_s: sum(|m| &m.prefill_ns) as f64 / 1e9,
            decode_s: sum(|m| &m.decode_ns) as f64 / 1e9,
            decode_steps: sum(|m| &m.decode_steps),
            gen_disconnects: sum(|m| &m.gen_disconnects),
            kv_blocks_in_use: sum(|m| &m.kv_blocks_in_use),
            kv_blocks_shared: sum(|m| &m.kv_blocks_shared),
            kv_blocks_peak: max(|m| &m.kv_blocks_peak),
            kv_blocks_total: sum(|m| &m.kv_blocks_total),
            preemptions: sum(|m| &m.preemptions),
            chunked_prefills: sum(|m| &m.chunked_prefills),
            deadline_misses: sum(|m| &m.deadline_misses),
            prefill_stall_tokens_max: max(|m| &m.prefill_stall_tokens_max),
            spec_drafted: sum(|m| &m.spec_drafted),
            spec_accepted: sum(|m| &m.spec_accepted),
            spec_rounds: sum(|m| &m.spec_rounds),
            itl_p50_ms: LatencyHisto::quantile_ms_across(&histos, 0.50),
            itl_p99_ms: LatencyHisto::quantile_ms_across(&histos, 0.99),
            swaps: sum(|m| &m.swaps),
            active_variant: replicas
                .first()
                .map_or(0, |m| m.active_variant.load(Ordering::Relaxed)),
            recompress_s: sum(|m| &m.recompress_ns) as f64 / 1e9,
            dispatch_entropy: replicas
                .first()
                .map_or(0.0, |m| m.dispatch_entropy_milli.load(Ordering::Relaxed) as f64 / 1e3),
        }
    }
}

/// Point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    /// Score requests accepted.
    pub requests: u64,
    /// Score rows accepted.
    pub rows: u64,
    /// Score batches executed.
    pub batches: u64,
    /// Seconds spent executing score batches.
    pub busy_s: f64,
    /// Seconds requests spent queued (both kinds).
    pub queue_s: f64,
    /// Generate requests accepted.
    pub gen_requests: u64,
    /// Prompt tokens prefilled.
    pub prefill_tokens: u64,
    /// Tokens emitted by decode steps (first-token samples excluded).
    pub gen_tokens: u64,
    /// Seconds spent in prompt prefills.
    pub prefill_s: f64,
    /// Seconds spent in decode steps.
    pub decode_s: f64,
    /// Batched decode iterations executed.
    pub decode_steps: u64,
    /// Generations evicted on client disconnect.
    pub gen_disconnects: u64,
    /// Gauge: paged KV blocks referenced by live sequences.
    pub kv_blocks_in_use: u64,
    /// Gauge: paged KV blocks shared by more than one sequence.
    pub kv_blocks_shared: u64,
    /// Gauge: high-water mark of `kv_blocks_in_use`.
    pub kv_blocks_peak: u64,
    /// Gauge: total block capacity of the executor's KV pool (sum of
    /// all replica pools in a [`Metrics::merged`] snapshot).
    pub kv_blocks_total: u64,
    /// Batch-class preemptions (swap-outs) performed.
    pub preemptions: u64,
    /// Prefills split across more than one chunk.
    pub chunked_prefills: u64,
    /// Generations finished after their deadline.
    pub deadline_misses: u64,
    /// Gauge: most prompt tokens prefilled between two consecutive decode
    /// steps while sequences were actively decoding.
    pub prefill_stall_tokens_max: u64,
    /// Draft tokens proposed by speculative sequences.
    pub spec_drafted: u64,
    /// Draft tokens the verifier accepted.
    pub spec_accepted: u64,
    /// Decode iterations that ran the multi-position verify path.
    pub spec_rounds: u64,
    /// Median Interactive inter-token latency (ms, bucket upper bound).
    pub itl_p50_ms: f64,
    /// 99th-percentile Interactive inter-token latency (ms).
    pub itl_p99_ms: f64,
    /// Variant hot-swaps performed.
    pub swaps: u64,
    /// Gauge: fingerprint of the currently active variant.
    pub active_variant: u64,
    /// Seconds spent in background recompressions (wall-clock, off the
    /// executor thread).
    pub recompress_s: f64,
    /// Gauge: dispatch entropy (bits) of the current routing window.
    pub dispatch_entropy: f64,
}

impl MetricsSnapshot {
    /// Rows scored per busy second.
    pub fn rows_per_sec(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.rows as f64 / self.busy_s
        } else {
            0.0
        }
    }

    /// Mean batch occupancy in [0, 1] at the given batch size.
    pub fn mean_batch_fill(&self, batch_size: usize) -> f64 {
        if self.batches > 0 {
            self.rows as f64 / (self.batches as f64 * batch_size as f64)
        } else {
            0.0
        }
    }

    /// Decode throughput in generated tokens per second.
    pub fn decode_tok_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.gen_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }

    /// Prefill throughput in prompt tokens per second.
    pub fn prefill_tok_s(&self) -> f64 {
        if self.prefill_s > 0.0 {
            self.prefill_tokens as f64 / self.prefill_s
        } else {
            0.0
        }
    }

    /// Mean per-token decode latency in milliseconds.
    pub fn ms_per_token(&self) -> f64 {
        if self.gen_tokens > 0 {
            self.decode_s * 1e3 / self.gen_tokens as f64
        } else {
            0.0
        }
    }

    /// Mean decode-batch occupancy: tokens advanced per batched decode
    /// iteration (1.0 = the batcher never saw concurrent sequences).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps > 0 {
            self.gen_tokens as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }

    /// Fraction of proposed speculative draft tokens the verifier
    /// accepted (0 when none were proposed) — the serving-side readout of
    /// how functionally close the merged drafter is to the full model.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted > 0 {
            self.spec_accepted as f64 / self.spec_drafted as f64
        } else {
            0.0
        }
    }
}

/// Dynamic-batcher flush policy for score rows (size or deadline,
/// whichever first). Generation is not subject to it: decode requests
/// join the continuous batch as soon as the executor sees them.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many rows are queued (= executable batch size).
    pub max_rows: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

/// What the executor thread should serve.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Artifact directory the executor loads from.
    pub artifacts_root: String,
    /// Model family name to serve.
    pub model: String,
    /// None = serve the original model; Some = compress first.
    pub compress: Option<(Method, usize, String)>, // (method, r, calib domain)
    /// Paged KV-cache pool budget in bytes. `None` resolves
    /// `HCSMOE_KV_BUDGET_MB`, then the 64 MiB default — see `SERVING.md`
    /// §"KV memory model". Generation prompts are only admitted while the
    /// pool can reserve their worst-case block count; the rest wait in the
    /// admission queue.
    pub kv_budget_bytes: Option<usize>,
    /// Most prompt tokens prefilled between consecutive decode steps
    /// (chunked prefill — see `SERVING.md` §"Scheduler"). `None` resolves
    /// `HCSMOE_PREFILL_CHUNK`, else whole-prompt prefills; `Some(0)` is a
    /// startup error (all knobs validate via [`crate::config::env`]).
    pub prefill_chunk: Option<usize>,
    /// Optional speculative drafter: compress the served model with this
    /// (method, r, calib domain) into a true r-expert **compact** variant
    /// held resident next to the full model. Requests opt in per-request
    /// via [`GenerateRequest::drafter`]; with `None` here, such requests
    /// are answered with an error at intake. The drafter shares the KV
    /// pool with the full model (cache pairs never alias blocks — the
    /// pool's sharing map is keyed by variant fingerprint).
    pub drafter: Option<(Method, usize, String)>,
    /// Adaptive recompression policy: `Some` makes the executor watch
    /// live routing statistics and hot-swap in freshly recompressed
    /// variants (see `SERVING.md` §"Adaptive compression & hot swap").
    /// Requires a backend that reports routing stats (native); a `Some`
    /// here on a backend that doesn't is a startup error.
    pub adapt: Option<AdaptSpec>,
}

impl ServeSpec {
    /// A spec serving the original model from `root` with every optional
    /// knob off — the single test-suite constructor, so adding a field to
    /// `ServeSpec` no longer breaks a dozen hand-written literals across
    /// `rust/tests/`.
    pub fn for_tests(root: &str, model: &str) -> Self {
        Self {
            artifacts_root: root.to_string(),
            model: model.to_string(),
            compress: None,
            kv_budget_bytes: None,
            prefill_chunk: None,
            drafter: None,
            adapt: None,
        }
    }
}

/// Adaptive recompression policy ([`ServeSpec::adapt`]): how and when the
/// serving executor rebuilds the served variant from live routing
/// statistics.
#[derive(Debug, Clone)]
pub struct AdaptSpec {
    /// Compression method recompressed variants are built with.
    pub method: Method,
    /// Expert budget (experts kept per layer) of recompressed variants.
    pub r: usize,
    /// Calibration domain seeding the similarity statistics; only the
    /// per-expert frequency weighting is replaced by the live routing
    /// window ([`crate::calib::CalibStats::reweighted`]).
    pub domain: String,
    /// Quantize recompressed variants to int8 experts before swapping.
    pub quantize: bool,
    /// Routed tokens per recompression window. `None` resolves
    /// `HCSMOE_ADAPT_WINDOW` (default 4096); `Some(0)` is a startup
    /// error (all knobs validate via [`crate::config::env`]).
    pub window_tokens: Option<u64>,
    /// Routed tokens the active variant must have served before the
    /// FIRST recompression fires (warm-up guard against adapting to a
    /// cold, unrepresentative window). `None` resolves
    /// `HCSMOE_ADAPT_MIN_TOKENS` (default 0 = no warm-up).
    pub min_tokens: Option<u64>,
}

/// Client-side handle to a running server.
pub struct ServerHandle {
    tx: Sender<Request>,
    /// Live serving counters.
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    /// Submit one multiple-choice item; returns per-choice normalised
    /// logprobs (blocking).
    pub fn score_item(&self, prompt: &[i32], choices: &[Vec<i32>]) -> Result<Vec<f64>> {
        let rows = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        let (reply, rx) = channel();
        self.tx
            .send(Request::Score(ScoreRequest { rows, reply, enqueued: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx.recv()?)
    }

    /// Submit one generation request; blocks until the sequence finishes.
    /// With a seeded [`SamplingParams`], the result is bit-identical to an
    /// offline [`crate::generate::generate`] call on the same variant —
    /// the server runs the same [`Session`] loop. Submits as
    /// [`Priority::Interactive`] with no deadline (exactly this method's
    /// historical behaviour); use [`Self::generate_opts`] or
    /// [`Self::submit`] for scheduling control.
    pub fn generate(&self, prompt: &[i32], params: SamplingParams) -> Result<Generated> {
        self.generate_opts(prompt, params, Priority::Interactive, None)
    }

    /// [`Self::generate`] with explicit scheduling options: priority
    /// class and optional completion deadline (see
    /// [`GenerateRequest::deadline`] for the miss semantics).
    pub fn generate_opts(
        &self,
        prompt: &[i32],
        params: SamplingParams,
        class: Priority,
        deadline: Option<Duration>,
    ) -> Result<Generated> {
        let mut req = GenerateRequest::new(prompt, params).priority(class);
        if let Some(d) = deadline {
            req = req.deadline(d);
        }
        let rx = self.submit(req)?.expect("a fresh request owns its receiver");
        rx.recv()?
    }

    /// Submit a built [`GenerateRequest`] without blocking. Returns the
    /// receiving half of the request's private reply channel — or `None`
    /// when [`GenerateRequest::reply_to`] routed the reply to a
    /// caller-owned channel.
    pub fn submit(
        &self,
        mut req: GenerateRequest,
    ) -> Result<Option<ReplyRx<Result<Generated>>>> {
        let rx = req.rx.take();
        self.tx
            .send(Request::Generate(req))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// A clonable submission channel for client threads.
    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    /// Stop the server and join the executor thread. Robust against
    /// still-alive cloned senders: an explicit stop flag breaks the
    /// executor loop even if the channel never disconnects. Every
    /// generation still in flight or queued — active, mid-prefill,
    /// waiting for admission, or sitting unread in the request channel —
    /// receives an explicit "server shutting down" error reply, so no
    /// client blocks forever on a request the executor will never run;
    /// pending score requests observe their reply channel closing. When
    /// the channel merely disconnects instead (all senders dropped, no
    /// stop), the executor finishes all in-flight work before exiting.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

/// Start the executor thread. All PJRT state lives inside it.
pub fn serve(spec: ServeSpec, batcher: BatcherConfig) -> Result<ServerHandle> {
    let (tx, rx) = channel::<Request>();
    let metrics = Arc::new(Metrics::default());
    let m2 = Arc::clone(&metrics);
    let stop = Arc::new(AtomicBool::new(false));
    let s2 = Arc::clone(&stop);
    let join = std::thread::Builder::new()
        .name("hcsmoe-executor".into())
        .spawn(move || executor_loop(spec, batcher, rx, m2, s2))?;
    Ok(ServerHandle { tx, metrics, stop, join: Some(join) })
}

/// A queued-but-unanswered score request with its partial scores.
struct Pending {
    req: ScoreRequest,
    scores: Vec<f64>,
    remaining: usize,
}

/// The executor: one thread owning the variant registry and all
/// execution state.
struct Executor {
    ctx: ModelContext,
    /// The variant lifecycle owner: active variant, optional resident
    /// drafter, retired-variant ledger. `RefCell` because the executor's
    /// methods take `&self` but a hot-swap mutates the registry — all on
    /// the one executor thread.
    registry: RefCell<VariantRegistry>,
    bsz: usize,
    t: usize,
    batcher: BatcherConfig,
    metrics: Arc<Metrics>,
    /// The paged KV-cache pool every generation's cache lives in — the
    /// memory budget admission control enforces. Speculative sequences
    /// keep BOTH caches of their full/drafter pair here. One pool spans
    /// every variant: block sharing keys on the variant fingerprint
    /// (which folds in weight content), so prefixes never alias across a
    /// hot swap.
    pool: PoolHandle,
    /// Most prompt tokens prefilled between consecutive decode steps
    /// (`None` = whole-prompt prefills).
    chunk: Option<usize>,
    /// Live adaptive-recompression state ([`ServeSpec::adapt`]); `None`
    /// serves a single fixed variant forever.
    adapt: RefCell<Option<AdaptState>>,
}

/// Live state of the adaptive recompression loop.
struct AdaptState {
    spec: AdaptSpec,
    /// Resolved window size (routed tokens per recompression window).
    window: u64,
    /// Resolved warm-up bound (routed tokens before the FIRST
    /// recompression).
    min_tokens: u64,
    /// Whether any recompression has been spawned yet (`min_tokens` only
    /// guards the first one).
    fired: bool,
    /// Routing snapshot at the start of the current window; re-marked
    /// after every spawn and after every landed result.
    mark: RoutingSnapshot,
    /// The in-flight background recompression: its result channel and
    /// spawn time. At most one recompression runs at a time.
    inflight: Option<(Receiver<Result<CompressedModel>>, Instant)>,
    /// Context coordinates the worker thread reloads from.
    artifacts_root: String,
    model: String,
}

fn executor_loop(
    spec: ServeSpec,
    batcher: BatcherConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    // all env knobs resolve (and validate) through config::env, so a set
    // but malformed value is a startup error rather than a silent default
    // — including the adapt knobs even when ServeSpec::adapt is None
    let budget = crate::config::env::kv_budget_bytes(spec.kv_budget_bytes)?;
    let chunk = crate::config::env::prefill_chunk(spec.prefill_chunk)?;
    let window = crate::config::env::adapt_window(
        spec.adapt.as_ref().and_then(|a| a.window_tokens),
    )?;
    let min_tokens = crate::config::env::adapt_min_tokens(
        spec.adapt.as_ref().and_then(|a| a.min_tokens),
    )?;
    let arts = Artifacts::new(&spec.artifacts_root);
    let ctx = ModelContext::load(&arts, &spec.model)?;
    // startup variant builds moved behind the variant registry (the
    // drafter stays a TRUE r-expert compact export — drafting forwards
    // must be cheaper than verify forwards)
    let primary = variant::build_primary(&ctx, &spec.compress)?;
    metrics.active_variant.store(primary.fingerprint, Ordering::Relaxed);
    let drafter = variant::build_drafter(&ctx, &spec.drafter)?;
    let registry = RefCell::new(VariantRegistry::new(primary, drafter));
    let adapt = match spec.adapt {
        None => None,
        Some(a) => {
            let mark = ctx
                .routing_stats(&registry.borrow().active().model)
                .ok_or_else(|| {
                    anyhow!(
                        "adaptive serving needs a backend that reports routing \
                         stats (native); the {} backend does not",
                        ctx.backend_name()
                    )
                })?;
            Some(AdaptState {
                spec: a,
                window,
                min_tokens,
                fired: false,
                mark,
                inflight: None,
                artifacts_root: spec.artifacts_root.clone(),
                model: spec.model.clone(),
            })
        }
    };
    let (bsz, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let pool = ctx.kv_pool(budget)?;
    // published once so the dispatcher can bound committed-block
    // placement estimates against real capacity
    metrics
        .kv_blocks_total
        .store(pool.total_blocks() as u64, Ordering::Relaxed);
    let exec = Executor {
        ctx,
        registry,
        bsz,
        t,
        batcher,
        metrics,
        pool,
        chunk,
        adapt: RefCell::new(adapt),
    };
    exec.run(rx, stop)
}

impl Executor {
    /// The main loop: intake → (score flush when due) → scheduler tick
    /// (priority admission + at most ONE prefill **chunk**) → one
    /// **batched** decode step across every active sequence — so decode
    /// requests join and leave the running batch on step boundaries,
    /// score batches interleave, and a long prompt advances at most
    /// `HCSMOE_PREFILL_CHUNK` tokens between consecutive decode steps.
    ///
    /// Prefill work is deliberately bounded per iteration instead of
    /// running inside the intake drain: a prefill costs O(prompt²)
    /// attention while a decode step costs O(t) per sequence, so draining
    /// a burst of long prompts synchronously (the old design) froze every
    /// active sequence for the whole burst. With chunking, an in-flight
    /// sequence falls at most one chunk behind per iteration
    /// (`rust/tests/scheduler.rs` pins the stall bound via the
    /// `prefill_stall_tokens_max` gauge).
    ///
    /// Two prefill slots exist — one per [`Priority`] class. A Batch
    /// prefill **parks** (keeping its partial cache and block
    /// reservation) while an Interactive prefill runs, resuming when the
    /// Interactive slot empties; it is preempted outright — cache
    /// dropped, request re-queued — only when an Interactive arrival
    /// cannot reserve its blocks ([`Self::make_room`]).
    fn run(&self, rx: Receiver<Request>, stop: Arc<AtomicBool>) -> Result<()> {
        let mut pendings: Vec<Pending> = Vec::new();
        let mut queue: Vec<(usize, usize, RowSpec)> = Vec::new();
        let mut active: Vec<ActiveGen> = Vec::new();
        // per-class admission queues + the (at most two) prefills in
        // flight
        let mut queues = SchedQueues::default();
        let mut inflight_i: Option<PrefillInFlight> = None;
        let mut inflight_b: Option<PrefillInFlight> = None;
        // prompt tokens prefilled since the last decode step while
        // sequences were actively decoding (feeds the observed-stall
        // gauge)
        let mut stall_tokens: u64 = 0;
        // enqueue time of the oldest unflushed score request
        let mut oldest: Option<Instant> = None;
        let mut disconnected = false;
        loop {
            if stop.load(Ordering::SeqCst) {
                self.drain_on_stop(
                    &rx,
                    &mut queues,
                    &mut inflight_i,
                    &mut inflight_b,
                    &mut active,
                );
                break;
            }
            if !disconnected {
                // Block only when there is nothing to advance or admit;
                // while sequences decode or prefills wait, drain without
                // waiting.
                let busy = !active.is_empty()
                    || !queues.is_empty()
                    || inflight_i.is_some()
                    || inflight_b.is_some();
                let wait = if busy {
                    Duration::ZERO
                } else if let Some(o) = oldest {
                    self.batcher.max_wait.saturating_sub(o.elapsed()).min(POLL)
                } else {
                    POLL
                };
                match rx.recv_timeout(wait) {
                    Ok(req) => {
                        self.intake(req, &mut pendings, &mut queue, &mut oldest, &mut queues);
                        while let Ok(req) = rx.try_recv() {
                            self.intake(req, &mut pendings, &mut queue, &mut oldest, &mut queues);
                        }
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
            if disconnected
                && active.is_empty()
                && queue.is_empty()
                && queues.is_empty()
                && inflight_i.is_none()
                && inflight_b.is_none()
            {
                break;
            }
            let flush_due = !queue.is_empty()
                && (queue.len() >= self.batcher.max_rows
                    || oldest.is_some_and(|o| o.elapsed() >= self.batcher.max_wait)
                    || disconnected);
            if flush_due {
                self.flush(&mut pendings, &mut queue)?;
                oldest = None;
            }
            // client-disconnect eviction at step boundaries: a sequence
            // (or queued request, or half-built prefill) whose reply
            // channel closed would run to max_tokens for nobody while
            // pinning its KV blocks — dropping it here releases the
            // blocks back to the pool
            let m = &self.metrics;
            queues.retain_connected(m);
            for slot in [&mut inflight_i, &mut inflight_b] {
                if slot.as_ref().is_some_and(|f| f.reply().is_closed()) {
                    m.gen_disconnects.fetch_add(1, Ordering::Relaxed);
                    *slot = None; // the partial cache (and its blocks) drop
                }
            }
            active.retain(|a| {
                let gone = a.reply.is_closed();
                if gone {
                    m.gen_disconnects.fetch_add(1, Ordering::Relaxed);
                }
                !gone
            });
            // adaptive recompression: land a finished background rebuild
            // (hot-swapping the active variant) or spawn one when the
            // routing window has filled — before admission, so sequences
            // admitted this very iteration already bind to the new variant
            self.adapt_tick();
            // memory-aware admission under strict priority: the
            // Interactive head starts whenever its prefill slot is free
            // (preempting Batch work when the pool cannot reserve its
            // worst-case block count); the Batch head starts only when no
            // Interactive work is queued or prefilling. FIFO within each
            // class, so a huge request is never starved by smaller ones
            // slipping past it.
            if inflight_i.is_none() && queues.has(Priority::Interactive) {
                self.make_room(&mut queues, &mut inflight_b, &mut active);
                inflight_i = self.try_admit(Priority::Interactive, &mut queues);
            }
            if inflight_i.is_none()
                && inflight_b.is_none()
                && !queues.has(Priority::Interactive)
                && queues.has(Priority::Batch)
            {
                inflight_b = self.try_admit(Priority::Batch, &mut queues);
            }
            // advance ONE in-flight prefill by one chunk (Interactive
            // first — a Batch prefill parks while Interactive runs)
            let slot = if inflight_i.is_some() { &mut inflight_i } else { &mut inflight_b };
            if let Some(inf) = slot.take() {
                *slot = self.prefill_chunk_step(inf, &mut active, &mut stall_tokens);
            }
            if !active.is_empty() {
                self.step(&mut active);
                stall_tokens = 0;
            }
            self.publish_kv_gauges();
        }
        Ok(())
    }

    /// Answer every generation the executor will never run — queued in
    /// the scheduler, mid-prefill, actively decoding, or still unread in
    /// the request channel — with an explicit error, so no client blocks
    /// forever on a reply that cannot come (`rust/tests/scheduler.rs`
    /// pins this). Pending score requests are answered by their reply
    /// channels dropping (the client's `recv` errors out).
    fn drain_on_stop(
        &self,
        rx: &Receiver<Request>,
        queues: &mut SchedQueues,
        inflight_i: &mut Option<PrefillInFlight>,
        inflight_b: &mut Option<PrefillInFlight>,
        active: &mut Vec<ActiveGen>,
    ) {
        while let Ok(req) = rx.try_recv() {
            if let Request::Generate(req) = req {
                let _ = req
                    .reply
                    .send(Err(anyhow!("server shutting down (request was still queued)")));
            } // Score: dropping the request drops its Sender
        }
        for q in queues.drain_all() {
            q.send_err(anyhow!("server shutting down (request was still queued)"));
        }
        for inf in [inflight_i.take(), inflight_b.take()].into_iter().flatten() {
            inf.seq.send_err(anyhow!("server shutting down (prefill was in flight)"));
        }
        for a in active.drain(..) {
            let _ = a
                .reply
                .send(Err(anyhow!("server shutting down (generation was in flight)")));
        }
    }

    /// Request validation performed at intake (degenerate parameters
    /// never enter a scheduler lane): sampling parameters, plus the
    /// speculative preconditions — a configured drafter and a usable
    /// draft depth.
    fn validate_gen(&self, req: &GenerateRequest) -> Result<()> {
        req.params.validate()?;
        match req.draft_k {
            None => {}
            Some(0) => {
                return Err(anyhow!("speculative decoding needs draft_k >= 1"));
            }
            Some(_) => {
                if self.registry.borrow().drafter().is_none() {
                    return Err(anyhow!(
                        "request asked for speculative decoding but the server has \
                         no drafter configured (set ServeSpec::drafter)"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Worst-case resident length of a request: its prompt plus every
    /// token `max_new_tokens` allows, clamped to the context window (the
    /// decode loop stops at `t_max` regardless; an over-long prompt is
    /// rejected by prefill, the `.max` merely keeps the bound honest until
    /// then). The single source for BOTH the admission check and the
    /// reservation passed to prefill — they must never disagree, or
    /// admission would guarantee a reservation it does not make.
    fn gen_reserve_tokens(&self, req: &GenerateRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.params.max_new_tokens)
            .min(self.ctx.cfg.t_max)
            .max(req.prompt.len())
    }

    /// Worst-case resident length of a queued unit of work. A fresh
    /// request uses [`Self::gen_reserve_tokens`]; a preempted one reuses
    /// the reservation bound it was originally admitted under (its
    /// resident prefix plus remaining decode room still fit inside it).
    fn queued_reserve_tokens(&self, q: &Queued) -> usize {
        match q {
            Queued::Fresh(req) => self.gen_reserve_tokens(req),
            Queued::Resume(p) => p.reserve_tokens,
        }
    }

    /// Worst-case KV **block** count of a queued unit of work: one
    /// cache's worth for a plain request, twice that for a speculative
    /// one (the full/drafter cache pair grows in lockstep, and KV
    /// geometry does not depend on expert count — the drafter's cache is
    /// exactly as large as the verifier's). The single source for BOTH
    /// the admission check and the reservations the prefill makes.
    fn queued_blocks(&self, q: &Queued) -> usize {
        let per_cache = self.pool.blocks_for(self.queued_reserve_tokens(q));
        if q.draft_k().is_some() {
            per_cache * 2
        } else {
            per_cache
        }
    }

    /// Preempt Batch work until the Interactive queue head can reserve its
    /// worst-case block count (or nothing preemptible remains). Victim
    /// order is cheapest-first: the in-flight/parked Batch prefill (only
    /// chunk compute is lost), then the most recently admitted active
    /// Batch sequence — LIFO, so the oldest Batch streams keep flowing.
    /// Interactive work is never preempted.
    fn make_room(
        &self,
        queues: &mut SchedQueues,
        inflight_b: &mut Option<PrefillInFlight>,
        active: &mut Vec<ActiveGen>,
    ) {
        let Some(head) = queues.front(Priority::Interactive) else { return };
        let need = self.queued_blocks(head);
        if need > self.pool.total_blocks() {
            return; // impossible request: try_admit answers it with an error
        }
        while !self.pool.can_reserve(need) && self.preempt_one(queues, inflight_b, active) {}
    }

    /// Swap out one unit of Batch work by **recompute**: the victim's KV
    /// blocks (and reservation) are released outright and the request
    /// re-queues at the head of the Batch lane; on re-admission its
    /// resident tokens — prompt plus everything generated so far — are
    /// re-prefilled chunk by chunk, rebuilding the exact dropped cache
    /// (`rust/tests/scheduler.rs` pins resumed streams bit-identical).
    /// Returns `false` when nothing preemptible remains.
    fn preempt_one(
        &self,
        queues: &mut SchedQueues,
        inflight_b: &mut Option<PrefillInFlight>,
        active: &mut Vec<ActiveGen>,
    ) -> bool {
        if let Some(inf) = inflight_b.take() {
            // push the request back first; the partial cache drops with
            // the rest of the in-flight state, releasing its blocks
            queues.push_front(inf.seq);
            self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        if let Some(i) = active.iter().rposition(|a| a.class == Priority::Batch) {
            let victim = active.remove(i);
            queues.push_front(Queued::Resume(victim.preempt()));
            self.metrics.preemptions.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Pop and start the head of `class`'s lane if the pool can reserve
    /// its worst-case block count. A request that could never fit (need
    /// exceeds the whole pool) is answered with an error immediately
    /// instead of deadlocking the lane behind it; a merely-currently-
    /// infeasible head keeps waiting — FIFO within its class.
    fn try_admit(&self, class: Priority, queues: &mut SchedQueues) -> Option<PrefillInFlight> {
        let head = queues.front(class)?;
        let need = self.queued_blocks(head);
        if need > self.pool.total_blocks() {
            let q = queues.pop(class).expect("head exists");
            q.send_err(anyhow!(
                "request needs {need} KV blocks but the pool holds only {} \
                 (raise {KV_BUDGET_ENV})",
                self.pool.total_blocks()
            ));
            return None;
        }
        if !self.pool.can_reserve(need) {
            return None;
        }
        let q = queues.pop(class).expect("head exists");
        // variant binding happens HERE, at admission: a fresh request
        // takes the currently active variant; a preempted one resumes on
        // the variant it was pinned to (its re-prefill must rebuild the
        // exact dropped cache — mixing variants mid-stream would break
        // the bit-identity contract)
        let variant = match &q {
            Queued::Fresh(_) => self.registry.borrow().active(),
            Queued::Resume(p) => Arc::clone(&p.variant),
        };
        Some(PrefillInFlight::new(q, variant))
    }

    /// Routing snapshot of the ACTIVE variant (zeroed counters on a
    /// freshly swapped-in one). Only called while adapt is configured,
    /// which the startup check guarantees the backend supports.
    fn routing_snapshot(&self) -> RoutingSnapshot {
        let active = self.registry.borrow().active();
        self.ctx
            .routing_stats(&active.model)
            .expect("adapt startup verified the backend reports routing stats")
    }

    /// One adaptive-recompression tick, run every executor iteration:
    ///
    /// 1. If a background recompression is in flight, try (without
    ///    blocking) to land its result: load the compressed weights on
    ///    the executor thread and [`VariantRegistry::swap`] atomically —
    ///    sequences admitted after this iteration bind the new variant,
    ///    in-flight ones finish on their pinned old one. A failed
    ///    recompression (or failed load) keeps the current variant
    ///    serving and restarts the window.
    /// 2. Otherwise, read the active variant's routing stats; when the
    ///    window since the last mark has `window` routed tokens (and the
    ///    warm-up bound is met), ship the window's dispatch counts to a
    ///    worker thread that rebuilds the variant from pristine base
    ///    weights with live-reweighted calibration
    ///    ([`variant::recompress`]).
    fn adapt_tick(&self) {
        let mut adapt = self.adapt.borrow_mut();
        let Some(st) = adapt.as_mut() else { return };
        if let Some((rx, t0)) = &st.inflight {
            match rx.try_recv() {
                Err(TryRecvError::Empty) => {} // still compressing; keep serving
                Ok(Ok(cm)) => {
                    self.metrics
                        .recompress_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let fp = cm.weights.content_hash();
                    // the load happens here on the executor thread (the
                    // backend state is not Send); only plain data crossed
                    // the channel
                    if let Ok(model) = cm.load(&self.ctx) {
                        let outcome = self.registry.borrow_mut().swap(model, fp);
                        if let SwapOutcome::Swapped { .. } = outcome {
                            self.metrics.swaps.fetch_add(1, Ordering::Relaxed);
                            self.metrics.active_variant.store(fp, Ordering::Relaxed);
                        }
                    }
                    st.inflight = None;
                    st.mark = self.routing_snapshot();
                }
                Ok(Err(_)) | Err(TryRecvError::Disconnected) => {
                    // recompression failed (or its thread died): the
                    // current variant keeps serving; restart the window
                    st.inflight = None;
                    st.mark = self.routing_snapshot();
                }
            }
            return; // at most one recompression in flight
        }
        let snap = self.routing_snapshot();
        let window = snap.since(&st.mark);
        self.metrics
            .dispatch_entropy_milli
            .store((window.dispatch_entropy() * 1e3) as u64, Ordering::Relaxed);
        if window.tokens < st.window {
            return;
        }
        if !st.fired && snap.tokens < st.min_tokens {
            return;
        }
        st.fired = true;
        let (tx, rx) = channel();
        let (root, model) = (st.artifacts_root.clone(), st.model.clone());
        let (method, r) = (st.spec.method.clone(), st.spec.r);
        let (domain, quantize) = (st.spec.domain.clone(), st.spec.quantize);
        let counts = window.counts;
        let spawned = std::thread::Builder::new()
            .name("hcsmoe-recompress".into())
            .spawn(move || {
                let _ = tx.send(variant::recompress(
                    &root, &model, &method, r, &domain, quantize, &counts,
                ));
            });
        if spawned.is_ok() {
            st.inflight = Some((rx, Instant::now()));
        }
        st.mark = snap;
    }

    /// Copy the pool counters into the metrics gauges.
    fn publish_kv_gauges(&self) {
        let s = self.pool.stats();
        self.metrics.kv_blocks_in_use.store(s.in_use as u64, Ordering::Relaxed);
        self.metrics.kv_blocks_shared.store(s.shared as u64, Ordering::Relaxed);
        self.metrics.kv_blocks_peak.store(s.peak_in_use as u64, Ordering::Relaxed);
    }

    /// Route one incoming request: score rows to the dynamic-batch queue,
    /// generations to their priority class's scheduler lane (prefilled
    /// later, chunk by chunk).
    fn intake(
        &self,
        req: Request,
        pendings: &mut Vec<Pending>,
        queue: &mut Vec<(usize, usize, RowSpec)>,
        oldest: &mut Option<Instant>,
        queues: &mut SchedQueues,
    ) {
        match req {
            Request::Score(req) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.rows.fetch_add(req.rows.len() as u64, Ordering::Relaxed);
                if req.rows.is_empty() {
                    // answer right away: an empty request would never reach
                    // flush() (the queue stays empty), and a stale `oldest`
                    // would pin the intake wait at zero
                    self.metrics
                        .queue_ns
                        .fetch_add(req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let _ = req.reply.send(Vec::new());
                    return;
                }
                oldest.get_or_insert(req.enqueued);
                let pi = pendings.len();
                let rows = req.rows.clone();
                pendings.push(Pending {
                    scores: vec![0.0; rows.len()],
                    remaining: rows.len(),
                    req,
                });
                for (ri, row) in rows.into_iter().enumerate() {
                    queue.push((pi, ri, row));
                }
            }
            // degenerate sampling parameters are answered immediately at
            // intake — they never enter a scheduler lane, so they can
            // neither delay their own error reply nor burn the one
            // chunk-per-iteration budget slot (and they don't count as
            // accepted in gen_requests)
            Request::Generate(req) => match self.validate_gen(&req) {
                Ok(()) => {
                    // counted at acceptance, not admission: a preempted
                    // request re-enters its lane and must not re-count
                    self.metrics.gen_requests.fetch_add(1, Ordering::Relaxed);
                    queues.push_back(Queued::Fresh(req));
                }
                Err(e) => {
                    let _ = req.reply.send(Err(e));
                }
            },
        }
    }

    /// Run the next chunk of an in-flight prefill: at most `self.chunk`
    /// prompt tokens (everything remaining when unchunked). The first
    /// chunk is a fresh paged prefill carrying the sequence's FULL block
    /// reservation — the caller's admission check guaranteed it fits —
    /// so later chunks and every decode step are assured their blocks;
    /// subsequent chunks extend the cache via
    /// [`crate::model::ModelContext::prefill_resume`]. Returns the
    /// in-flight state back while chunks remain; a finished prefill joins
    /// the continuous batch (or is answered immediately) and a failed one
    /// is answered with its error. Sampling parameters were already
    /// validated at intake.
    fn prefill_chunk_step(
        &self,
        mut inf: PrefillInFlight,
        active: &mut Vec<ActiveGen>,
        stall_tokens: &mut u64,
    ) -> Option<PrefillInFlight> {
        let total = inf.tokens().len();
        let remaining = total - inf.done;
        let take = self.chunk.map_or(remaining, |c| c.min(remaining));
        let ids: Vec<i32> = inf.tokens()[inf.done..inf.done + take].to_vec();
        // every chunk of this prefill runs on the variant bound at
        // admission — a hot swap mid-prefill never splits a cache across
        // two weight sets
        let variant = Arc::clone(&inf.variant);
        let t0 = Instant::now();
        let result = if let Some(cache) = inf.cache.as_mut() {
            self.ctx.prefill_resume(&variant.model, &ids, cache.as_mut())
        } else {
            let reserve = self.queued_reserve_tokens(&inf.seq);
            self.ctx
                .prefill_paged(&variant.model, &ids, &self.pool, reserve)
                .map(|(cache, logits)| {
                    inf.cache = Some(cache);
                    logits
                })
        };
        // drafter lockstep (speculative requests only): run the same
        // chunk through the compact drafter, so both caches of the pair
        // finish together and BOTH reservations are claimed by the first
        // chunk — admission checked 2× the block bound, and nothing else
        // can be admitted between the two halves of the claim
        let result = result.and_then(|logits| {
            if inf.seq.draft_k().is_some() {
                let drafter = self.registry.borrow().drafter().expect("validated at intake");
                if let Some(dc) = inf.draft_cache.as_mut() {
                    self.ctx.prefill_resume_compact(&drafter, &ids, dc.as_mut())?;
                } else {
                    let reserve = self.queued_reserve_tokens(&inf.seq);
                    let (dc, _) =
                        self.ctx.prefill_paged_compact(&drafter, &ids, &self.pool, reserve)?;
                    inf.draft_cache = Some(dc);
                }
            }
            Ok(logits)
        });
        let dt = t0.elapsed();
        inf.prefill_s += dt.as_secs_f64();
        inf.chunks += 1;
        self.metrics.prefill_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        self.metrics.prefill_tokens.fetch_add(take as u64, Ordering::Relaxed);
        if !active.is_empty() {
            // decode steps are stalling behind this chunk: feed the
            // observed-stall gauge (reset to zero after every decode step)
            *stall_tokens += take as u64;
            self.metrics
                .prefill_stall_tokens_max
                .fetch_max(*stall_tokens, Ordering::Relaxed);
        }
        let logits = match result {
            Ok(l) => l,
            Err(e) => {
                inf.seq.send_err(e);
                return None; // the partial cache drops, releasing its blocks
            }
        };
        inf.done += take;
        if inf.done < total {
            return Some(inf);
        }
        if inf.chunks > 1 {
            self.metrics.chunked_prefills.fetch_add(1, Ordering::Relaxed);
        }
        let cache = inf.cache.take().expect("completed prefill has a cache");
        let draft = match (inf.seq.draft_k(), inf.draft_cache.take()) {
            (Some(k), Some(cache)) => Some(DraftSeq { cache, k }),
            _ => None,
        };
        match inf.seq {
            Queued::Fresh(req) => {
                self.activate_fresh(req, variant, cache, draft, logits, inf.prefill_s, active)
            }
            Queued::Resume(p) => {
                // the re-prefill rebuilt the exact dropped cache pair; its
                // final logits are re-derived state (the next token was
                // already sampled before the preemption), so they are
                // discarded and decoding continues precisely where it
                // stopped
                active.push(ActiveGen {
                    reply: p.reply,
                    enqueued: p.enqueued,
                    class: p.class,
                    deadline: p.deadline,
                    prompt: p.prompt,
                    reserve_tokens: p.reserve_tokens,
                    session: p.session,
                    variant: p.variant,
                    cache,
                    draft,
                    next: p.next,
                    last_emit: Instant::now(),
                    prefill_s: p.prefill_s + inf.prefill_s,
                    decode_s: p.decode_s,
                    // the streamed watermark survives the preempt/resume
                    // round-trip, so nothing re-emits
                    stream: p.stream,
                    streamed: p.streamed,
                    lease: p.lease,
                });
            }
        }
        None
    }

    /// Push every not-yet-streamed token of this sequence down its
    /// per-token stream (no-op for non-streaming requests). The
    /// `streamed` watermark makes emission idempotent across preemption
    /// and resume — a re-prefilled sequence never re-emits. A send to a
    /// hung-up client is ignored here; disconnect eviction stays the
    /// intake loop's job (`reply.is_closed()`), keeping one eviction
    /// path for streaming and plain requests alike.
    fn emit_stream(a: &mut ActiveGen) {
        let Some(tx) = a.stream.as_ref() else { return };
        let toks = a.session.tokens();
        for &t in &toks[a.streamed..] {
            let _ = tx.send(t);
        }
        a.streamed = toks.len();
    }

    /// A fresh request finished its prefill: sample the first token from
    /// the final chunk's logits and join the continuous batch (or answer
    /// immediately when the first sample already finishes the request).
    fn activate_fresh(
        &self,
        mut req: GenerateRequest,
        variant: Arc<Variant>,
        cache: Box<dyn KvCache>,
        draft: Option<DraftSeq>,
        logits: Vec<f32>,
        prefill_s: f64,
        active: &mut Vec<ActiveGen>,
    ) {
        let reserve_tokens = self.gen_reserve_tokens(&req);
        let mut session = Session::new(req.params);
        // the first token is sampled from the prefill logits — its compute
        // is charged to prefill_ns, so it does not enter gen_tokens (which
        // strictly counts decode-step output; this keeps decode_tok_s /
        // ms_per_token honest per-step measurements)
        let next = session.advance(&logits, cache.seq_len(), self.ctx.cfg.t_max);
        match next {
            Some(next) => {
                let mut a = ActiveGen {
                    reply: req.reply,
                    enqueued: req.enqueued,
                    class: req.class,
                    deadline: req.deadline,
                    prompt: req.prompt,
                    reserve_tokens,
                    session,
                    variant,
                    cache,
                    draft,
                    next,
                    last_emit: Instant::now(),
                    prefill_s,
                    decode_s: 0.0,
                    stream: req.stream,
                    streamed: 0,
                    lease: req.lease,
                };
                Self::emit_stream(&mut a);
                active.push(a);
            }
            None => {
                self.metrics
                    .queue_ns
                    .fetch_add(req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if req.deadline.is_some_and(|d| req.enqueued.elapsed() > d) {
                    self.metrics.deadline_misses.fetch_add(1, Ordering::Relaxed);
                }
                // flush the (single) token to a streaming client before the
                // final reply, then drop the sender: channel close IS the
                // end-of-stream marker
                if let Some(tx) = req.stream.take() {
                    for &t in session.tokens() {
                        let _ = tx.send(t);
                    }
                }
                let finish = session.finish().expect("finished session");
                let _ = req.reply.send(Ok(Generated {
                    tokens: session.into_tokens(),
                    finish,
                    prefill_s,
                    decode_s: 0.0,
                }));
            }
        }
    }

    /// One decode iteration over the whole continuous batch. A batch of
    /// plain sequences takes the k=1 batched-decode path; as soon as any
    /// speculative sequence is active, the whole batch rides ONE
    /// multi-position verify forward instead — speculative sequences
    /// contribute their draft runs, plain sequences a 1-token run, and
    /// the verify bit-identity contract makes both indistinguishable
    /// from sequential decoding.
    fn step(&self, active: &mut Vec<ActiveGen>) {
        // fast path: the whole batch runs one variant (always true until
        // a hot swap, and again once the pre-swap sequences drain)
        let fp0 = active[0].variant.fingerprint;
        if active.iter().all(|a| a.variant.fingerprint == fp0) {
            return self.step_group(active);
        }
        // post-swap transient: in-flight sequences pin the variant they
        // were admitted on, so the batch briefly spans variants — but a
        // batched forward takes ONE weight set. Partition by fingerprint
        // (first-occurrence order keeps scheduling stable) and step each
        // group; each sequence still advances exactly one iteration.
        let mut groups: Vec<(u64, Vec<ActiveGen>)> = Vec::new();
        for a in std::mem::take(active) {
            let fp = a.variant.fingerprint;
            match groups.iter_mut().find(|(g, _)| *g == fp) {
                Some((_, members)) => members.push(a),
                None => groups.push((fp, vec![a])),
            }
        }
        for (_, mut group) in groups {
            self.step_group(&mut group);
            active.append(&mut group);
        }
    }

    /// One decode iteration for a single-variant group of sequences.
    fn step_group(&self, active: &mut Vec<ActiveGen>) {
        if active.iter().any(|a| a.draft.is_some()) {
            self.step_speculative(active)
        } else {
            self.step_plain(active)
        }
    }

    /// One **batched** decode step advancing every active sequence by one
    /// token (`ModelContext::decode_batch`: shared projection GEMMs,
    /// per-expert grouped SwiGLU across sequences); finished sequences are
    /// answered and leave the batch immediately. Each sequence's reported
    /// `decode_s` is its equal share of the batched step wall-clock.
    ///
    /// If the batched call itself fails, fall back to per-sequence decode
    /// so a single poisoned sequence is evicted with its error instead of
    /// failing the whole batch.
    fn step_plain(&self, active: &mut Vec<ActiveGen>) {
        let bsz = active.len();
        let tokens: Vec<i32> = active.iter().map(|a| a.next).collect();
        // single-variant group (step() partitioned): every cache here was
        // built by this variant, so one batched forward serves them all
        let variant = Arc::clone(&active[0].variant);
        let t0 = Instant::now();
        let rows = {
            let mut caches: Vec<&mut dyn KvCache> =
                active.iter_mut().map(|a| a.cache.as_mut()).collect();
            self.ctx.decode_batch(&variant.model, &mut caches, &tokens)
        };
        let rows = match rows {
            Ok(rows) => rows,
            Err(_) => return self.step_sequential(active),
        };
        let dt = t0.elapsed();
        self.metrics.decode_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.metrics.gen_tokens.fetch_add(bsz as u64, Ordering::Relaxed);
        let share = dt.as_secs_f64() / bsz as f64;
        for (mut a, logits) in std::mem::take(active).into_iter().zip(rows) {
            a.decode_s += share;
            self.record_itl(&mut a);
            match a.session.advance(&logits, a.cache.seq_len(), self.ctx.cfg.t_max) {
                Some(next) => {
                    a.next = next;
                    Self::emit_stream(&mut a);
                    active.push(a);
                }
                None => self.finish_gen(a),
            }
        }
    }

    /// One continuous-batch iteration through the multi-position verify
    /// path, interleaving speculative and plain sequences:
    ///
    /// 1. **Draft** — every speculative sequence proposes up to `k - 1`
    ///    tokens beyond its committed one, picking with a *clone* of its
    ///    [`Session`] (same RNG draws the verifier will spend) on batched
    ///    compact-drafter decodes: sequences still drafting round `j`
    ///    share one `decode_batch_compact` call. The drafter cache is
    ///    snapshotted per position so any rejection point is restorable.
    /// 2. **Verify** — ONE [`ModelContext::verify`] forward scores every
    ///    sequence; a plain sequence contributes a 1-token run and gets
    ///    exactly its plain batched-decode logits (the k=1 wrapper
    ///    identity), so mixing costs plain traffic nothing.
    /// 3. **Accept** — each sequence's real [`Session`] consumes its
    ///    verify rows in emission order (bit-identity with plain
    ///    decoding, same construction as [`crate::generate::speculative`]).
    ///    Past the first disagreement both caches of the pair roll back;
    ///    on a full accept with the sequence still live, the drafter
    ///    replays the run's last token (batched across sequences).
    ///
    /// A draft or verify error rolls every drafter cache back to its
    /// round-start snapshot and retries the iteration through
    /// [`Self::step_sequential`] (plain semantics, lockstep drafter
    /// feeds), so one poisoned sequence is evicted with its error instead
    /// of failing the whole batch.
    fn step_speculative(&self, active: &mut Vec<ActiveGen>) {
        let drafter =
            self.registry.borrow().drafter().expect("speculative sequence without a drafter");
        let drafter = &*drafter;
        // single-variant group (step() partitioned) — the verifier model
        let variant = Arc::clone(&active[0].variant);
        let t_max = self.ctx.cfg.t_max;
        let n = active.len();
        let t0 = Instant::now();
        // per-sequence round state: base length, proposed run, per-length
        // drafter snapshots, drafting session clone
        let mut t_bases = Vec::with_capacity(n);
        let mut k_effs = Vec::with_capacity(n);
        let mut runs: Vec<Vec<i32>> = Vec::with_capacity(n);
        let mut dsnaps: Vec<Vec<CacheSnapshot>> = Vec::with_capacity(n);
        let mut draft_sessions: Vec<Option<Session>> = Vec::with_capacity(n);
        for a in active.iter() {
            let t_base = a.cache.seq_len();
            // never propose more positions than the session can still emit
            // or the context window can still hold (both bounds >= 1: the
            // sequence is active, so its last advance returned Some)
            let mut k_eff = 1;
            if let Some(d) = a.draft.as_ref() {
                let remaining = a.session.params().max_new_tokens - a.session.tokens().len();
                k_eff = d.k.min(remaining).min(t_max - t_base).max(1);
            }
            let snap = if k_eff > 1 {
                let d = a.draft.as_ref().expect("k_eff > 1 implies a drafter");
                // a failed snapshot (foreign cache type) degrades the
                // sequence to a 1-token run this round instead of erroring
                self.ctx.snapshot_cache(d.cache.as_ref()).ok()
            } else {
                None
            };
            match snap {
                Some(s) => {
                    dsnaps.push(vec![s]);
                    draft_sessions.push(Some(a.session.clone()));
                }
                None => {
                    k_eff = 1;
                    dsnaps.push(Vec::new());
                    draft_sessions.push(None);
                }
            }
            t_bases.push(t_base);
            k_effs.push(k_eff);
            runs.push(vec![a.next]);
        }
        // draft rounds: all sequences still proposing at depth j share one
        // batched compact decode
        let max_k = k_effs.iter().copied().max().unwrap_or(1);
        let mut draft_failed = false;
        'draft: for j in 1..max_k {
            let idxs: Vec<usize> = (0..n).filter(|&i| k_effs[i] > j).collect();
            let tokens: Vec<i32> = idxs.iter().map(|&i| runs[i][j - 1]).collect();
            let rows = {
                let mut caches: Vec<&mut dyn KvCache> = active
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| k_effs[*i] > j)
                    .map(|(_, a)| {
                        a.draft.as_mut().expect("drafting sequence").cache.as_mut()
                    })
                    .collect();
                self.ctx.decode_batch_compact(drafter, &mut caches, &tokens)
            };
            let rows = match rows {
                Ok(r) => r,
                Err(_) => {
                    draft_failed = true;
                    break 'draft;
                }
            };
            for (row, &i) in rows.iter().zip(&idxs) {
                let d = active[i].draft.as_ref().expect("drafting sequence");
                match self.ctx.snapshot_cache(d.cache.as_ref()) {
                    Ok(s) => dsnaps[i].push(s),
                    Err(_) => {
                        draft_failed = true;
                        break 'draft;
                    }
                }
                let tok =
                    draft_sessions[i].as_mut().expect("drafting sequence").pick_next(row);
                runs[i].push(tok);
            }
        }
        if draft_failed {
            self.rollback_drafts(active, &dsnaps);
            return self.step_sequential(active);
        }
        // ONE multi-position verify across the whole batch (speculative
        // runs and plain 1-token runs interleaved)
        let outs = {
            let token_slices: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut caches: Vec<&mut dyn KvCache> =
                active.iter_mut().map(|a| a.cache.as_mut()).collect();
            self.ctx.verify(&variant.model, &mut caches, &token_slices)
        };
        let outs = match outs {
            Ok(o) => o,
            Err(_) => {
                // run_verify validates everything before mutating any
                // cache, so the batch state is exactly pre-call here
                self.rollback_drafts(active, &dsnaps);
                return self.step_sequential(active);
            }
        };
        let dt = t0.elapsed();
        self.metrics.decode_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.metrics.spec_rounds.fetch_add(1, Ordering::Relaxed);
        let drafted_now: u64 = runs.iter().map(|r| (r.len() - 1) as u64).sum();
        self.metrics.spec_drafted.fetch_add(drafted_now, Ordering::Relaxed);
        let share = dt.as_secs_f64() / n as f64;
        // accept phase: the real Sessions consume their verify rows; a
        // full-accepted drafter still owes a feed of its run's last token
        // (collected here, replayed batched below)
        let mut accepted_now = 0u64;
        let mut emitted_now = 0u64;
        let mut replay_idx: Vec<usize> = Vec::new();
        let mut replay_tokens: Vec<i32> = Vec::new();
        for (i, (mut a, out)) in std::mem::take(active).into_iter().zip(outs).enumerate() {
            a.decode_s += share;
            self.record_itl(&mut a);
            let t_base = t_bases[i];
            let k_run = runs[i].len();
            let before = a.session.tokens().len();
            let mut fed = k_run; // verify rows whose fed token stays accepted
            let mut next_pending = None;
            for p in 0..k_run {
                match a.session.advance(&out.logits[p], t_base + p + 1, t_max) {
                    None => {
                        // finished (EOS / budget / context): rows past p
                        // are speculative overshoot
                        fed = p + 1;
                        next_pending = None;
                        break;
                    }
                    Some(t) if p + 1 < k_run => {
                        if t == runs[i][p + 1] {
                            accepted_now += 1; // draft confirmed
                        } else {
                            fed = p + 1; // verifier's token replaces it
                            next_pending = Some(t);
                            break;
                        }
                    }
                    Some(t) => next_pending = Some(t), // all rows accepted
                }
            }
            emitted_now += (a.session.tokens().len() - before) as u64;
            if fed < k_run {
                // roll both caches of the pair back past the rejection
                let rolled = self
                    .ctx
                    .rollback_cache(a.cache.as_mut(), &out.checkpoints[fed - 1])
                    .and_then(|()| {
                        let d = a.draft.as_mut().expect("only draft runs can reject");
                        self.ctx.rollback_cache(d.cache.as_mut(), &dsnaps[i][fed])
                    });
                if let Err(e) = rolled {
                    let _ = a.reply.send(Err(e));
                    continue;
                }
            }
            match next_pending {
                Some(next) => {
                    a.next = next;
                    // a verify round may accept several tokens at once;
                    // the watermark streams exactly the newly-kept ones
                    Self::emit_stream(&mut a);
                    if fed == k_run && a.draft.is_some() {
                        replay_idx.push(active.len());
                        replay_tokens.push(runs[i][k_run - 1]);
                    }
                    active.push(a);
                }
                None => self.finish_gen(a),
            }
        }
        self.metrics.gen_tokens.fetch_add(emitted_now, Ordering::Relaxed);
        self.metrics.spec_accepted.fetch_add(accepted_now, Ordering::Relaxed);
        // batched drafter replay for fully-accepted live sequences; on a
        // batch error retry per sequence so only true offenders are
        // evicted
        if !replay_idx.is_empty() {
            let res = {
                let mut want = replay_idx.iter().copied().peekable();
                let mut caches: Vec<&mut dyn KvCache> = Vec::with_capacity(replay_idx.len());
                for (i, a) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&i) {
                        want.next();
                        caches.push(
                            a.draft.as_mut().expect("replay targets a drafter").cache.as_mut(),
                        );
                    }
                }
                self.ctx.decode_batch_compact(drafter, &mut caches, &replay_tokens)
            };
            if res.is_err() {
                // walk from the back so swap_remove never disturbs
                // unvisited (lower) indices
                for (pos, &i) in replay_idx.iter().enumerate().rev() {
                    let a = &mut active[i];
                    let d = a.draft.as_mut().expect("replay targets a drafter");
                    if let Err(e) =
                        self.ctx.decode_compact(drafter, d.cache.as_mut(), replay_tokens[pos])
                    {
                        let a = active.swap_remove(i);
                        let _ = a.reply.send(Err(e));
                    }
                }
            }
        }
    }

    /// Roll every drafter cache back to its round-start snapshot (the
    /// speculative error-fallback path); a sequence whose rollback itself
    /// fails is evicted with the error. Walks back-to-front so
    /// `swap_remove` keeps unvisited indices aligned with `dsnaps`.
    fn rollback_drafts(&self, active: &mut Vec<ActiveGen>, dsnaps: &[Vec<CacheSnapshot>]) {
        for i in (0..active.len().min(dsnaps.len())).rev() {
            let a = &mut active[i];
            let (Some(d), Some(snap)) = (a.draft.as_mut(), dsnaps[i].first()) else {
                continue;
            };
            if d.cache.seq_len() == snap.len() {
                continue;
            }
            if let Err(e) = self.ctx.rollback_cache(d.cache.as_mut(), snap) {
                let a = active.swap_remove(i);
                let _ = a.reply.send(Err(e));
            }
        }
    }

    /// Per-sequence decode fallback: only reached when the batched step
    /// errors, to isolate and evict the offending sequence while the rest
    /// keep decoding.
    fn step_sequential(&self, active: &mut Vec<ActiveGen>) {
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            let t0 = Instant::now();
            let fed = a.next;
            // per-sequence path, so each sequence decodes on its own
            // pinned variant (this fallback may legally mix variants)
            let variant = Arc::clone(&a.variant);
            // a speculative pair stays in lockstep even on this plain
            // path: the fed token enters both caches
            let logits = self.ctx.decode(&variant.model, a.cache.as_mut(), fed).and_then(|l| {
                if let Some(d) = a.draft.as_mut() {
                    let drafter = self
                        .registry
                        .borrow()
                        .drafter()
                        .expect("speculative sequence without a drafter");
                    self.ctx.decode_compact(&drafter, d.cache.as_mut(), fed)?;
                }
                Ok(l)
            });
            let logits = match logits {
                Ok(l) => l,
                Err(e) => {
                    let a = active.swap_remove(i);
                    let _ = a.reply.send(Err(e));
                    continue;
                }
            };
            let dt = t0.elapsed();
            a.decode_s += dt.as_secs_f64();
            self.metrics.decode_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
            self.metrics.gen_tokens.fetch_add(1, Ordering::Relaxed);
            self.record_itl(a);
            match a.session.advance(&logits, a.cache.seq_len(), self.ctx.cfg.t_max) {
                Some(next) => {
                    a.next = next;
                    Self::emit_stream(a);
                    i += 1;
                }
                None => {
                    let a = active.swap_remove(i);
                    self.finish_gen(a);
                }
            }
        }
    }

    /// Record one inter-token gap for a sequence that just produced a
    /// decode-step token. Only Interactive traffic feeds the histogram —
    /// it is the class with a latency SLO; Batch gaps (which legitimately
    /// balloon across a swap-out) would drown the signal.
    fn record_itl(&self, a: &mut ActiveGen) {
        if a.class == Priority::Interactive {
            self.metrics.itl.record(a.last_emit.elapsed().as_nanos() as u64);
        }
        a.last_emit = Instant::now();
    }

    /// Answer one finished generation; record its queue latency and
    /// whether it met its deadline (SLO accounting — see `deadline_misses`
    /// in SERVING.md's metrics table).
    fn finish_gen(&self, mut a: ActiveGen) {
        self.metrics
            .queue_ns
            .fetch_add(a.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if a.deadline.is_some_and(|d| a.enqueued.elapsed() > d) {
            self.metrics.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
        // flush the stream before the final reply, then let the sender
        // drop with `a`: channel close is the end-of-stream marker, and
        // the KV lease (if any) releases on the same drop
        Self::emit_stream(&mut a);
        let finish = a.session.finish().expect("finished session");
        let _ = a.reply.send(Ok(Generated {
            tokens: a.session.into_tokens(),
            finish,
            prefill_s: a.prefill_s,
            decode_s: a.decode_s,
        }));
    }

    /// Execute the queued score rows as full batches and deliver finished
    /// requests.
    fn flush(
        &self,
        pendings: &mut Vec<Pending>,
        queue: &mut Vec<(usize, usize, RowSpec)>,
    ) -> Result<()> {
        let (bsz, t) = (self.bsz, self.t);
        // score rows are stateless (no KV cache), so they always run the
        // currently active variant
        let variant = self.registry.borrow().active();
        while !queue.is_empty() {
            let take = queue.len().min(bsz);
            let chunk: Vec<_> = queue.drain(..take).collect();
            let mut ids = vec![crate::data::vocab::PAD; bsz * t];
            for (bi, (_, _, row)) in chunk.iter().enumerate() {
                for (p, &tok) in row.seq.iter().enumerate().take(t) {
                    ids[bi * t + p] = tok;
                }
            }
            let t0 = Instant::now();
            let logits = self.ctx.run_logits(&variant.model, &ids)?;
            self.metrics
                .busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.metrics.batches.fetch_add(1, Ordering::Relaxed);
            let v = logits.shape()[2];
            let ld = logits.data();
            for (bi, (pi, ri, row)) in chunk.iter().enumerate() {
                let mut lp = 0f64;
                // Position 0 has no conditioning context (there is no
                // logits row at -1): an empty-prompt row starts scoring
                // at position 1. Guards the `pos - 1` underflow that
                // panicked the executor on `start == 0` rows.
                for pos in row.start.max(1)..row.end.min(t) {
                    let lrow = &ld[(bi * t + pos - 1) * v..(bi * t + pos) * v];
                    lp += log_softmax_at(lrow, row.seq[pos] as usize);
                }
                // normalise by the number of positions actually scored
                // (start==0 skips position 0, so the divisor must too)
                lp /= (row.end.saturating_sub(row.start.max(1))).max(1) as f64;
                let p = &mut pendings[*pi];
                p.scores[*ri] = lp;
                p.remaining -= 1;
            }
        }
        // deliver finished requests
        for p in pendings.iter_mut() {
            if p.remaining == 0 {
                let scores = std::mem::take(&mut p.scores);
                self.metrics
                    .queue_ns
                    .fetch_add(p.req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = p.req.reply.send(scores);
            }
        }
        pendings.retain(|p| p.remaining > 0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_snapshot_math() {
        let m = Metrics::default();
        m.rows.store(64, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.busy_ns.store(2_000_000_000, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.rows_per_sec(), 32.0);
        assert_eq!(s.mean_batch_fill(32), 1.0);
    }

    #[test]
    fn generation_metrics_math() {
        let m = Metrics::default();
        m.gen_requests.store(4, Ordering::Relaxed);
        m.gen_tokens.store(100, Ordering::Relaxed);
        m.decode_ns.store(500_000_000, Ordering::Relaxed); // 0.5 s
        m.prefill_tokens.store(40, Ordering::Relaxed);
        m.prefill_ns.store(10_000_000, Ordering::Relaxed); // 10 ms
        let s = m.snapshot();
        assert_eq!(s.decode_tok_s(), 200.0);
        assert_eq!(s.ms_per_token(), 5.0);
        assert_eq!(s.prefill_tok_s(), 4000.0);
        // empty counters stay well-defined
        let z = Metrics::default().snapshot();
        assert_eq!(z.decode_tok_s(), 0.0);
        assert_eq!(z.ms_per_token(), 0.0);
    }

    #[test]
    fn rowspec_construction() {
        let prompt = [1, 2, 3];
        let choices = vec![vec![7], vec![8, 9]];
        let rows: Vec<RowSpec> = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        assert_eq!(rows[0].end, 4);
        assert_eq!(rows[1].end, 5);
        assert_eq!(rows[1].start, 3);
    }
}
