//! Serving layer: a threaded scoring **and generation** server.
//!
//! The paper's deployment motivation (Section 1) is memory-constrained
//! *serving* of SMoE models; this module demonstrates the merged models on
//! a live request path with two coexisting workloads:
//!
//! * **Score requests** (multiple-choice scoring) ride a *dynamic batcher*:
//!   rows are packed up to the model's batch size or a deadline, whichever
//!   comes first (vLLM-router-style size/deadline policy).
//! * **Generate requests** ride a *continuous batcher* (vLLM-style): each
//!   accepted request is prefilled into its own KV cache and joins the
//!   running decode set; every executor iteration advances **all** active
//!   sequences by one token in a **single batched call**
//!   ([`crate::backend::Backend::run_decode_batch`] — shared projection
//!   GEMMs, per-expert grouped SwiGLU), and sequences leave the set the
//!   moment they hit a stop condition — no sequence waits for a "batch"
//!   to finish. Score batches interleave between decode steps.
//!   Admissions are **budgeted**: at most one prompt prefill runs between
//!   decode steps, so a burst of long prompts queues behind the budget
//!   instead of stalling every active sequence (head-of-line fairness).
//!
//! A single executor thread owns all execution state (required for the
//! PJRT backend, whose xla handles are not `Send`; the native backend
//! simply inherits the same single-executor design) — everything else is
//! channels. Used by `examples/serve_merged.rs`,
//! `examples/generate_merged.rs` and the Table 20 throughput/latency
//! measurements. Runs offline end to end on the native backend. The full
//! architecture (request lifecycle, batching policies, KV-cache memory
//! accounting, metrics definitions) is documented in `SERVING.md`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::KvCache;
use crate::calib::CalibStats;
use crate::config::Artifacts;
use crate::eval::log_softmax_at;
use crate::generate::{Generated, SamplingParams, Session};
use crate::kvpool::{PoolHandle, DEFAULT_KV_BUDGET_MB, KV_BUDGET_ENV};
use crate::model::{LoadedModel, ModelContext};
use crate::pipeline::{Method, Pipeline};

/// Shared state of a [`reply_channel`] pair.
struct ReplyShared<T> {
    state: Mutex<ReplyState<T>>,
    cv: Condvar,
}

struct ReplyState<T> {
    queue: VecDeque<T>,
    senders: usize,
    rx_alive: bool,
}

/// Sending half of a [`reply_channel`]: like an `mpsc::Sender`, plus
/// [`ReplyTx::is_closed`] — the executor probes it at step boundaries to
/// evict generations whose client vanished, instead of decoding to
/// `max_tokens` for nobody and holding the sequence's KV blocks the whole
/// time (`std::sync::mpsc` cannot express this: a disconnected receiver is
/// only observable by consuming a send).
pub struct ReplyTx<T>(Arc<ReplyShared<T>>);

/// Receiving half of a [`reply_channel`]. Dropping it marks the channel
/// closed, which the executor observes via [`ReplyTx::is_closed`].
pub struct ReplyRx<T>(Arc<ReplyShared<T>>);

/// A multi-producer reply channel with disconnect detection. Several
/// requests may share one channel (replies arrive in the executor's
/// completion order — the admission-ordering tests rely on this).
pub fn reply_channel<T>() -> (ReplyTx<T>, ReplyRx<T>) {
    let shared = Arc::new(ReplyShared {
        state: Mutex::new(ReplyState { queue: VecDeque::new(), senders: 1, rx_alive: true }),
        cv: Condvar::new(),
    });
    (ReplyTx(Arc::clone(&shared)), ReplyRx(shared))
}

impl<T> ReplyTx<T> {
    /// Deliver one value; returns it back when the receiver is gone.
    pub fn send(&self, value: T) -> std::result::Result<(), T> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        if !st.rx_alive {
            return Err(value);
        }
        st.queue.push_back(value);
        drop(st);
        self.0.cv.notify_one();
        Ok(())
    }

    /// True once the receiving half was dropped — no send can ever be
    /// observed again, so work producing one is wasted.
    pub fn is_closed(&self) -> bool {
        !self.0.state.lock().expect("reply channel poisoned").rx_alive
    }
}

impl<T> Clone for ReplyTx<T> {
    fn clone(&self) -> Self {
        self.0.state.lock().expect("reply channel poisoned").senders += 1;
        ReplyTx(Arc::clone(&self.0))
    }
}

impl<T> Drop for ReplyTx<T> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        st.senders -= 1;
        let last = st.senders == 0;
        drop(st);
        if last {
            // unblock a receiver waiting on a channel that can no longer
            // produce values
            self.0.cv.notify_all();
        }
    }
}

impl<T> ReplyRx<T> {
    /// Block until a value arrives; errors once every sender is gone and
    /// the queue is drained (mirrors `mpsc::Receiver::recv`).
    pub fn recv(&self) -> Result<T> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        loop {
            if let Some(v) = st.queue.pop_front() {
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(anyhow!("reply channel disconnected"));
            }
            st = self.0.cv.wait(st).expect("reply channel poisoned");
        }
    }

    /// Non-blocking receive: `Ok(None)` when the queue is empty but
    /// senders remain.
    pub fn try_recv(&self) -> Result<Option<T>> {
        let mut st = self.0.state.lock().expect("reply channel poisoned");
        if let Some(v) = st.queue.pop_front() {
            return Ok(Some(v));
        }
        if st.senders == 0 {
            return Err(anyhow!("reply channel disconnected"));
        }
        Ok(None)
    }
}

impl<T> Drop for ReplyRx<T> {
    fn drop(&mut self) {
        self.0.state.lock().expect("reply channel poisoned").rx_alive = false;
    }
}

/// How long the executor sleeps on an empty queue before re-checking the
/// stop flag.
const POLL: Duration = Duration::from_millis(50);

/// One scoring request: score `rows` (token sequences) and return the
/// length-normalised logprob of positions [start, end) per row.
pub struct ScoreRequest {
    /// Rows to score.
    pub rows: Vec<RowSpec>,
    /// Channel receiving the per-row normalised logprobs.
    pub reply: Sender<Vec<f64>>,
    /// Submission time (drives queue-latency metrics).
    pub enqueued: Instant,
}

/// One text-generation request, served by the continuous batcher.
pub struct GenerateRequest {
    /// Prompt token ids (must be non-empty and fit in `t_max`).
    pub prompt: Vec<i32>,
    /// Sampling strategy + stop conditions.
    pub params: SamplingParams,
    /// Channel receiving the finished generation (or the error). A
    /// [`ReplyTx`] rather than a plain `Sender` so the executor can detect
    /// a vanished client ([`ReplyTx::is_closed`]) and evict the sequence —
    /// releasing its KV blocks — instead of decoding to `max_tokens` into
    /// the void.
    pub reply: ReplyTx<Result<Generated>>,
    /// Submission time (drives queue-latency metrics).
    pub enqueued: Instant,
}

/// Anything a client can submit to the executor.
pub enum Request {
    /// Multiple-choice scoring (dynamic batcher).
    Score(ScoreRequest),
    /// Autoregressive generation (continuous batcher).
    Generate(GenerateRequest),
}

impl From<ScoreRequest> for Request {
    fn from(r: ScoreRequest) -> Self {
        Request::Score(r)
    }
}

impl From<GenerateRequest> for Request {
    fn from(r: GenerateRequest) -> Self {
        Request::Generate(r)
    }
}

/// One scored row: a token sequence plus the `[start, end)` span whose
/// logprob is accumulated.
#[derive(Debug, Clone)]
pub struct RowSpec {
    /// Token sequence (padded by the batcher).
    pub seq: Vec<i32>,
    /// First predicted position (prompt length).
    pub start: usize,
    /// One past the last predicted position.
    pub end: usize,
}

/// Live serving counters (shared with clients via `Arc`).
///
/// Scoring traffic is tracked by `requests`/`rows`/`batches`/`busy_ns`;
/// generation traffic by `gen_requests`/`prefill_tokens`/`gen_tokens` with
/// its time split into `prefill_ns` and `decode_ns` (so per-token decode
/// latency is measurable independently of prompt length). `queue_ns`
/// covers both workloads (submit → reply).
///
/// # Examples
///
/// ```
/// use std::sync::atomic::Ordering;
/// use hc_smoe::serving::Metrics;
///
/// let m = Metrics::default();
/// m.gen_tokens.store(500, Ordering::Relaxed);
/// m.decode_ns.store(2_000_000_000, Ordering::Relaxed); // 2 s
/// m.prefill_tokens.store(64, Ordering::Relaxed);
/// m.prefill_ns.store(8_000_000, Ordering::Relaxed); // 8 ms
///
/// let s = m.snapshot();
/// assert_eq!(s.decode_tok_s(), 250.0);
/// assert_eq!(s.ms_per_token(), 4.0);
/// assert_eq!(s.prefill_tok_s(), 8000.0);
/// ```
#[derive(Default)]
pub struct Metrics {
    /// Score requests accepted.
    pub requests: AtomicU64,
    /// Score rows accepted.
    pub rows: AtomicU64,
    /// Score batches executed.
    pub batches: AtomicU64,
    /// Nanoseconds spent executing score batches.
    pub busy_ns: AtomicU64,
    /// Nanoseconds requests spent queued (enqueue -> reply), both kinds.
    pub queue_ns: AtomicU64,
    /// Generate requests accepted.
    pub gen_requests: AtomicU64,
    /// Prompt tokens prefilled for generate requests.
    pub prefill_tokens: AtomicU64,
    /// Tokens emitted by decode steps (incl. EOS when sampled). Each
    /// sequence's *first* token is sampled from the prefill logits — its
    /// compute sits in `prefill_ns`, so it is deliberately not counted
    /// here; `decode_ns / gen_tokens` is then an honest per-decode-step
    /// latency.
    pub gen_tokens: AtomicU64,
    /// Nanoseconds spent in prompt prefills.
    pub prefill_ns: AtomicU64,
    /// Nanoseconds spent in decode steps.
    pub decode_ns: AtomicU64,
    /// Batched decode iterations executed (each advances every active
    /// sequence by one token). `gen_tokens / decode_steps` is therefore
    /// the mean decode-batch occupancy — how much concurrency the batched
    /// step actually captured.
    pub decode_steps: AtomicU64,
    /// Generations evicted because the client dropped its reply channel
    /// (queued or mid-decode); their KV blocks return to the pool.
    pub gen_disconnects: AtomicU64,
    /// Gauge: paged KV blocks currently referenced by live sequences.
    pub kv_blocks_in_use: AtomicU64,
    /// Gauge: paged KV blocks referenced by more than one sequence
    /// (prefix sharing in effect).
    pub kv_blocks_shared: AtomicU64,
    /// Gauge: high-water mark of `kv_blocks_in_use` over the pool's life.
    pub kv_blocks_peak: AtomicU64,
}

impl Metrics {
    /// Consistent-enough copy of the counters for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            busy_s: self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
            queue_s: self.queue_ns.load(Ordering::Relaxed) as f64 / 1e9,
            gen_requests: self.gen_requests.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            gen_tokens: self.gen_tokens.load(Ordering::Relaxed),
            prefill_s: self.prefill_ns.load(Ordering::Relaxed) as f64 / 1e9,
            decode_s: self.decode_ns.load(Ordering::Relaxed) as f64 / 1e9,
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            gen_disconnects: self.gen_disconnects.load(Ordering::Relaxed),
            kv_blocks_in_use: self.kv_blocks_in_use.load(Ordering::Relaxed),
            kv_blocks_shared: self.kv_blocks_shared.load(Ordering::Relaxed),
            kv_blocks_peak: self.kv_blocks_peak.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`Metrics`].
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    /// Score requests accepted.
    pub requests: u64,
    /// Score rows accepted.
    pub rows: u64,
    /// Score batches executed.
    pub batches: u64,
    /// Seconds spent executing score batches.
    pub busy_s: f64,
    /// Seconds requests spent queued (both kinds).
    pub queue_s: f64,
    /// Generate requests accepted.
    pub gen_requests: u64,
    /// Prompt tokens prefilled.
    pub prefill_tokens: u64,
    /// Tokens emitted by decode steps (first-token samples excluded).
    pub gen_tokens: u64,
    /// Seconds spent in prompt prefills.
    pub prefill_s: f64,
    /// Seconds spent in decode steps.
    pub decode_s: f64,
    /// Batched decode iterations executed.
    pub decode_steps: u64,
    /// Generations evicted on client disconnect.
    pub gen_disconnects: u64,
    /// Gauge: paged KV blocks referenced by live sequences.
    pub kv_blocks_in_use: u64,
    /// Gauge: paged KV blocks shared by more than one sequence.
    pub kv_blocks_shared: u64,
    /// Gauge: high-water mark of `kv_blocks_in_use`.
    pub kv_blocks_peak: u64,
}

impl MetricsSnapshot {
    /// Rows scored per busy second.
    pub fn rows_per_sec(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.rows as f64 / self.busy_s
        } else {
            0.0
        }
    }

    /// Mean batch occupancy in [0, 1] at the given batch size.
    pub fn mean_batch_fill(&self, batch_size: usize) -> f64 {
        if self.batches > 0 {
            self.rows as f64 / (self.batches as f64 * batch_size as f64)
        } else {
            0.0
        }
    }

    /// Decode throughput in generated tokens per second.
    pub fn decode_tok_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.gen_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }

    /// Prefill throughput in prompt tokens per second.
    pub fn prefill_tok_s(&self) -> f64 {
        if self.prefill_s > 0.0 {
            self.prefill_tokens as f64 / self.prefill_s
        } else {
            0.0
        }
    }

    /// Mean per-token decode latency in milliseconds.
    pub fn ms_per_token(&self) -> f64 {
        if self.gen_tokens > 0 {
            self.decode_s * 1e3 / self.gen_tokens as f64
        } else {
            0.0
        }
    }

    /// Mean decode-batch occupancy: tokens advanced per batched decode
    /// iteration (1.0 = the batcher never saw concurrent sequences).
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps > 0 {
            self.gen_tokens as f64 / self.decode_steps as f64
        } else {
            0.0
        }
    }
}

/// Dynamic-batcher flush policy for score rows (size or deadline,
/// whichever first). Generation is not subject to it: decode requests
/// join the continuous batch as soon as the executor sees them.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush when this many rows are queued (= executable batch size).
    pub max_rows: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

/// What the executor thread should serve.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Artifact directory the executor loads from.
    pub artifacts_root: String,
    /// Model family name to serve.
    pub model: String,
    /// None = serve the original model; Some = compress first.
    pub compress: Option<(Method, usize, String)>, // (method, r, calib domain)
    /// Paged KV-cache pool budget in bytes. `None` resolves
    /// `HCSMOE_KV_BUDGET_MB`, then the 64 MiB default — see `SERVING.md`
    /// §"KV memory model". Generation prompts are only admitted while the
    /// pool can reserve their worst-case block count; the rest wait in the
    /// admission queue.
    pub kv_budget_bytes: Option<usize>,
}

/// Client-side handle to a running server.
pub struct ServerHandle {
    tx: Sender<Request>,
    /// Live serving counters.
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    /// Submit one multiple-choice item; returns per-choice normalised
    /// logprobs (blocking).
    pub fn score_item(&self, prompt: &[i32], choices: &[Vec<i32>]) -> Result<Vec<f64>> {
        let rows = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        let (reply, rx) = channel();
        self.tx
            .send(Request::Score(ScoreRequest { rows, reply, enqueued: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx.recv()?)
    }

    /// Submit one generation request; blocks until the sequence finishes.
    /// With a seeded [`SamplingParams`], the result is bit-identical to an
    /// offline [`crate::generate::generate`] call on the same variant —
    /// the server runs the same [`Session`] loop.
    pub fn generate(&self, prompt: &[i32], params: SamplingParams) -> Result<Generated> {
        let (reply, rx) = reply_channel();
        self.tx
            .send(Request::Generate(GenerateRequest {
                prompt: prompt.to_vec(),
                params,
                reply,
                enqueued: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv()?
    }

    /// A clonable submission channel for client threads.
    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    /// Stop the server and join the executor thread. Robust against
    /// still-alive cloned senders: an explicit stop flag breaks the
    /// executor loop even if the channel never disconnects. In-flight
    /// generations are abandoned (their clients observe a closed reply
    /// channel); when the channel merely disconnects instead, the
    /// executor finishes all in-flight work before exiting.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

/// Start the executor thread. All PJRT state lives inside it.
pub fn serve(spec: ServeSpec, batcher: BatcherConfig) -> Result<ServerHandle> {
    let (tx, rx) = channel::<Request>();
    let metrics = Arc::new(Metrics::default());
    let m2 = Arc::clone(&metrics);
    let stop = Arc::new(AtomicBool::new(false));
    let s2 = Arc::clone(&stop);
    let join = std::thread::Builder::new()
        .name("hcsmoe-executor".into())
        .spawn(move || executor_loop(spec, batcher, rx, m2, s2))?;
    Ok(ServerHandle { tx, metrics, stop, join: Some(join) })
}

/// A queued-but-unanswered score request with its partial scores.
struct Pending {
    req: ScoreRequest,
    scores: Vec<f64>,
    remaining: usize,
}

/// One generation sequence in the continuous batch.
struct ActiveGen {
    reply: ReplyTx<Result<Generated>>,
    enqueued: Instant,
    session: Session,
    cache: Box<dyn KvCache>,
    /// Sampled but not yet fed to the model.
    next: i32,
    prefill_s: f64,
    decode_s: f64,
}

/// The executor: one thread owning the model and all execution state.
struct Executor {
    ctx: ModelContext,
    model: LoadedModel,
    bsz: usize,
    t: usize,
    batcher: BatcherConfig,
    metrics: Arc<Metrics>,
    /// The paged KV-cache pool every generation's cache lives in — the
    /// memory budget admission control enforces.
    pool: PoolHandle,
}

/// Resolve the pool budget: explicit spec bytes, else `HCSMOE_KV_BUDGET_MB`,
/// else the 64 MiB default. A *set but malformed* env value is a startup
/// error — silently falling back to the default would serve a different
/// memory budget than the operator asked for.
fn resolve_kv_budget(spec: &ServeSpec) -> Result<usize> {
    if let Some(bytes) = spec.kv_budget_bytes {
        return Ok(bytes);
    }
    match std::env::var(KV_BUDGET_ENV) {
        Ok(v) => {
            let mb: usize = v.trim().parse().map_err(|_| {
                anyhow!("{KV_BUDGET_ENV}={v:?} is not a whole MiB count (e.g. 64)")
            })?;
            Ok(mb * 1024 * 1024)
        }
        Err(_) => Ok(DEFAULT_KV_BUDGET_MB * 1024 * 1024),
    }
}

fn executor_loop(
    spec: ServeSpec,
    batcher: BatcherConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let budget = resolve_kv_budget(&spec)?;
    let arts = Artifacts::new(&spec.artifacts_root);
    let ctx = ModelContext::load(&arts, &spec.model)?;
    let model = match &spec.compress {
        None => ctx.load_original()?,
        Some((method, r, domain)) => {
            let stats: CalibStats = ctx.calibrate(domain)?;
            let plan = Pipeline::new(method.clone()).plan(&ctx, &stats, *r)?;
            plan.apply(&ctx, &stats)?.load(&ctx)?
        }
    };
    let (bsz, t) = (ctx.manifest.eval_b, ctx.manifest.eval_t);
    let pool = ctx.kv_pool(budget)?;
    let exec = Executor { ctx, model, bsz, t, batcher, metrics, pool };
    exec.run(rx, stop)
}

impl Executor {
    /// The main loop: intake → (score flush when due) → at most ONE
    /// prefill admission → one **batched** decode step across every
    /// active sequence — so decode requests join and leave the running
    /// batch on step boundaries while score batches interleave.
    ///
    /// Admissions are deliberately budgeted instead of running inside the
    /// intake drain: a prefill costs O(prompt²) attention while a decode
    /// step costs O(t) per sequence, so draining a burst of long prompts
    /// synchronously (the old design) froze every active sequence for the
    /// whole burst. With the budget, an in-flight sequence falls at most
    /// one prefill behind per iteration (`rust/tests/decode_batch.rs`
    /// pins the regression).
    fn run(&self, rx: Receiver<Request>, stop: Arc<AtomicBool>) -> Result<()> {
        let mut pendings: Vec<Pending> = Vec::new();
        let mut queue: Vec<(usize, usize, RowSpec)> = Vec::new();
        let mut active: Vec<ActiveGen> = Vec::new();
        // generation requests accepted but not yet prefilled (admission
        // budget: one per loop iteration)
        let mut admissions: VecDeque<GenerateRequest> = VecDeque::new();
        // enqueue time of the oldest unflushed score request
        let mut oldest: Option<Instant> = None;
        let mut disconnected = false;
        loop {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            if !disconnected {
                // Block only when there is nothing to advance or admit;
                // while sequences decode or prefills wait, drain without
                // waiting.
                let wait = if !active.is_empty() || !admissions.is_empty() {
                    Duration::ZERO
                } else if let Some(o) = oldest {
                    self.batcher.max_wait.saturating_sub(o.elapsed()).min(POLL)
                } else {
                    POLL
                };
                match rx.recv_timeout(wait) {
                    Ok(req) => {
                        self.intake(req, &mut pendings, &mut queue, &mut oldest, &mut admissions);
                        while let Ok(req) = rx.try_recv() {
                            self.intake(
                                req,
                                &mut pendings,
                                &mut queue,
                                &mut oldest,
                                &mut admissions,
                            );
                        }
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
            if disconnected && active.is_empty() && queue.is_empty() && admissions.is_empty() {
                break;
            }
            let flush_due = !queue.is_empty()
                && (queue.len() >= self.batcher.max_rows
                    || oldest.is_some_and(|o| o.elapsed() >= self.batcher.max_wait)
                    || disconnected);
            if flush_due {
                self.flush(&mut pendings, &mut queue)?;
                oldest = None;
            }
            // client-disconnect eviction at step boundaries: a sequence
            // (or queued request) whose reply channel closed would decode
            // to max_tokens for nobody while pinning its KV blocks —
            // dropping it here releases the blocks back to the pool
            let m = &self.metrics;
            admissions.retain(|r| {
                let gone = r.reply.is_closed();
                if gone {
                    m.gen_disconnects.fetch_add(1, Ordering::Relaxed);
                }
                !gone
            });
            active.retain(|a| {
                let gone = a.reply.is_closed();
                if gone {
                    m.gen_disconnects.fetch_add(1, Ordering::Relaxed);
                }
                !gone
            });
            // bounded, memory-aware admission: at most one prefill between
            // decode steps, and only when the pool can reserve the
            // request's worst-case block count (prompt + max_new_tokens);
            // otherwise the queue head waits — FIFO, so a huge request is
            // never starved by smaller ones slipping past it
            if let Some(front) = admissions.front() {
                let need = self.gen_blocks(front);
                if need > self.pool.total_blocks() {
                    // can never fit: answer now instead of deadlocking the
                    // admission queue behind an impossible reservation
                    let req = admissions.pop_front().expect("front exists");
                    let _ = req.reply.send(Err(anyhow!(
                        "request needs {need} KV blocks but the pool holds only {} \
                         (raise {KV_BUDGET_ENV})",
                        self.pool.total_blocks()
                    )));
                } else if self.pool.can_reserve(need) {
                    let req = admissions.pop_front().expect("front exists");
                    self.admit(req, &mut active);
                }
            }
            if !active.is_empty() {
                self.step(&mut active);
            }
            self.publish_kv_gauges();
        }
        Ok(())
    }

    /// Worst-case resident length of a request: its prompt plus every
    /// token `max_new_tokens` allows, clamped to the context window (the
    /// decode loop stops at `t_max` regardless; an over-long prompt is
    /// rejected by prefill, the `.max` merely keeps the bound honest until
    /// then). The single source for BOTH the admission check and the
    /// reservation passed to prefill — they must never disagree, or
    /// admission would guarantee a reservation it does not make.
    fn gen_reserve_tokens(&self, req: &GenerateRequest) -> usize {
        req.prompt
            .len()
            .saturating_add(req.params.max_new_tokens)
            .min(self.ctx.cfg.t_max)
            .max(req.prompt.len())
    }

    /// Worst-case KV blocks a request can occupy (the admission quantity).
    fn gen_blocks(&self, req: &GenerateRequest) -> usize {
        self.pool.blocks_for(self.gen_reserve_tokens(req))
    }

    /// Copy the pool counters into the metrics gauges.
    fn publish_kv_gauges(&self) {
        let s = self.pool.stats();
        self.metrics.kv_blocks_in_use.store(s.in_use as u64, Ordering::Relaxed);
        self.metrics.kv_blocks_shared.store(s.shared as u64, Ordering::Relaxed);
        self.metrics.kv_blocks_peak.store(s.peak_in_use as u64, Ordering::Relaxed);
    }

    /// Route one incoming request: score rows to the dynamic-batch queue,
    /// generations to the admission queue (prefilled later under the
    /// per-iteration budget).
    fn intake(
        &self,
        req: Request,
        pendings: &mut Vec<Pending>,
        queue: &mut Vec<(usize, usize, RowSpec)>,
        oldest: &mut Option<Instant>,
        admissions: &mut VecDeque<GenerateRequest>,
    ) {
        match req {
            Request::Score(req) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.rows.fetch_add(req.rows.len() as u64, Ordering::Relaxed);
                if req.rows.is_empty() {
                    // answer right away: an empty request would never reach
                    // flush() (the queue stays empty), and a stale `oldest`
                    // would pin the intake wait at zero
                    self.metrics
                        .queue_ns
                        .fetch_add(req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let _ = req.reply.send(Vec::new());
                    return;
                }
                oldest.get_or_insert(req.enqueued);
                let pi = pendings.len();
                let rows = req.rows.clone();
                pendings.push(Pending {
                    scores: vec![0.0; rows.len()],
                    remaining: rows.len(),
                    req,
                });
                for (ri, row) in rows.into_iter().enumerate() {
                    queue.push((pi, ri, row));
                }
            }
            // degenerate sampling parameters are answered immediately at
            // intake — they never enter the admission queue, so they can
            // neither delay their own error reply nor burn the one
            // prefill-per-iteration budget slot (and they don't count as
            // accepted in gen_requests)
            Request::Generate(req) => match req.params.validate() {
                Ok(()) => admissions.push_back(req),
                Err(e) => {
                    let _ = req.reply.send(Err(e));
                }
            },
        }
    }

    /// Prefill one generation request into the paged pool and add it to
    /// the continuous batch (or answer immediately when it finishes within
    /// the first sample). The caller verified the pool can reserve the
    /// request's worst-case block count, so the reservation below cannot
    /// fail and decode-time allocations are guaranteed. Sampling
    /// parameters were already validated at intake.
    fn admit(&self, req: GenerateRequest, active: &mut Vec<ActiveGen>) {
        self.metrics.gen_requests.fetch_add(1, Ordering::Relaxed);
        let reserve_tokens = self.gen_reserve_tokens(&req);
        let t0 = Instant::now();
        let (cache, logits) =
            match self
                .ctx
                .prefill_paged(&self.model, &req.prompt, &self.pool, reserve_tokens)
            {
                Ok(x) => x,
                Err(e) => {
                    let _ = req.reply.send(Err(e));
                    return;
                }
            };
        let prefill_s = t0.elapsed().as_secs_f64();
        self.metrics
            .prefill_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.metrics
            .prefill_tokens
            .fetch_add(req.prompt.len() as u64, Ordering::Relaxed);
        let mut session = Session::new(req.params);
        // the first token is sampled from the prefill logits — its compute
        // is charged to prefill_ns, so it does not enter gen_tokens (which
        // strictly counts decode-step output; this keeps decode_tok_s /
        // ms_per_token honest per-step measurements)
        let next = session.advance(&logits, cache.seq_len(), self.ctx.cfg.t_max);
        match next {
            Some(next) => active.push(ActiveGen {
                reply: req.reply,
                enqueued: req.enqueued,
                session,
                cache,
                next,
                prefill_s,
                decode_s: 0.0,
            }),
            None => {
                self.metrics
                    .queue_ns
                    .fetch_add(req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let finish = session.finish().expect("finished session");
                let _ = req.reply.send(Ok(Generated {
                    tokens: session.into_tokens(),
                    finish,
                    prefill_s,
                    decode_s: 0.0,
                }));
            }
        }
    }

    /// One **batched** decode step advancing every active sequence by one
    /// token (`ModelContext::decode_batch`: shared projection GEMMs,
    /// per-expert grouped SwiGLU across sequences); finished sequences are
    /// answered and leave the batch immediately. Each sequence's reported
    /// `decode_s` is its equal share of the batched step wall-clock.
    ///
    /// If the batched call itself fails, fall back to per-sequence decode
    /// so a single poisoned sequence is evicted with its error instead of
    /// failing the whole batch.
    fn step(&self, active: &mut Vec<ActiveGen>) {
        let bsz = active.len();
        let tokens: Vec<i32> = active.iter().map(|a| a.next).collect();
        let t0 = Instant::now();
        let rows = {
            let mut caches: Vec<&mut dyn KvCache> =
                active.iter_mut().map(|a| a.cache.as_mut()).collect();
            self.ctx.decode_batch(&self.model, &mut caches, &tokens)
        };
        let rows = match rows {
            Ok(rows) => rows,
            Err(_) => return self.step_sequential(active),
        };
        let dt = t0.elapsed();
        self.metrics.decode_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.metrics.gen_tokens.fetch_add(bsz as u64, Ordering::Relaxed);
        let share = dt.as_secs_f64() / bsz as f64;
        for (mut a, logits) in std::mem::take(active).into_iter().zip(rows) {
            a.decode_s += share;
            match a.session.advance(&logits, a.cache.seq_len(), self.ctx.cfg.t_max) {
                Some(next) => {
                    a.next = next;
                    active.push(a);
                }
                None => self.finish_gen(a),
            }
        }
    }

    /// Per-sequence decode fallback: only reached when the batched step
    /// errors, to isolate and evict the offending sequence while the rest
    /// keep decoding.
    fn step_sequential(&self, active: &mut Vec<ActiveGen>) {
        self.metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        let mut i = 0;
        while i < active.len() {
            let a = &mut active[i];
            let t0 = Instant::now();
            let logits = match self.ctx.decode(&self.model, a.cache.as_mut(), a.next) {
                Ok(l) => l,
                Err(e) => {
                    let a = active.swap_remove(i);
                    let _ = a.reply.send(Err(e));
                    continue;
                }
            };
            let dt = t0.elapsed();
            a.decode_s += dt.as_secs_f64();
            self.metrics.decode_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
            self.metrics.gen_tokens.fetch_add(1, Ordering::Relaxed);
            match a.session.advance(&logits, a.cache.seq_len(), self.ctx.cfg.t_max) {
                Some(next) => {
                    a.next = next;
                    i += 1;
                }
                None => {
                    let a = active.swap_remove(i);
                    self.finish_gen(a);
                }
            }
        }
    }

    /// Answer one finished generation and record its queue latency.
    fn finish_gen(&self, a: ActiveGen) {
        self.metrics
            .queue_ns
            .fetch_add(a.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let finish = a.session.finish().expect("finished session");
        let _ = a.reply.send(Ok(Generated {
            tokens: a.session.into_tokens(),
            finish,
            prefill_s: a.prefill_s,
            decode_s: a.decode_s,
        }));
    }

    /// Execute the queued score rows as full batches and deliver finished
    /// requests.
    fn flush(
        &self,
        pendings: &mut Vec<Pending>,
        queue: &mut Vec<(usize, usize, RowSpec)>,
    ) -> Result<()> {
        let (bsz, t) = (self.bsz, self.t);
        while !queue.is_empty() {
            let take = queue.len().min(bsz);
            let chunk: Vec<_> = queue.drain(..take).collect();
            let mut ids = vec![crate::data::vocab::PAD; bsz * t];
            for (bi, (_, _, row)) in chunk.iter().enumerate() {
                for (p, &tok) in row.seq.iter().enumerate().take(t) {
                    ids[bi * t + p] = tok;
                }
            }
            let t0 = Instant::now();
            let logits = self.ctx.run_logits(&self.model, &ids)?;
            self.metrics
                .busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.metrics.batches.fetch_add(1, Ordering::Relaxed);
            let v = logits.shape()[2];
            let ld = logits.data();
            for (bi, (pi, ri, row)) in chunk.iter().enumerate() {
                let mut lp = 0f64;
                // Position 0 has no conditioning context (there is no
                // logits row at -1): an empty-prompt row starts scoring
                // at position 1. Guards the `pos - 1` underflow that
                // panicked the executor on `start == 0` rows.
                for pos in row.start.max(1)..row.end.min(t) {
                    let lrow = &ld[(bi * t + pos - 1) * v..(bi * t + pos) * v];
                    lp += log_softmax_at(lrow, row.seq[pos] as usize);
                }
                // normalise by the number of positions actually scored
                // (start==0 skips position 0, so the divisor must too)
                lp /= (row.end.saturating_sub(row.start.max(1))).max(1) as f64;
                let p = &mut pendings[*pi];
                p.scores[*ri] = lp;
                p.remaining -= 1;
            }
        }
        // deliver finished requests
        for p in pendings.iter_mut() {
            if p.remaining == 0 {
                let scores = std::mem::take(&mut p.scores);
                self.metrics
                    .queue_ns
                    .fetch_add(p.req.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = p.req.reply.send(scores);
            }
        }
        pendings.retain(|p| p.remaining > 0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_snapshot_math() {
        let m = Metrics::default();
        m.rows.store(64, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.busy_ns.store(2_000_000_000, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.rows_per_sec(), 32.0);
        assert_eq!(s.mean_batch_fill(32), 1.0);
    }

    #[test]
    fn generation_metrics_math() {
        let m = Metrics::default();
        m.gen_requests.store(4, Ordering::Relaxed);
        m.gen_tokens.store(100, Ordering::Relaxed);
        m.decode_ns.store(500_000_000, Ordering::Relaxed); // 0.5 s
        m.prefill_tokens.store(40, Ordering::Relaxed);
        m.prefill_ns.store(10_000_000, Ordering::Relaxed); // 10 ms
        let s = m.snapshot();
        assert_eq!(s.decode_tok_s(), 200.0);
        assert_eq!(s.ms_per_token(), 5.0);
        assert_eq!(s.prefill_tok_s(), 4000.0);
        // empty counters stay well-defined
        let z = Metrics::default().snapshot();
        assert_eq!(z.decode_tok_s(), 0.0);
        assert_eq!(z.ms_per_token(), 0.0);
    }

    #[test]
    fn rowspec_construction() {
        let prompt = [1, 2, 3];
        let choices = vec![vec![7], vec![8, 9]];
        let rows: Vec<RowSpec> = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        assert_eq!(rows[0].end, 4);
        assert_eq!(rows[1].end, 5);
        assert_eq!(rows[1].start, 3);
    }
}
