//! SLO-aware scheduling state for the serving executor: priority
//! classes, the per-class admission queues, chunked-prefill progress,
//! swapped-out (preempted) sequences and the inter-token latency
//! histogram.
//!
//! The policy these types implement (see `SERVING.md` §"Scheduler"):
//!
//! * Two priority classes — [`Priority::Interactive`] (default) and
//!   [`Priority::Batch`]. Admission is FIFO **within** a class and
//!   strict-priority **across** classes: a queued Interactive request is
//!   always admitted before any queued Batch request (no priority
//!   inversion), and an Interactive arrival that cannot reserve its
//!   worst-case KV blocks preempts Batch work to make room.
//! * Prefills run in **chunks** of at most `HCSMOE_PREFILL_CHUNK` prompt
//!   tokens between consecutive decode steps ([`PrefillInFlight`] tracks
//!   the progress), so a long prompt cannot stall in-flight decodes for
//!   more than one chunk's worth of compute.
//! * Preemption is swap-out-by-recompute: the victim's KV cache is
//!   dropped (every pool block and the remaining reservation return
//!   instantly), and [`PreemptedGen`] retains the token prefix needed to
//!   rebuild it by chunked re-prefill when capacity frees up. Resumed
//!   streams are bit-identical to uninterrupted ones — re-prefill
//!   reconstructs the exact cache contents (the
//!   [`crate::backend::Backend::run_prefill`] chunk contract) and the
//!   [`Session`] carries the sampling state across the swap.
//! * Deadlines are SLO *accounting*, not reordering: a request finishing
//!   after its deadline bumps the `deadline_misses` counter; scheduling
//!   order stays FIFO-within-class so deadline choices can never starve
//!   anyone.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::KvCache;
use crate::generate::{Generated, Session};
use crate::variant::Variant;

use super::dispatch::Lease;
use super::{GenerateRequest, Metrics, ReplyTx};

/// Scheduling class of a generation request. Interactive traffic is
/// latency-sensitive (admitted first, never preempted); Batch traffic is
/// throughput work that yields capacity to Interactive arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive (the default): admitted ahead of Batch, never
    /// preempted.
    #[default]
    Interactive,
    /// Throughput work: admitted only when no Interactive request waits,
    /// and preempted (swapped out) when an Interactive arrival cannot
    /// reserve its KV blocks.
    Batch,
}

/// A generation waiting for (re-)admission.
pub(crate) enum Queued {
    /// Accepted but not yet prefilled.
    Fresh(GenerateRequest),
    /// Swapped out by a preemption; resumes by re-prefilling its
    /// retained token prefix.
    Resume(PreemptedGen),
}

impl Queued {
    pub(crate) fn class(&self) -> Priority {
        match self {
            Queued::Fresh(r) => r.class,
            Queued::Resume(p) => p.class,
        }
    }

    /// The request's speculative draft depth (`None` = plain decoding).
    /// A preempted sequence keeps its depth across the swap, so resume
    /// rebuilds the same full/drafter cache pair it was admitted with.
    pub(crate) fn draft_k(&self) -> Option<usize> {
        match self {
            Queued::Fresh(r) => r.draft_k,
            Queued::Resume(p) => p.draft_k,
        }
    }

    pub(crate) fn reply(&self) -> &ReplyTx<Result<Generated>> {
        match self {
            Queued::Fresh(r) => &r.reply,
            Queued::Resume(p) => &p.reply,
        }
    }

    /// Answer this request with an error (the drain / reject path).
    pub(crate) fn send_err(self, e: anyhow::Error) {
        let _ = self.reply().send(Err(e));
    }
}

/// Per-class FIFO admission queues. Strict priority across classes:
/// every head/pop consults Interactive first, so a Batch request can
/// never be admitted while an Interactive one waits.
#[derive(Default)]
pub(crate) struct SchedQueues {
    interactive: VecDeque<Queued>,
    batch: VecDeque<Queued>,
}

impl SchedQueues {
    fn lane(&mut self, class: Priority) -> &mut VecDeque<Queued> {
        match class {
            Priority::Interactive => &mut self.interactive,
            Priority::Batch => &mut self.batch,
        }
    }

    /// Enqueue at the back of the request's class lane (arrival order).
    pub(crate) fn push_back(&mut self, q: Queued) {
        self.lane(q.class()).push_back(q);
    }

    /// Re-enqueue at the *front* of the class lane — a preempted victim
    /// resumes before anything that arrived after it (FIFO is preserved
    /// under preemption).
    pub(crate) fn push_front(&mut self, q: Queued) {
        self.lane(q.class()).push_front(q);
    }

    /// Head of one class lane.
    pub(crate) fn front(&self, class: Priority) -> Option<&Queued> {
        match class {
            Priority::Interactive => self.interactive.front(),
            Priority::Batch => self.batch.front(),
        }
    }

    /// Pop the head of one class lane.
    pub(crate) fn pop(&mut self, class: Priority) -> Option<Queued> {
        self.lane(class).pop_front()
    }

    pub(crate) fn has(&self, class: Priority) -> bool {
        self.front(class).is_some()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.batch.is_empty()
    }

    /// Drop queued requests whose client vanished (closed reply channel),
    /// counting them into `gen_disconnects`.
    pub(crate) fn retain_connected(&mut self, metrics: &Metrics) {
        for lane in [&mut self.interactive, &mut self.batch] {
            lane.retain(|q| {
                let gone = q.reply().is_closed();
                if gone {
                    metrics.gen_disconnects.fetch_add(1, Ordering::Relaxed);
                }
                !gone
            });
        }
    }

    /// Take every queued request (the shutdown drain).
    pub(crate) fn drain_all(&mut self) -> Vec<Queued> {
        self.interactive.drain(..).chain(self.batch.drain(..)).collect()
    }
}

/// A prefill in progress, possibly spanning several chunks with decode
/// steps interleaved between them. Holds the sequence's partially built
/// KV cache (and therefore its block reservation); dropping the whole
/// struct — e.g. when a Batch prefill is preempted — releases every
/// block back to the pool.
pub(crate) struct PrefillInFlight {
    /// The request being prefilled (Fresh) or rebuilt (Resume).
    pub(crate) seq: Queued,
    /// The model variant this prefill is bound to (pinned at admission —
    /// or inherited across a preemption): every chunk runs on it, even if
    /// a hot swap lands between chunks, so the finished cache is
    /// internally consistent and carries this variant's KV fingerprint.
    pub(crate) variant: Arc<Variant>,
    /// The cache under construction; `None` until the first chunk ran.
    pub(crate) cache: Option<Box<dyn KvCache>>,
    /// For a speculative request: the drafter's cache, built chunk by
    /// chunk in lockstep with the full-model one (both reservations are
    /// claimed by the FIRST chunk, so the admission check's 2× block
    /// bound is secured before any later admission runs).
    pub(crate) draft_cache: Option<Box<dyn KvCache>>,
    /// Prompt tokens prefilled so far.
    pub(crate) done: usize,
    /// Chunks executed so far.
    pub(crate) chunks: usize,
    /// Prefill wall-clock accumulated across this attempt's chunks.
    pub(crate) prefill_s: f64,
}

impl PrefillInFlight {
    pub(crate) fn new(seq: Queued, variant: Arc<Variant>) -> Self {
        Self {
            seq,
            variant,
            cache: None,
            draft_cache: None,
            done: 0,
            chunks: 0,
            prefill_s: 0.0,
        }
    }

    /// The full token sequence this prefill must feed: the prompt for a
    /// fresh request, the retained fed-token prefix for a resume.
    pub(crate) fn tokens(&self) -> &[i32] {
        match &self.seq {
            Queued::Fresh(r) => &r.prompt,
            Queued::Resume(p) => &p.resident,
        }
    }

    pub(crate) fn reply(&self) -> &ReplyTx<Result<Generated>> {
        self.seq.reply()
    }
}

/// A speculative sequence's drafter side: the compact variant's own KV
/// cache plus the per-round draft depth. Lives inside [`ActiveGen`];
/// dropping it (eviction, preemption, finish) releases the drafter's
/// blocks exactly like the full-model cache's.
pub(crate) struct DraftSeq {
    /// The compact drafter's KV cache, kept in lockstep with the
    /// verifier's (same sequence length at every step boundary).
    pub(crate) cache: Box<dyn KvCache>,
    /// Most tokens proposed per verify round (the request's `draft_k`).
    pub(crate) k: usize,
}

/// One generation sequence in the continuous decode batch.
pub(crate) struct ActiveGen {
    pub(crate) reply: ReplyTx<Result<Generated>>,
    pub(crate) enqueued: Instant,
    pub(crate) class: Priority,
    pub(crate) deadline: Option<Duration>,
    /// The original prompt — kept so a preemption can reconstruct the
    /// fed-token prefix (prompt ++ generated-and-fed tokens) to
    /// re-prefill from.
    pub(crate) prompt: Vec<i32>,
    /// The worst-case token reservation this sequence was admitted under
    /// (prompt + max_new_tokens, clamped to `t_max`) — reused verbatim
    /// when a preempted sequence re-reserves, so resume can never demand
    /// more than original admission did.
    pub(crate) reserve_tokens: usize,
    pub(crate) session: Session,
    /// The model variant this sequence decodes on, pinned for its whole
    /// life: an in-flight sequence finishes on the variant it started on
    /// — hot swaps only redirect *new* admissions — which keeps its
    /// stream bit-identical to an uninterrupted offline run and its KV
    /// fingerprint consistent. The pin also keeps the (possibly retired)
    /// variant's weights resident until the sequence finishes.
    pub(crate) variant: Arc<Variant>,
    pub(crate) cache: Box<dyn KvCache>,
    /// Speculative state (`None` = plain decoding): the drafter-side
    /// cache and draft depth.
    pub(crate) draft: Option<DraftSeq>,
    /// Sampled but not yet fed to the model.
    pub(crate) next: i32,
    /// When this sequence last emitted a token (admission or previous
    /// decode step) — inter-token latency is recorded against it.
    pub(crate) last_emit: Instant,
    pub(crate) prefill_s: f64,
    pub(crate) decode_s: f64,
    /// Live per-token stream (`None` = reply-only request): every newly
    /// committed token is pushed here the moment its decode step lands.
    /// A preemption carries the stream across the swap and `streamed`
    /// guarantees resume never re-emits.
    pub(crate) stream: Option<ReplyTx<i32>>,
    /// Tokens already pushed to `stream` (== `session.tokens()` prefix).
    pub(crate) streamed: usize,
    /// Dispatcher occupancy lease (`None` when submitted directly to a
    /// [`super::ServerHandle`]): dropping the sequence on ANY terminal
    /// path — reply, error, disconnect eviction, shutdown drain —
    /// releases the replica's committed-block estimate automatically.
    pub(crate) lease: Option<Lease>,
}

impl ActiveGen {
    /// Swap this sequence out: drop its KV cache — every pool block and
    /// the remaining reservation release immediately — and retain the
    /// exact token prefix the model has consumed, for recompute on
    /// resume. `session.tokens()` ends with the sampled-but-unfed
    /// `next`, which must NOT be re-prefilled: it is fed on the first
    /// decode step after resume, exactly as it would have been without
    /// the preemption (bit-identity of the resumed stream).
    pub(crate) fn preempt(self) -> PreemptedGen {
        let fed = self.session.tokens().len() - 1;
        let mut resident = self.prompt.clone();
        resident.extend_from_slice(&self.session.tokens()[..fed]);
        PreemptedGen {
            reply: self.reply,
            enqueued: self.enqueued,
            class: self.class,
            deadline: self.deadline,
            prompt: self.prompt,
            resident,
            reserve_tokens: self.reserve_tokens,
            session: self.session,
            variant: self.variant,
            draft_k: self.draft.as_ref().map(|d| d.k),
            next: self.next,
            prefill_s: self.prefill_s,
            decode_s: self.decode_s,
            stream: self.stream,
            streamed: self.streamed,
            lease: self.lease,
        } // self.cache (and self.draft's cache) drop here, releasing
          // every block of the pair
    }
}

/// A sequence swapped out of the pool: everything needed to resume it
/// bit-identically once capacity frees up — the [`Session`] (sampling
/// state, RNG, stop conditions), the outstanding sampled token, and the
/// fed-token prefix whose chunked re-prefill rebuilds the KV cache.
pub(crate) struct PreemptedGen {
    pub(crate) reply: ReplyTx<Result<Generated>>,
    pub(crate) enqueued: Instant,
    pub(crate) class: Priority,
    pub(crate) deadline: Option<Duration>,
    /// Original prompt (restored into the resumed [`ActiveGen`]).
    pub(crate) prompt: Vec<i32>,
    /// Every token the model had consumed: prompt ++ fed generations.
    /// Re-prefilling exactly this rebuilds the dropped cache.
    pub(crate) resident: Vec<i32>,
    /// The admission-time reservation bound (see
    /// [`ActiveGen::reserve_tokens`]).
    pub(crate) reserve_tokens: usize,
    pub(crate) session: Session,
    /// The variant pin carried across the swap-out: the resume re-prefill
    /// and all further decoding run on the variant the stream started on,
    /// even if a hot swap happened while it was preempted — mixing
    /// variants mid-stream would break the bit-identity contract.
    pub(crate) variant: Arc<Variant>,
    /// The draft depth of a speculative sequence (`None` = plain).
    /// Resume rebuilds the drafter cache over `resident` alongside the
    /// full-model one.
    pub(crate) draft_k: Option<usize>,
    /// Sampled but not yet fed when the preemption hit.
    pub(crate) next: i32,
    pub(crate) prefill_s: f64,
    pub(crate) decode_s: f64,
    /// Live per-token stream carried across the swap-out (see
    /// [`ActiveGen::stream`]); `streamed` marks where resume picks up, so
    /// the client never sees a token twice.
    pub(crate) stream: Option<ReplyTx<i32>>,
    pub(crate) streamed: usize,
    /// Dispatcher occupancy lease carried across the swap-out: the
    /// replica's committed estimate stays charged while the sequence is
    /// parked — its reservation claim returns the moment it resumes.
    pub(crate) lease: Option<Lease>,
}

/// Bucket count of [`LatencyHisto`]: 16 exact sub-16 ns buckets plus
/// 16 sub-buckets per power of two up to 2^63 — index 975 at most.
const HISTO_BUCKETS: usize = 1024;

/// A lock-free log-linear latency histogram (HdrHistogram-style):
/// nanosecond samples land in one of [`HISTO_BUCKETS`] buckets — exact
/// below 16 ns, then 16 sub-buckets per power of two, giving a worst-case
/// quantile error of ~6% across the full `u64` range. Recording is one
/// relaxed atomic increment, so the executor's decode hot path can feed
/// it without locks; readers take quantiles concurrently.
///
/// The bucket mapping is monotone in the sample value, so comparing the
/// same quantile of two histograms (e.g. chunked vs unchunked
/// inter-token latency in the `sched_sweep` bench) is bucketisation-safe:
/// if every chunked sample is below every unchunked one, the reported
/// quantiles preserve that order.
pub struct LatencyHisto {
    buckets: Vec<AtomicU64>,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self { buckets: (0..HISTO_BUCKETS).map(|_| AtomicU64::new(0)).collect() }
    }
}

impl LatencyHisto {
    /// Bucket index of a nanosecond sample.
    fn index(ns: u64) -> usize {
        if ns < 16 {
            return ns as usize;
        }
        let exp = 63 - u64::from(ns.leading_zeros()); // >= 4
        let sub = (ns >> (exp - 4)) & 0xF; // top 4 mantissa bits
        ((exp - 3) * 16 + sub) as usize
    }

    /// Upper bound (ns) of a bucket — quantiles report this, so they
    /// over- rather than under-state latency.
    fn upper_ns(idx: usize) -> u64 {
        if idx < 16 {
            return idx as u64;
        }
        let exp = (idx / 16 + 3) as u32;
        let sub = (idx % 16) as u64;
        let width = 1u64 << (exp - 4);
        (1u64 << exp) + sub * width + (width - 1)
    }

    /// Record one nanosecond sample.
    pub fn record(&self, ns: u64) {
        self.buckets[Self::index(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The `q`-quantile (`q` in `[0, 1]`) in **milliseconds**; `0.0` when
    /// no samples were recorded. Reported as the matched bucket's upper
    /// bound.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return Self::upper_ns(i) as f64 / 1e6;
            }
        }
        Self::upper_ns(HISTO_BUCKETS - 1) as f64 / 1e6
    }

    /// The `q`-quantile over the **union** of several histograms' samples
    /// (bucket-level sums — buckets share one mapping, so the union
    /// histogram is exact, not an approximation of an approximation).
    /// This is how [`super::Metrics::merged`] reports fleet-wide
    /// inter-token latency: averaging per-replica quantiles would be
    /// statistically meaningless, merging the buckets is not.
    pub fn quantile_ms_across(histos: &[&LatencyHisto], q: f64) -> f64 {
        let total: u64 = histos.iter().map(|h| h.count()).sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for i in 0..HISTO_BUCKETS {
            cum += histos.iter().map(|h| h.buckets[i].load(Ordering::Relaxed)).sum::<u64>();
            if cum >= rank {
                return Self::upper_ns(i) as f64 / 1e6;
            }
        }
        Self::upper_ns(HISTO_BUCKETS - 1) as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_buckets_are_monotone_and_bounded() {
        // index is monotone non-decreasing in the sample, and every
        // sample lands at or below its bucket's upper bound
        let mut prev = 0usize;
        for shift in 0..64u32 {
            for delta in [0u64, 1, 3] {
                let ns = (1u64 << shift).saturating_add(delta);
                let idx = LatencyHisto::index(ns);
                assert!(idx >= prev || ns < (1u64 << shift), "non-monotone at {ns}");
                assert!(idx < HISTO_BUCKETS, "index {idx} out of range");
                assert!(
                    LatencyHisto::upper_ns(idx) >= ns || shift == 63,
                    "upper bound below sample at {ns}"
                );
                prev = idx;
            }
            prev = LatencyHisto::index(1u64 << shift);
        }
        // exact below 16
        for ns in 0..16u64 {
            assert_eq!(LatencyHisto::index(ns), ns as usize);
            assert_eq!(LatencyHisto::upper_ns(ns as usize), ns);
        }
    }

    #[test]
    fn histo_quantiles() {
        let h = LatencyHisto::default();
        assert_eq!(h.quantile_ms(0.5), 0.0); // empty
        // 100 samples at 1 ms, 1 sample at ~16 ms: p50 ~1 ms, p99 ~1 ms,
        // p100 ~16 ms (bucket upper bounds, <= ~6% over)
        for _ in 0..100 {
            h.record(1_000_000);
        }
        h.record(16_000_000);
        let p50 = h.quantile_ms(0.5);
        let p99 = h.quantile_ms(0.99);
        let p100 = h.quantile_ms(1.0);
        assert!((1.0..1.1).contains(&p50), "p50 {p50}");
        assert!((1.0..1.1).contains(&p99), "p99 {p99}");
        assert!((16.0..17.1).contains(&p100), "p100 {p100}");
        assert_eq!(h.count(), 101);
        // ordering under bucketisation: strictly larger samples can never
        // report a smaller quantile
        let lo = LatencyHisto::default();
        let hi = LatencyHisto::default();
        for i in 0..50u64 {
            lo.record(500_000 + i * 1_000);
            hi.record(5_000_000 + i * 10_000);
        }
        assert!(lo.quantile_ms(0.99) <= hi.quantile_ms(0.99));
    }

    #[test]
    fn priority_default_is_interactive() {
        assert_eq!(Priority::default(), Priority::Interactive);
    }

    #[test]
    fn histo_union_quantiles_merge_buckets() {
        // two replicas: one fast (1 ms), one slow (8 ms). The union p50
        // sits in the fast bucket (100 of 150 samples), the union p99 in
        // the slow one — neither replica alone reports both.
        let fast = LatencyHisto::default();
        let slow = LatencyHisto::default();
        for _ in 0..100 {
            fast.record(1_000_000);
        }
        for _ in 0..50 {
            slow.record(8_000_000);
        }
        let both = [&fast, &slow];
        let p50 = LatencyHisto::quantile_ms_across(&both, 0.50);
        let p99 = LatencyHisto::quantile_ms_across(&both, 0.99);
        assert!((1.0..1.1).contains(&p50), "p50 {p50}");
        assert!((8.0..8.6).contains(&p99), "p99 {p99}");
        // degenerate inputs stay well-defined
        assert_eq!(LatencyHisto::quantile_ms_across(&[], 0.5), 0.0);
        let single = LatencyHisto::quantile_ms_across(&[&fast], 0.5);
        assert_eq!(single, fast.quantile_ms(0.5));
    }
}
