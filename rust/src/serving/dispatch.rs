//! Replica scale-out: N independent executors behind one dispatcher.
//!
//! The continuous-batching executor is deliberately single-threaded —
//! PJRT handles are not `Send`, and one thread owning all execution
//! state is what makes hot swaps and preemption race-free. Scaling
//! therefore happens *outside* the executor: the [`Dispatcher`] launches
//! `N` full replicas (each with its own [`super::executor_loop`] thread,
//! `ModelContext`, variant registry, and paged KV pool) and places every
//! generation on exactly one of them. HC-SMoE is what makes this cheap:
//! a merged r-expert variant's resident weights shrink by `r / n_expert`,
//! so several replicas fit where one uncompressed model did.
//!
//! Placement is **admission-aware** and **prefix-affine**:
//!
//! 1. Estimate the request's worst-case KV footprint in pool blocks
//!    (`ceil((prompt + max_new) / block_tokens)`, doubled for
//!    speculative pairs — the same bound each executor's admission
//!    control reserves).
//! 2. If the prompt spans at least one full block, hash that first
//!    block and look it up in the affinity map: requests sharing a
//!    prefix land on the replica that already holds its KV blocks, so
//!    cross-request prefix sharing keeps working under scale-out
//!    (blocks are per-pool; a prefix cached on replica 0 is invisible
//!    to replica 1).
//! 3. Honour the affinity only while that replica has headroom
//!    (committed + estimate ≤ its pool capacity); otherwise spill to
//!    the least-committed replica (ties → lowest index, keeping
//!    placement deterministic) and move the affinity there — the
//!    prefix's blocks will be rebuilt where traffic now flows.
//!
//! "Committed" is tracked by RAII [`Lease`]s attached to each dispatched
//! request: every terminal path through the scheduler — normal finish,
//! error reply, disconnect eviction, shutdown drain — drops the request
//! state and with it the lease, so the dispatcher's occupancy view can
//! never leak. Placement is a *best-effort estimate*; the per-executor
//! admission queue remains the real gate (an over-placed request waits
//! there, it is never lost).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::{
    BatcherConfig, GenerateRequest, Generated, Metrics, MetricsSnapshot, Priority, ReplyRx,
    Request, RowSpec, ScoreRequest, ServeSpec, ServerHandle,
};
use crate::generate::SamplingParams;
use crate::kvpool::DEFAULT_BLOCK_TOKENS;

/// RAII occupancy lease: `acquire` adds the request's estimated block
/// footprint to its replica's committed counter, `Drop` subtracts it.
/// The lease travels inside the [`GenerateRequest`] through every
/// scheduler state (queued → prefilling → active → preempted →
/// finished), so whichever path retires the request — reply, error,
/// disconnect eviction, shutdown drain — releases the blocks without
/// any explicit bookkeeping call.
pub(crate) struct Lease {
    counter: Arc<AtomicU64>,
    blocks: u64,
}

impl Lease {
    fn acquire(counter: &Arc<AtomicU64>, blocks: u64) -> Self {
        counter.fetch_add(blocks, Ordering::Relaxed);
        Self { counter: Arc::clone(counter), blocks }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        self.counter.fetch_sub(self.blocks, Ordering::Relaxed);
    }
}

/// Pure placement decision: honour `affinity` while it has headroom,
/// else the least-committed replica (ties → lowest index). `totals[i]`
/// of 0 means "capacity unknown" (the replica's executor has not
/// published its pool size yet) and always fits — placement degrades to
/// load balancing, never to rejection.
fn pick_replica(committed: &[u64], totals: &[u64], affinity: Option<usize>, est: u64) -> usize {
    let fits = |i: usize| totals[i] == 0 || committed[i] + est <= totals[i];
    if let Some(i) = affinity {
        if i < committed.len() && fits(i) {
            return i;
        }
    }
    committed
        .iter()
        .enumerate()
        .min_by_key(|&(_, &c)| c)
        .map(|(i, _)| i)
        .expect("dispatcher has at least one replica")
}

/// Client-side handle to a fleet of serving replicas. See the module
/// docs for the placement policy. All methods take `&self` so the
/// dispatcher can be shared behind an `Arc` (the HTTP front end in
/// [`super::net`] serves every connection off one dispatcher).
pub struct Dispatcher {
    /// The replica handles; emptied by [`Self::shutdown`]. Only
    /// shutdown locks this — submissions go through `senders`.
    replicas: Mutex<Vec<ServerHandle>>,
    /// Cloned submission channels, one per replica (cleared on
    /// shutdown so new submissions fail fast). Kept under a mutex and
    /// cloned out per call: the lock is never held across a blocking
    /// send or recv.
    senders: Mutex<Vec<Sender<Request>>>,
    /// Per-replica live counters (same `Arc`s the executors update).
    metrics: Vec<Arc<Metrics>>,
    /// Per-replica committed KV blocks (lease-tracked estimates).
    committed: Vec<Arc<AtomicU64>>,
    /// First-block prompt hash → replica index.
    affinity: Mutex<HashMap<u64, usize>>,
    /// Tokens per KV pool block (the affinity prefix length and the
    /// block-estimate divisor).
    block_tokens: usize,
    /// Round-robin cursor for stateless score traffic.
    rr: AtomicU64,
}

impl Dispatcher {
    /// Launch `n` replicas of `spec` (each its own executor thread with
    /// a private model context, variant registry, and KV pool). `None`
    /// resolves `HCSMOE_REPLICAS` (default 1 — exactly the old
    /// single-executor [`super::serve`]). Zero is a startup error.
    pub fn launch(spec: ServeSpec, batcher: BatcherConfig, n: Option<usize>) -> Result<Self> {
        let n = crate::config::env::replicas(n)?;
        let mut replicas = Vec::with_capacity(n);
        let mut senders = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        for _ in 0..n {
            let h = super::serve(spec.clone(), batcher.clone())?;
            senders.push(h.sender());
            metrics.push(Arc::clone(&h.metrics));
            replicas.push(h);
        }
        Ok(Self {
            replicas: Mutex::new(replicas),
            senders: Mutex::new(senders),
            metrics,
            committed: (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            affinity: Mutex::new(HashMap::new()),
            block_tokens: DEFAULT_BLOCK_TOKENS,
            rr: AtomicU64::new(0),
        })
    }

    /// Number of replicas launched (fixed for the dispatcher's life).
    pub fn replica_count(&self) -> usize {
        self.metrics.len()
    }

    /// Worst-case KV pool blocks this request can occupy — the same
    /// bound the target executor's admission control will reserve
    /// (speculative pairs hold BOTH caches, hence 2×).
    fn est_blocks(&self, req: &GenerateRequest) -> u64 {
        let tokens = req.prompt.len() + req.params.max_new_tokens;
        let blocks = ((tokens + self.block_tokens - 1) / self.block_tokens) as u64;
        if req.draft_k.is_some() {
            blocks * 2
        } else {
            blocks
        }
    }

    /// Affinity key: hash of the prompt's first pool block. Prompts
    /// shorter than one block can't share KV blocks anyway (sharing is
    /// whole-block), so they carry no affinity.
    fn affinity_key(&self, prompt: &[i32]) -> Option<u64> {
        if prompt.len() < self.block_tokens {
            return None;
        }
        let mut h = DefaultHasher::new();
        prompt[..self.block_tokens].hash(&mut h);
        Some(h.finish())
    }

    /// Place one request: (replica index, estimated blocks).
    fn place(&self, req: &GenerateRequest) -> (usize, u64) {
        let est = self.est_blocks(req);
        let committed: Vec<u64> =
            self.committed.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let totals: Vec<u64> = self
            .metrics
            .iter()
            .map(|m| m.kv_blocks_total.load(Ordering::Relaxed))
            .collect();
        let key = self.affinity_key(&req.prompt);
        let mut aff = self.affinity.lock().expect("dispatcher poisoned");
        let hint = key.and_then(|k| aff.get(&k).copied());
        let idx = pick_replica(&committed, &totals, hint, est);
        if let Some(k) = key {
            // first sight OR over-commit spill: the prefix now lives
            // (or will be rebuilt) on `idx`
            aff.insert(k, idx);
        }
        (idx, est)
    }

    /// Submit a built [`GenerateRequest`] without blocking: place it,
    /// attach its occupancy [`Lease`], and hand it to the chosen
    /// replica. Returns the replica index (tests pin placement through
    /// it) and the private reply receiver (`None` after
    /// [`GenerateRequest::reply_to`]).
    pub fn submit(
        &self,
        mut req: GenerateRequest,
    ) -> Result<(usize, Option<ReplyRx<Result<Generated>>>)> {
        let (idx, est) = self.place(&req);
        req.lease = Some(Lease::acquire(&self.committed[idx], est));
        let rx = req.rx.take();
        let tx = {
            let senders = self.senders.lock().expect("dispatcher poisoned");
            senders.get(idx).cloned().ok_or_else(|| anyhow!("dispatcher stopped"))?
        };
        tx.send(Request::Generate(req)).map_err(|_| anyhow!("replica {idx} stopped"))?;
        Ok((idx, rx))
    }

    /// Blocking generation with default scheduling — the dispatcher
    /// counterpart of [`ServerHandle::generate`], bit-identical to it
    /// (and to offline [`crate::generate::generate`]) for seeded
    /// sampling: placement only chooses *where* the same `Session`
    /// loop runs.
    pub fn generate(&self, prompt: &[i32], params: SamplingParams) -> Result<Generated> {
        self.generate_opts(prompt, params, Priority::Interactive, None)
    }

    /// [`Self::generate`] with explicit scheduling options.
    pub fn generate_opts(
        &self,
        prompt: &[i32],
        params: SamplingParams,
        class: Priority,
        deadline: Option<Duration>,
    ) -> Result<Generated> {
        let mut req = GenerateRequest::new(prompt, params).priority(class);
        if let Some(d) = deadline {
            req = req.deadline(d);
        }
        let (_, rx) = self.submit(req)?;
        rx.expect("a fresh request owns its receiver").recv()?
    }

    /// Score one multiple-choice item (blocking). Scoring is stateless
    /// (no KV cache), so placement is plain round-robin.
    pub fn score_item(&self, prompt: &[i32], choices: &[Vec<i32>]) -> Result<Vec<f64>> {
        let idx = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % self.metrics.len();
        let rows: Vec<RowSpec> = choices
            .iter()
            .map(|ch| {
                let mut seq = prompt.to_vec();
                seq.extend_from_slice(ch);
                RowSpec { seq: seq.clone(), start: prompt.len(), end: seq.len() }
            })
            .collect();
        let tx = {
            let senders = self.senders.lock().expect("dispatcher poisoned");
            senders.get(idx).cloned().ok_or_else(|| anyhow!("dispatcher stopped"))?
        };
        let (reply, rx) = channel();
        tx.send(Request::Score(ScoreRequest { rows, reply, enqueued: Instant::now() }))
            .map_err(|_| anyhow!("replica {idx} stopped"))?;
        Ok(rx.recv()?)
    }

    /// Per-replica metric snapshots, index-aligned with placement.
    pub fn metrics(&self) -> Vec<MetricsSnapshot> {
        self.metrics.iter().map(|m| m.snapshot()).collect()
    }

    /// Fleet-wide aggregate snapshot ([`Metrics::merged`]).
    pub fn merged(&self) -> MetricsSnapshot {
        let refs: Vec<&Metrics> = self.metrics.iter().map(Arc::as_ref).collect();
        Metrics::merged(&refs)
    }

    /// Blocks currently committed (lease-held) on replica `i` — the
    /// dispatcher's occupancy estimate, not the pool's own gauge.
    pub fn committed_blocks(&self, i: usize) -> u64 {
        self.committed[i].load(Ordering::Relaxed)
    }

    /// Stop every replica and join its executor thread. Each replica's
    /// shutdown answers all of its in-flight and queued generations
    /// (see [`ServerHandle::shutdown`]), so no dispatcher client blocks
    /// forever. `&self` (not `self`) so an `Arc`-shared dispatcher —
    /// the HTTP front end's case — can be drained; later submissions
    /// fail with "dispatcher stopped". Idempotent.
    pub fn shutdown(&self) -> Result<()> {
        self.senders.lock().expect("dispatcher poisoned").clear();
        let replicas: Vec<ServerHandle> =
            std::mem::take(&mut *self.replicas.lock().expect("dispatcher poisoned"));
        let mut first_err = None;
        for h in replicas {
            if let Err(e) = h.shutdown() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

impl Drop for Dispatcher {
    fn drop(&mut self) {
        // best-effort: a dispatcher dropped without an explicit
        // shutdown() still stops its executor threads
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_releases_on_drop() {
        let counter = Arc::new(AtomicU64::new(0));
        let a = Lease::acquire(&counter, 5);
        let b = Lease::acquire(&counter, 3);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
        drop(a);
        assert_eq!(counter.load(Ordering::Relaxed), 3);
        drop(b);
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pick_honours_affinity_with_headroom() {
        // replica 1 is busier but the affinity hint still fits
        assert_eq!(pick_replica(&[0, 10], &[100, 100], Some(1), 5), 1);
    }

    #[test]
    fn pick_spills_when_affinity_overcommitted() {
        // affinity replica 0 is full (committed + est > total): spill
        // to the least-committed of the rest
        assert_eq!(pick_replica(&[98, 40, 20], &[100, 100, 100], Some(0), 5), 2);
    }

    #[test]
    fn pick_least_committed_without_affinity() {
        assert_eq!(pick_replica(&[7, 3, 9], &[100, 100, 100], None, 1), 1);
    }

    #[test]
    fn pick_breaks_ties_toward_lowest_index() {
        assert_eq!(pick_replica(&[4, 4, 4], &[100, 100, 100], None, 1), 0);
    }

    #[test]
    fn unknown_capacity_always_fits() {
        // totals of 0 mean the executor has not published its pool size
        // yet — the affinity hint must still be honoured
        assert_eq!(pick_replica(&[1_000_000, 0], &[0, 0], Some(0), 64), 0);
    }
}
