//! # HC-SMoE — Retraining-Free Merging of Sparse MoE via Hierarchical Clustering
//!
//! A full-system reproduction of the ICML 2025 paper as a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! * **L1/L2** live in `python/compile/` and are AOT-lowered once to HLO
//!   text artifacts (`make artifacts`);
//! * **L3** is this crate: the retraining-free compression toolchain
//!   (calibration → similarity metrics → clustering → merging/pruning),
//!   the zero-shot evaluation harness, an autoregressive [`generate`]
//!   API with KV-cached decode backed by the budgeted paged [`kvpool`]
//!   (copy-on-write prefix sharing, memory-aware admission), a serving layer that mixes dynamic-batched
//!   scoring with continuous-batched generation (`SERVING.md`), and the
//!   bench harness regenerating every table/figure of the paper. Its hot
//!   paths run on the [`parallel`] scoped thread pool with deterministic
//!   work splitting — parallel and serial outputs are bit-identical
//!   (`rust/tests/determinism.rs`).
//!
//! Model execution goes through the [`backend`] abstraction: the default
//! **native** backend is a pure-Rust CPU interpreter of the simulated
//! SMoE family that runs straight from `.hcwt` weights (no Python, PJRT
//! or network anywhere in the loop — missing artifacts are synthesized
//! in-process by [`bench_support::ensure_artifacts`]), while
//! `HCSMOE_BACKEND=pjrt` selects the HLO/PJRT path.
//!
//! Quick tour:
//!
//! ```no_run
//! use hc_smoe::prelude::*;
//! use hc_smoe::{clustering::Linkage, merging::MergeStrategy, similarity::Metric};
//!
//! let arts = Artifacts::discover();
//! let ctx = ModelContext::load(&arts, "qwensim").unwrap();
//! let stats = ctx.calibrate("general").unwrap();
//! let plan = Pipeline::new(Method::HcSmoe { linkage: Linkage::Average,
//!                                           metric: Metric::ExpertOutput,
//!                                           merge: MergeStrategy::Frequency })
//!     .plan(&ctx, &stats, 8).unwrap();
//! let merged = plan.apply(&ctx, &stats).unwrap();
//! let model = merged.load(&ctx).unwrap();
//! let acc = Evaluator::new(&ctx).unwrap().accuracy(&model, "arc_e").unwrap();
//! println!("arc_e accuracy after 50% merge: {acc:.4}");
//! ```

#![warn(missing_docs)]

pub mod backend;
pub mod bench_support;
pub mod calib;
pub mod clustering;
pub mod config;
pub mod data;
pub mod eval;
pub mod generate;
pub mod kvpool;
pub mod merging;
pub mod model;
pub mod parallel;
pub mod pipeline;
pub mod pruning;
pub mod quality;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod similarity;
pub mod tensor;
pub mod util;
pub mod variant;
pub mod weights;

/// One-import surface for the common pipeline types (see the crate-level
/// quick tour).
pub mod prelude {
    pub use crate::backend::Backend;
    pub use crate::calib::{CalibStats, LayerStats};
    pub use crate::clustering::{Clustering, Linkage};
    pub use crate::config::{Artifacts, Manifest, ModelCfg};
    pub use crate::data::{Benchmark, MCItem, TokenStream};
    pub use crate::eval::Evaluator;
    pub use crate::generate::{generate, FinishReason, Generated, SamplingParams, Strategy};
    pub use crate::merging::MergeStrategy;
    pub use crate::model::ModelContext;
    pub use crate::pipeline::{Method, Pipeline, Plan};
    pub use crate::runtime::Runtime;
    pub use crate::similarity::Metric;
    pub use crate::tensor::Tensor;
    pub use crate::weights::Weights;
}

/// Crate version string (from `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
