//! A tiny property-test runner (proptest is unavailable offline).
//!
//! `check` runs a property over `n` seeded random cases and reports the
//! first failing seed so a failure is reproducible with a unit test.

use super::rng::Rng;

/// Run `prop` over `n` cases seeded from `base_seed`. Panics with the
/// failing case seed on the first failure.
pub fn check<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    base_seed: u64,
    n: usize,
    mut prop: F,
) {
    for case in 0..n {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x517CC1B727220A95);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Convenience assertion helpers returning Result for use inside `check`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> std::result::Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Relative-tolerance float comparison for use inside [`check`].
pub fn approx_eq(a: f32, b: f32, tol: f32, ctx: &str) -> std::result::Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("add-commutes", 1, 50, |rng| {
            let a = rng.next_f32();
            let b = rng.next_f32();
            approx_eq(a + b, b + a, 1e-6, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_bad_property() {
        check("always-false", 2, 5, |_| Err("nope".into()));
    }
}
