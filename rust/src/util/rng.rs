//! Deterministic xorshift64* RNG.
//!
//! Used everywhere randomness is needed (K-means-rnd init, O-prune subset
//! sampling, workload generation) so every experiment is reproducible from
//! its seed — mirroring the paper's emphasis on HC's determinism vs
//! K-means' initialisation sensitivity.

/// Deterministic xorshift64* generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (any seed, including 0, is valid).
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (reservoir-free; k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let i = r.below(10);
            assert!(i < 10);
        }
    }

    #[test]
    fn distinct_choice() {
        let mut r = Rng::new(9);
        let k = r.choose_distinct(10, 5);
        assert_eq!(k.len(), 5);
        let mut s = k.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
