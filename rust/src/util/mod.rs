//! Small self-contained utilities: deterministic RNG, key=value parsing,
//! timing helpers and a shrink-free property-test runner.
//!
//! The offline crate set has no `rand`/`criterion`/`proptest`, so these are
//! hand-rolled (see DESIGN.md "Offline-environment notes").

pub mod kv;
pub mod proptest;
pub mod rng;
pub mod timing;

pub use kv::KvFile;
pub use rng::Rng;
pub use timing::{bench_median, Timer};
