//! Minimal `key = value` file parser, the manifest/config interchange with
//! the Python build step (serde/toml are unavailable offline).
//!
//! Grammar: one `key = value` per line; `#` comments; blank lines ignored.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A parsed `key = value` file.
#[derive(Debug, Clone, Default)]
pub struct KvFile {
    map: BTreeMap<String, String>,
}

impl KvFile {
    /// Parse `key = value` lines (with `#` comments) from a string.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`: {line:?}", i + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { map })
    }

    /// Load and parse a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Raw string value for `key` (error when absent).
    pub fn get(&self, key: &str) -> Result<&str> {
        self.map
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Raw string value for `key`, or `default` when absent.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// `key` parsed as usize.
    pub fn usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("parsing {key} as usize"))
    }

    /// `key` parsed as f64.
    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.parse().with_context(|| format!("parsing {key} as f64"))
    }

    /// `key` parsed as bool (`1`/`true`/`0`/`false`).
    pub fn bool(&self, key: &str) -> Result<bool> {
        match self.get(key)? {
            "1" | "true" | "True" => Ok(true),
            "0" | "false" | "False" => Ok(false),
            other => Err(anyhow!("cannot parse {other:?} as bool")),
        }
    }

    /// `key` split on commas into trimmed, non-empty strings.
    pub fn list(&self, key: &str) -> Result<Vec<String>> {
        Ok(self
            .get(key)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }

    /// `key` as a comma-separated usize list.
    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.list(key)?
            .iter()
            .map(|s| s.parse().with_context(|| format!("parsing {key} list")))
            .collect()
    }

    /// All keys in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let kv = KvFile::parse("a = 1\n# comment\nb = hello world\nlist = x,y , z\n").unwrap();
        assert_eq!(kv.usize("a").unwrap(), 1);
        assert_eq!(kv.get("b").unwrap(), "hello world");
        assert_eq!(kv.list("list").unwrap(), vec!["x", "y", "z"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(KvFile::parse("no equals sign").is_err());
    }

    #[test]
    fn missing_key_errors() {
        let kv = KvFile::parse("a = 1").unwrap();
        assert!(kv.get("b").is_err());
        assert_eq!(kv.get_or("b", "z"), "z");
    }

    #[test]
    fn bool_and_float() {
        let kv = KvFile::parse("t = 1\nf = false\nx = 1.5").unwrap();
        assert!(kv.bool("t").unwrap());
        assert!(!kv.bool("f").unwrap());
        assert_eq!(kv.f64("x").unwrap(), 1.5);
    }
}
