//! Timing helpers for the bench harness (criterion is unavailable offline).

use std::time::{Duration, Instant};

/// Simple wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Time since `start`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since `start` in seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Statistics from a median-of-N measurement.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Median sample, seconds.
    pub median_s: f64,
    /// Mean sample, seconds.
    pub mean_s: f64,
    /// Fastest sample, seconds.
    pub min_s: f64,
    /// Slowest sample, seconds.
    pub max_s: f64,
    /// Sample standard deviation, seconds.
    pub std_s: f64,
    /// Measured iteration count (excluding warmup).
    pub iters: usize,
}

/// Median-of-N wallclock benchmark with warmup — the harness every
/// `rust/benches/*` target uses for latency/throughput rows.
pub fn bench_median<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    BenchStats {
        median_s: samples[samples.len() / 2],
        mean_s: mean,
        min_s: samples[0],
        max_s: *samples.last().unwrap(),
        std_s: var.sqrt(),
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let mut n = 0u64;
        let st = bench_median(2, 5, || {
            n += 1;
            std::hint::black_box(n);
        });
        assert_eq!(n, 7);
        assert!(st.min_s <= st.median_s && st.median_s <= st.max_s);
    }
}
