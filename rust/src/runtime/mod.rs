//! PJRT runtime plumbing: loads the AOT-lowered HLO text artifacts and
//! executes them on the CPU PJRT client via the `xla` crate. Consumed by
//! [`crate::backend::pjrt`]; most code should go through the
//! [`crate::backend::Backend`] abstraction instead of using this module
//! directly.
//!
//! Design (see DESIGN.md §Perf L3): weights are uploaded to device buffers
//! **once** per model variant and reused across every execution — only the
//! small data inputs (token ids, router mask) are transferred per call.
//! This is the Rust-side analog of keeping the model resident on the GPU.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;
use crate::weights::Weights;

/// Shared PJRT CPU client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Construct the shared CPU client.
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Arc::new(Self { client }))
    }

    /// PJRT platform name (e.g. `cpu`, or `stub-cpu` offline).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo<P: AsRef<Path>>(self: &Arc<Self>, path: P) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(wrap)
            .with_context(|| format!("parsing HLO text {}", path.as_ref().display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(Executable {
            rt: Arc::clone(self),
            exe,
            name: path
                .as_ref()
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    /// Upload an f32 tensor to a device buffer.
    pub fn upload_f32(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape().to_vec();
        self.client
            .buffer_from_host_buffer(t.data(), &dims, None)
            .map_err(wrap)
    }

    /// Upload an i32 tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(wrap)
    }
}

/// A compiled executable plus its name (for logs/metrics).
pub struct Executable {
    rt: Arc<Runtime>,
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// Per-call data inputs (weights ride along as resident buffers).
pub enum Input {
    /// An f32 tensor input.
    F32(Tensor),
    /// An i32 buffer with explicit dimensions.
    I32(Vec<i32>, Vec<usize>),
}

impl Executable {
    /// Artifact stem this executable was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Upload the model weights once; returns resident buffers to pass as
    /// the leading inputs of every subsequent `run_with`.
    pub fn upload_weights(&self, w: &Weights) -> Result<Vec<xla::PjRtBuffer>> {
        w.ordered().iter().map(|t| self.rt.upload_f32(t)).collect()
    }

    /// Execute with resident weight buffers + per-call data inputs.
    /// Returns the flattened output tuple as host tensors.
    pub fn run_with(
        &self,
        weights: &[xla::PjRtBuffer],
        data: &[Input],
    ) -> Result<Vec<Tensor>> {
        let owned: Vec<xla::PjRtBuffer> = data
            .iter()
            .map(|d| match d {
                Input::F32(t) => self.rt.upload_f32(t),
                Input::I32(v, dims) => self.rt.upload_i32(v, dims),
            })
            .collect::<Result<_>>()?;
        let bufs: Vec<&xla::PjRtBuffer> = weights.iter().chain(owned.iter()).collect();
        let result = self.exe.execute_b(&bufs).map_err(wrap)?;
        let out = result
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("no device results"))?;
        let first = out.into_iter().next().ok_or_else(|| anyhow!("empty result"))?;
        let literal = first.to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = literal.to_tuple().map_err(wrap)?;
        elems.into_iter().map(literal_to_tensor).collect()
    }
}

fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(wrap)?;
    let data: Vec<f32> = match ty {
        xla::ElementType::F32 => lit.to_vec::<f32>().map_err(wrap)?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(wrap)?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => return Err(anyhow!("unsupported output element type {other:?}")),
    };
    Tensor::new(dims, data)
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
