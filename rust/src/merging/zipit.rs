//! Full ZipIt-style merging (Stoica et al. 2024), the slow baseline of
//! Table 9 / Appendix B.2.
//!
//! Unlike Fix-Dom (which freezes the dominant expert's feature order),
//! ZipIt concatenates the hidden dimensions of *all* cluster members
//! (|C|·m features), computes the full pairwise correlation, and greedily
//! "zips" the most-correlated feature pairs until only m merged features
//! remain.  Every merged feature then averages the weight columns of its
//! member dimensions.  Complexity is O((|C|·m)² · f) vs Fix-Dom's
//! O(|C|·m²·f) — the source of the paper's >100× runtime gap.

use anyhow::Result;

use crate::calib::LayerStats;
use crate::tensor::{corr_matrix, Tensor};
use crate::weights::ExpertWeights;

use super::fixdom::{feature_rows, FixDomFeature};

/// ZipIt merge of a cluster.
pub fn merge_zipit(
    experts: &[ExpertWeights],
    stats: &LayerStats,
    members: &[usize],
    feature: FixDomFeature,
) -> Result<ExpertWeights> {
    let c = experts.len();
    let d = experts[0].wg.shape()[0];
    let m = experts[0].wg.shape()[1];
    let total = c * m;
    // 1. collect features of every (expert, dim) pair
    let mut all_rows: Vec<f32> = Vec::new();
    let mut f_len = 0usize;
    for (i, e) in experts.iter().enumerate() {
        let (rows, f) = feature_rows(e, stats, members[i], feature);
        if i == 0 {
            f_len = f;
        }
        anyhow::ensure!(f == f_len, "feature length mismatch");
        all_rows.extend(rows);
    }
    // 2. full correlation matrix over all c*m features
    let corr = corr_matrix(&all_rows, &all_rows, total, total, f_len);
    // 3. greedy zip: union-find over feature groups, merging the highest-
    //    correlated pair of distinct groups until `m` groups remain
    let mut parent: Vec<usize> = (0..total).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        let mut r = x;
        while parent[r] != r {
            r = parent[r];
        }
        let mut cur = x;
        while parent[cur] != r {
            let next = parent[cur];
            parent[cur] = r;
            cur = next;
        }
        r
    }
    let mut pairs: Vec<(usize, usize, f32)> = Vec::with_capacity(total * (total - 1) / 2);
    for i in 0..total {
        for j in (i + 1)..total {
            pairs.push((i, j, corr[i * total + j]));
        }
    }
    pairs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then((a.0, a.1).cmp(&(b.0, b.1))));
    let mut groups = total;
    for &(i, j, _) in &pairs {
        if groups == m {
            break;
        }
        let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
        if ri != rj {
            parent[rj.max(ri)] = rj.min(ri);
            groups -= 1;
        }
    }
    // 4. assign group slots (stable by smallest member) and average columns
    let mut root_of: Vec<usize> = (0..total).map(|x| find(&mut parent, x)).collect();
    let mut roots: Vec<usize> = root_of.clone();
    roots.sort_unstable();
    roots.dedup();
    // If the greedy pass ran out of positive pairs early we may have > m
    // groups; fold the excess smallest groups together to guarantee m.
    while roots.len() > m {
        let a = roots.pop().unwrap();
        let b = *roots.last().unwrap();
        for r in root_of.iter_mut() {
            if *r == a {
                *r = b;
            }
        }
    }
    let slot_of = |root: usize| roots.binary_search(&root).unwrap_or(0);
    let mut wg = vec![0f32; d * m];
    let mut wu = vec![0f32; d * m];
    let mut wd = vec![0f32; m * d];
    let mut cnt = vec![0f32; m];
    for idx in 0..total {
        let slot = slot_of(root_of[idx]);
        let (e, j) = (idx / m, idx % m);
        cnt[slot] += 1.0;
        let ew = &experts[e];
        for i in 0..d {
            wg[i * m + slot] += ew.wg.data()[i * m + j];
            wu[i * m + slot] += ew.wu.data()[i * m + j];
        }
        for i in 0..d {
            wd[slot * d + i] += ew.wd.data()[j * d + i];
        }
    }
    for slot in 0..m {
        let cdiv = cnt[slot].max(1.0);
        for i in 0..d {
            wg[i * m + slot] /= cdiv;
            wu[i * m + slot] /= cdiv;
            wd[slot * d + i] /= cdiv;
        }
    }
    Ok(ExpertWeights {
        wg: Tensor::new(vec![d, m], wg)?,
        wu: Tensor::new(vec![d, m], wu)?,
        wd: Tensor::new(vec![m, d], wd)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_grouped;
    use crate::util::Rng;

    fn rand_expert(rng: &mut Rng, d: usize, m: usize) -> ExpertWeights {
        let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
        ExpertWeights {
            wg: Tensor::new(vec![d, m], mk(d * m)).unwrap(),
            wu: Tensor::new(vec![d, m], mk(d * m)).unwrap(),
            wd: Tensor::new(vec![m, d], mk(d * m)).unwrap(),
        }
    }

    #[test]
    fn identical_experts_zip_to_themselves() {
        let mut rng = Rng::new(12);
        let a = rand_expert(&mut rng, 5, 4);
        let st = synthetic_grouped(2, 4, &[vec![0, 1]], 0.0, 6);
        let merged = merge_zipit(
            &[a.clone(), a.clone()],
            &st,
            &[0, 1],
            FixDomFeature::Weight,
        )
        .unwrap();
        // each original dim should pair with its twin in the other expert;
        // averaging identical columns reproduces the original expert
        let mut matched = 0;
        for j in 0..4 {
            let col: Vec<f32> = (0..5).map(|i| merged.wg.data()[i * 4 + j]).collect();
            if (0..4).any(|j2| {
                (0..5).all(|i| (col[i] - a.wg.data()[i * 4 + j2]).abs() < 1e-4)
            }) {
                matched += 1;
            }
        }
        assert_eq!(matched, 4, "all zipped dims must match original columns");
    }

    #[test]
    fn output_shapes_are_expert_shaped() {
        let mut rng = Rng::new(13);
        let a = rand_expert(&mut rng, 4, 3);
        let b = rand_expert(&mut rng, 4, 3);
        let c = rand_expert(&mut rng, 4, 3);
        let st = synthetic_grouped(3, 4, &[vec![0, 1, 2]], 0.0, 7);
        let merged =
            merge_zipit(&[a, b, c], &st, &[0, 1, 2], FixDomFeature::Weight).unwrap();
        assert_eq!(merged.wg.shape(), &[4, 3]);
        assert_eq!(merged.wd.shape(), &[3, 4]);
        assert!(merged.wg.data().iter().all(|x| x.is_finite()));
    }
}
