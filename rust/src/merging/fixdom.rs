//! Fix-Dom merging (Appendix B.2, Fig. 4).
//!
//! Steps (quoting the paper's Fig. 4):
//!   1. collect intermediate features per expert — activations
//!      act = silu(x Wg) ⊙ (x Wu) on calibration tokens, and/or the weight
//!      columns themselves;
//!   2. pairwise correlation between the *dominant* expert's feature order
//!      (fixed) and each non-dominant expert's features;
//!   3. each non-dominant feature dimension joins the dominant dimension of
//!      highest correlation;
//!   4. average-merge weights within each matched dimension group.
//!
//! The dominant expert is the cluster member with the highest activation
//! frequency; its feature order is preserved, which is what makes Fix-Dom
//! >100× faster than full ZipIt while staying competitive (Table 9).

use anyhow::Result;

use crate::calib::LayerStats;
use crate::tensor::corr_matrix;
use crate::weights::ExpertWeights;

/// Feature space used to correlate hidden units (Appendix B.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FixDomFeature {
    /// Intermediate activations on calibration tokens.
    Act,
    /// Weight columns as features.
    Weight,
    /// Concatenation of both.
    ActWeight,
}

impl FixDomFeature {
    /// Short label used in method strings.
    pub fn short(&self) -> &'static str {
        match self {
            FixDomFeature::Act => "act",
            FixDomFeature::Weight => "weight",
            FixDomFeature::ActWeight => "actweight",
        }
    }
}

/// Feature rows for each of the `m` hidden dims of expert `member`.
/// Returns a [m, f] row-major matrix.
pub(crate) fn feature_rows(
    e: &ExpertWeights,
    stats: &LayerStats,
    member: usize,
    feature: FixDomFeature,
) -> (Vec<f32>, usize) {
    let m = e.wg.shape()[1];
    let d = e.wg.shape()[0];
    let act_feat = |out: &mut Vec<f32>| {
        // act_sub[member]: [t_act, m] -> transpose to per-dim rows [m, t_act]
        let a = stats.acts(member);
        let t = a.shape()[0];
        for j in 0..m {
            for s in 0..t {
                out.push(a.data()[s * m + j]);
            }
        }
    };
    let weight_feat = |out: &mut Vec<f32>| {
        // per-dim weight feature: [Wg[:,j] | Wu[:,j] | Wd[j,:]] of length 3d
        for j in 0..m {
            for i in 0..d {
                out.push(e.wg.data()[i * m + j]);
            }
            for i in 0..d {
                out.push(e.wu.data()[i * m + j]);
            }
            out.extend_from_slice(&e.wd.data()[j * d..(j + 1) * d]);
        }
    };
    let mut rows = Vec::new();
    match feature {
        FixDomFeature::Act => {
            act_feat(&mut rows);
            let f = rows.len() / m;
            (rows, f)
        }
        FixDomFeature::Weight => {
            weight_feat(&mut rows);
            (rows, 3 * d)
        }
        FixDomFeature::ActWeight => {
            // interleave per-dim: [act_j | weight_j]
            let mut acts = Vec::new();
            act_feat(&mut acts);
            let ta = acts.len() / m;
            let mut weights = Vec::new();
            weight_feat(&mut weights);
            let tw = 3 * d;
            for j in 0..m {
                rows.extend_from_slice(&acts[j * ta..(j + 1) * ta]);
                rows.extend_from_slice(&weights[j * tw..(j + 1) * tw]);
            }
            (rows, ta + tw)
        }
    }
}

/// Best-correlated dominant dimension for every dimension of `other`.
pub(crate) fn match_dims(dom_rows: &[f32], other_rows: &[f32], m: usize, f: usize) -> Vec<usize> {
    let corr = corr_matrix(other_rows, dom_rows, m, m, f);
    (0..m)
        .map(|j| {
            let row = &corr[j * m..(j + 1) * m];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(j)
        })
        .collect()
}

/// Permute the hidden dims of `e` so dim j lands on `mapping[j]` of the
/// dominant order, accumulating into per-dominant-dim groups.
fn align_to_dominant(e: &ExpertWeights, mapping: &[usize]) -> ExpertWeights {
    let d = e.wg.shape()[0];
    let m = e.wg.shape()[1];
    let mut wg = vec![0f32; d * m];
    let mut wu = vec![0f32; d * m];
    let mut wd = vec![0f32; m * d];
    let mut count = vec![0f32; m];
    for (j, &tgt) in mapping.iter().enumerate() {
        count[tgt] += 1.0;
        for i in 0..d {
            wg[i * m + tgt] += e.wg.data()[i * m + j];
            wu[i * m + tgt] += e.wu.data()[i * m + j];
        }
        for i in 0..d {
            wd[tgt * d + i] += e.wd.data()[j * d + i];
        }
    }
    // average within groups; unmatched dominant dims keep zeros (they will
    // only receive the dominant expert's own weight in the final average)
    for tgt in 0..m {
        let c = count[tgt].max(1.0);
        for i in 0..d {
            wg[i * m + tgt] /= c;
            wu[i * m + tgt] /= c;
            wd[tgt * d + i] /= c;
        }
    }
    ExpertWeights {
        wg: crate::tensor::Tensor::new(vec![d, m], wg).unwrap(),
        wu: crate::tensor::Tensor::new(vec![d, m], wu).unwrap(),
        wd: crate::tensor::Tensor::new(vec![m, d], wd).unwrap(),
    }
}

/// Fix-Dom merge of a cluster. `members[i]` is the expert index of
/// `experts[i]`; the dominant is the member with the highest frequency.
pub fn merge_fixdom(
    experts: &[ExpertWeights],
    stats: &LayerStats,
    members: &[usize],
    feature: FixDomFeature,
) -> Result<ExpertWeights> {
    let dom_pos = members
        .iter()
        .enumerate()
        .max_by(|a, b| {
            stats.counts[*a.1]
                .partial_cmp(&stats.counts[*b.1])
                .unwrap()
                .then(b.1.cmp(a.1)) // tie -> lower expert index
        })
        .map(|(i, _)| i)
        .unwrap();
    let m = experts[0].wg.shape()[1];
    let (dom_rows, f) = feature_rows(&experts[dom_pos], stats, members[dom_pos], feature);
    let mut aligned: Vec<ExpertWeights> = Vec::with_capacity(experts.len());
    for (i, e) in experts.iter().enumerate() {
        if i == dom_pos {
            aligned.push(e.clone());
            continue;
        }
        let (rows, f2) = feature_rows(e, stats, members[i], feature);
        anyhow::ensure!(f2 == f, "feature length mismatch");
        let mapping = match_dims(&dom_rows, &rows, m, f);
        aligned.push(align_to_dominant(e, &mapping));
    }
    let a = vec![1.0 / aligned.len() as f32; aligned.len()];
    super::merge_weighted(&aligned, &a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_grouped;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn rand_expert(rng: &mut Rng, d: usize, m: usize) -> ExpertWeights {
        let mut mk = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal() as f32).collect() };
        ExpertWeights {
            wg: Tensor::new(vec![d, m], mk(d * m)).unwrap(),
            wu: Tensor::new(vec![d, m], mk(d * m)).unwrap(),
            wd: Tensor::new(vec![m, d], mk(d * m)).unwrap(),
        }
    }

    /// Permute hidden dims of an expert with a known permutation.
    fn permute(e: &ExpertWeights, perm: &[usize]) -> ExpertWeights {
        let d = e.wg.shape()[0];
        let m = e.wg.shape()[1];
        let mut wg = vec![0f32; d * m];
        let mut wu = vec![0f32; d * m];
        let mut wd = vec![0f32; m * d];
        for (j, &p) in perm.iter().enumerate() {
            // dim j of the new expert = dim p of the original
            for i in 0..d {
                wg[i * m + j] = e.wg.data()[i * m + p];
                wu[i * m + j] = e.wu.data()[i * m + p];
            }
            wd[j * d..(j + 1) * d].copy_from_slice(&e.wd.data()[p * d..(p + 1) * d]);
        }
        ExpertWeights {
            wg: Tensor::new(vec![d, m], wg).unwrap(),
            wu: Tensor::new(vec![d, m], wu).unwrap(),
            wd: Tensor::new(vec![m, d], wd).unwrap(),
        }
    }

    #[test]
    fn weight_features_recover_a_permutation() {
        // expert B = expert A with permuted hidden dims. Fix-Dom with weight
        // features must align B back onto A, so the merge equals A itself.
        let mut rng = Rng::new(3);
        let (d, m) = (6, 5);
        let a = rand_expert(&mut rng, d, m);
        let perm = vec![2usize, 0, 4, 1, 3];
        let b = permute(&a, &perm);
        let mut st = synthetic_grouped(2, 4, &[vec![0], vec![1]], 0.0, 4);
        st.counts = vec![10.0, 1.0]; // expert 0 (A) dominant
        let merged = merge_fixdom(&[a.clone(), b], &st, &[0, 1], FixDomFeature::Weight).unwrap();
        for (x, y) in merged.wg.data().iter().zip(a.wg.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        for (x, y) in merged.wd.data().iter().zip(a.wd.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn identical_experts_merge_to_themselves() {
        let mut rng = Rng::new(9);
        let a = rand_expert(&mut rng, 4, 3);
        let st = synthetic_grouped(2, 4, &[vec![0, 1]], 0.0, 5);
        let merged =
            merge_fixdom(&[a.clone(), a.clone()], &st, &[0, 1], FixDomFeature::Weight).unwrap();
        for (x, y) in merged.wg.data().iter().zip(a.wg.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn match_dims_identity_on_same_rows() {
        let rows = vec![1.0, 2.0, 3.0, /* dim1 */ 9.0, 1.0, 5.0];
        let m = match_dims(&rows, &rows, 2, 3);
        assert_eq!(m, vec![0, 1]);
    }
}
