//! Expert merging strategies (Section 3.2.3 + Appendix B.2).
//!
//! Given a cluster C_i, produce the merged expert Ê_i = Σ_j α_j E_j with
//! Σ α_j = 1:
//!
//! * **Average**   — α_j = 1/|C_i|;
//! * **Frequency** — α_j = f̃_j (Algorithm 1 lines 12-17; HC-SMoE default);
//! * **Fix-Dom**   — the paper's ZipIt adaptation: permutation-align every
//!   member's hidden features to the *dominant* (most frequent) expert via
//!   feature correlation, then average (Appendix B.2, Fig. 4);
//! * **ZipIt**     — the full iterative pairwise feature matcher, kept as
//!   the slow baseline of Table 9 / the >100× runtime comparison.

pub mod fixdom;
pub mod zipit;

use anyhow::Result;

use crate::calib::LayerStats;
use crate::tensor::weighted_sum;
use crate::weights::{ExpertWeights, Weights};

pub use fixdom::FixDomFeature;

/// How a cluster's member experts combine into one (Section 3.2.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MergeStrategy {
    /// Uniform weights α_j = 1/|C|.
    Average,
    /// Frequency weights α_j = f̃_j (HC-SMoE default).
    Frequency,
    /// Permutation-align members to the dominant expert, then average.
    FixDom(FixDomFeature),
    /// Full iterative pairwise feature matching (slow baseline, Table 9).
    ZipIt(FixDomFeature),
}

impl MergeStrategy {
    /// Short label used in method strings.
    pub fn short(&self) -> String {
        match self {
            MergeStrategy::Average => "average".into(),
            MergeStrategy::Frequency => "frequency".into(),
            MergeStrategy::FixDom(f) => format!("fixdom-{}", f.short()),
            MergeStrategy::ZipIt(f) => format!("zipit-{}", f.short()),
        }
    }

    /// Parse a strategy name (`average`, `frequency`, `fixdom[-*]`, `zipit[-*]`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "average" | "avg" => MergeStrategy::Average,
            "frequency" | "freq" => MergeStrategy::Frequency,
            "fixdom" | "fixdom-act" => MergeStrategy::FixDom(FixDomFeature::Act),
            "fixdom-weight" => MergeStrategy::FixDom(FixDomFeature::Weight),
            "fixdom-actweight" => MergeStrategy::FixDom(FixDomFeature::ActWeight),
            "zipit" | "zipit-act" => MergeStrategy::ZipIt(FixDomFeature::Act),
            "zipit-weight" => MergeStrategy::ZipIt(FixDomFeature::Weight),
            "zipit-actweight" => MergeStrategy::ZipIt(FixDomFeature::ActWeight),
            other => anyhow::bail!("unknown merge strategy {other:?}"),
        })
    }
}

/// Plain weighted merge with explicit coefficients (must sum to ~1).
pub fn merge_weighted(experts: &[ExpertWeights], alphas: &[f32]) -> Result<ExpertWeights> {
    anyhow::ensure!(experts.len() == alphas.len() && !experts.is_empty());
    let s: f32 = alphas.iter().sum();
    anyhow::ensure!((s - 1.0).abs() < 1e-3, "alphas must sum to 1, got {s}");
    let wg: Vec<&_> = experts.iter().map(|e| &e.wg).collect();
    let wu: Vec<&_> = experts.iter().map(|e| &e.wu).collect();
    let wd: Vec<&_> = experts.iter().map(|e| &e.wd).collect();
    Ok(ExpertWeights {
        wg: weighted_sum(&wg, alphas)?,
        wu: weighted_sum(&wu, alphas)?,
        wd: weighted_sum(&wd, alphas)?,
    })
}

/// Merge one cluster under a strategy. `members` are expert indices.
pub fn merge_cluster(
    weights: &Weights,
    stats: &LayerStats,
    layer: usize,
    members: &[usize],
    strategy: MergeStrategy,
) -> Result<ExpertWeights> {
    anyhow::ensure!(!members.is_empty(), "empty cluster");
    let experts: Vec<ExpertWeights> = members
        .iter()
        .map(|&e| weights.expert(layer, e))
        .collect::<Result<_>>()?;
    if experts.len() == 1 {
        return Ok(experts.into_iter().next().unwrap());
    }
    match strategy {
        MergeStrategy::Average => {
            let a = vec![1.0 / experts.len() as f32; experts.len()];
            merge_weighted(&experts, &a)
        }
        MergeStrategy::Frequency => {
            let a = stats.norm_freq(members);
            merge_weighted(&experts, &a)
        }
        MergeStrategy::FixDom(feature) => {
            fixdom::merge_fixdom(&experts, stats, members, feature)
        }
        MergeStrategy::ZipIt(feature) => {
            zipit::merge_zipit(&experts, stats, members, feature)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::synthetic::synthetic_grouped;
    use crate::tensor::Tensor;

    fn demo_expert(v: f32, d: usize, m: usize) -> ExpertWeights {
        ExpertWeights {
            wg: Tensor::full(vec![d, m], v),
            wu: Tensor::full(vec![d, m], v + 1.0),
            wd: Tensor::full(vec![m, d], v + 2.0),
        }
    }

    #[test]
    fn average_merge_is_mean() {
        let a = demo_expert(0.0, 2, 3);
        let b = demo_expert(2.0, 2, 3);
        let m = merge_weighted(&[a, b], &[0.5, 0.5]).unwrap();
        assert!(m.wg.data().iter().all(|&x| x == 1.0));
        assert!(m.wu.data().iter().all(|&x| x == 2.0));
        assert!(m.wd.data().iter().all(|&x| x == 3.0));
    }

    #[test]
    fn frequency_merge_respects_counts() {
        let mut st = synthetic_grouped(2, 4, &[vec![0], vec![1]], 0.0, 1);
        st.counts = vec![3.0, 1.0];
        let f = st.norm_freq(&[0, 1]);
        assert_eq!(f, vec![0.75, 0.25]);
    }

    #[test]
    fn alphas_must_sum_to_one() {
        let a = demo_expert(0.0, 2, 2);
        let b = demo_expert(1.0, 2, 2);
        assert!(merge_weighted(&[a, b], &[0.9, 0.9]).is_err());
    }

    #[test]
    fn singleton_cluster_is_identity() {
        let mut map = std::collections::BTreeMap::new();
        map.insert(
            "layer00.exp.wg".to_string(),
            Tensor::new(vec![2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]).unwrap(),
        );
        map.insert("layer00.exp.wu".to_string(), Tensor::zeros(vec![2, 2, 2]));
        map.insert("layer00.exp.wd".to_string(), Tensor::zeros(vec![2, 2, 2]));
        let w = Weights::new(map);
        let st = synthetic_grouped(2, 4, &[vec![0], vec![1]], 0.0, 2);
        let m = merge_cluster(&w, &st, 0, &[1], MergeStrategy::Average).unwrap();
        assert_eq!(m.wg.data(), &[5., 6., 7., 8.]);
    }
}
