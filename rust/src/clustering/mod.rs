//! Expert grouping (Section 3.2.2 + ablations): hierarchical clustering
//! (the paper's method, Algorithm 1), K-means (fixed/random init), Fuzzy
//! C-Means (Appendix B.5), M-SMoE-style single-shot grouping, and the
//! non-uniform layer-budget variant (Appendix B.1).

pub mod fcm;
pub mod hierarchical;
pub mod kmeans;
pub mod nonuniform;
pub mod singleshot;

pub use fcm::{fcm, fcm_with, FcmResult};
pub use hierarchical::{hierarchical, hierarchical_with, Linkage};
pub use kmeans::{kmeans, kmeans_with, KmeansInit};
pub use nonuniform::nonuniform_budgets;
pub use singleshot::single_shot;

/// A hard clustering of `n` experts into `r` groups.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Clustering {
    /// assign[e] = cluster id in 0..r
    pub assign: Vec<usize>,
    /// Cluster count.
    pub r: usize,
}

impl Clustering {
    /// Wrap an assignment vector (debug-asserts ids are in range).
    pub fn new(assign: Vec<usize>, r: usize) -> Self {
        debug_assert!(assign.iter().all(|&c| c < r));
        Self { assign, r }
    }

    /// Number of clustered experts.
    pub fn n(&self) -> usize {
        self.assign.len()
    }

    /// Member lists per cluster.
    pub fn groups(&self) -> Vec<Vec<usize>> {
        let mut g = vec![Vec::new(); self.r];
        for (e, &c) in self.assign.iter().enumerate() {
            g[c].push(e);
        }
        g
    }

    /// Invariants every grouping algorithm must satisfy: total coverage
    /// (Σ|C_i| = n, Section 3.1) and no empty cluster.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.r >= 1, "need at least one cluster");
        anyhow::ensure!(
            self.assign.iter().all(|&c| c < self.r),
            "assignment out of range"
        );
        let groups = self.groups();
        anyhow::ensure!(
            groups.iter().all(|g| !g.is_empty()),
            "empty cluster in {:?}",
            groups
        );
        let total: usize = groups.iter().map(|g| g.len()).sum();
        anyhow::ensure!(total == self.n(), "partition does not cover all experts");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_and_validate() {
        let c = Clustering::new(vec![0, 1, 0, 2], 3);
        assert_eq!(c.groups(), vec![vec![0, 2], vec![1], vec![3]]);
        c.validate().unwrap();
    }

    #[test]
    fn validate_rejects_empty_cluster() {
        let c = Clustering { assign: vec![0, 0, 0], r: 2 };
        assert!(c.validate().is_err());
    }
}
