//! Non-uniform per-layer cluster budgets (Appendix B.1).
//!
//! Instead of a fixed `r` everywhere, select the globally top `r·L` experts
//! by activation frequency and let the per-layer survivor counts set each
//! layer's cluster budget — then run HC within each layer as usual.

/// `freqs[l][e]`: per-layer activation frequencies. Returns the per-layer
/// cluster counts summing to `r_avg * n_layers`, each within [min_r, n].
pub fn nonuniform_budgets(freqs: &[Vec<f32>], r_avg: usize, min_r: usize) -> Vec<usize> {
    let nl = freqs.len();
    let n = freqs[0].len();
    assert!(min_r >= 1 && r_avg >= min_r && r_avg <= n);
    let total = r_avg * nl;
    // rank all (layer, expert) pairs by frequency
    let mut pairs: Vec<(usize, usize, f32)> = Vec::with_capacity(nl * n);
    for (l, row) in freqs.iter().enumerate() {
        for (e, &f) in row.iter().enumerate() {
            pairs.push((l, e, f));
        }
    }
    pairs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then((a.0, a.1).cmp(&(b.0, b.1))));
    let mut budgets = vec![0usize; nl];
    for &(l, _, _) in pairs.iter().take(total) {
        budgets[l] += 1;
    }
    // repair to the [min_r, n] box while preserving the total; donors are
    // the largest-budget layers, ties broken toward the coldest layer
    let layer_heat: Vec<f64> = freqs
        .iter()
        .map(|row| row.iter().map(|&x| x as f64).sum())
        .collect();
    loop {
        let mut moved = false;
        for l in 0..nl {
            if budgets[l] < min_r {
                // take one from the largest (coldest on ties) layer above min_r
                let donor = (0..nl)
                    .filter(|&d| budgets[d] > min_r)
                    .max_by(|&a, &b| {
                        budgets[a]
                            .cmp(&budgets[b])
                            .then(layer_heat[b].partial_cmp(&layer_heat[a]).unwrap())
                    });
                if let Some(d) = donor {
                    budgets[d] -= 1;
                    budgets[l] += 1;
                    moved = true;
                }
            } else if budgets[l] > n {
                let taker = (0..nl).find(|&d| budgets[d] < n);
                if let Some(d) = taker {
                    budgets[l] -= 1;
                    budgets[d] += 1;
                    moved = true;
                }
            }
        }
        if !moved {
            break;
        }
    }
    debug_assert_eq!(budgets.iter().sum::<usize>(), total);
    budgets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn uniform_freqs_give_uniform_budgets() {
        let freqs = vec![vec![1.0; 8]; 4];
        let b = nonuniform_budgets(&freqs, 6, 2);
        assert_eq!(b.iter().sum::<usize>(), 24);
        // ties break deterministically; each layer stays within bounds
        assert!(b.iter().all(|&x| (2..=8).contains(&x)));
    }

    #[test]
    fn hot_layer_gets_more_clusters() {
        let mut freqs = vec![vec![1.0; 8]; 4];
        freqs[2] = vec![100.0; 8]; // layer 2 dominates the global top list
        let b = nonuniform_budgets(&freqs, 6, 2);
        assert_eq!(b[2], 8, "hottest layer keeps all experts");
        assert_eq!(b.iter().sum::<usize>(), 24);
    }

    #[test]
    fn budget_invariants() {
        proptest::check("nonuniform-budget", 41, 30, |rng| {
            let nl = 1 + rng.below(6);
            let n = 4 + rng.below(12);
            let min_r = 2;
            let r_avg = min_r + rng.below(n - min_r);
            let freqs: Vec<Vec<f32>> = (0..nl)
                .map(|_| (0..n).map(|_| rng.next_f32() * 50.0).collect())
                .collect();
            let b = nonuniform_budgets(&freqs, r_avg, min_r);
            proptest::ensure(b.len() == nl, "layer count")?;
            proptest::ensure(
                b.iter().sum::<usize>() == r_avg * nl,
                format!("total {} != {}", b.iter().sum::<usize>(), r_avg * nl),
            )?;
            proptest::ensure(
                b.iter().all(|&x| x >= min_r && x <= n),
                format!("bounds violated: {b:?}"),
            )
        });
    }
}
