//! Agglomerative hierarchical clustering (Algorithm 1, Section 3.2.2).
//!
//! Bottom-up: start from singleton clusters, repeatedly merge the pair with
//! the smallest linkage distance until `r` clusters remain.  Deterministic —
//! the paper's key robustness argument vs K-means — and with average linkage
//! it carries the Moseley-Wang 3·OPT approximation guarantee (Appendix A.1).
//!
//! Linkage distances are recomputed from the *expert-level* distance matrix
//! at every step (Eq. 6-8), so merged clusters re-enter the comparison with
//! their true aggregate distances ("iterative recalibration", §3.2.2).

use super::Clustering;
use crate::parallel;

/// Agglomerative linkage criterion (Eqs. 6-8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Linkage {
    /// Eq. 6: min pairwise distance.
    Single,
    /// Eq. 7: max pairwise distance.
    Complete,
    /// Eq. 8: mean pairwise distance (the paper's choice).
    Average,
}

impl Linkage {
    /// Short label used in method strings.
    pub fn short(&self) -> &'static str {
        match self {
            Linkage::Single => "single",
            Linkage::Complete => "complete",
            Linkage::Average => "avg",
        }
    }

    /// Parse a linkage name (`single` / `complete` / `average`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "single" => Linkage::Single,
            "complete" => Linkage::Complete,
            "average" | "avg" => Linkage::Average,
            other => anyhow::bail!("unknown linkage {other:?}"),
        })
    }
}

/// Linkage distance between two clusters given the expert distance matrix.
fn cluster_dist(dist: &[Vec<f32>], a: &[usize], b: &[usize], linkage: Linkage) -> f32 {
    match linkage {
        Linkage::Single => {
            let mut best = f32::INFINITY;
            for &i in a {
                for &j in b {
                    best = best.min(dist[i][j]);
                }
            }
            best
        }
        Linkage::Complete => {
            let mut worst = f32::NEG_INFINITY;
            for &i in a {
                for &j in b {
                    worst = worst.max(dist[i][j]);
                }
            }
            worst
        }
        Linkage::Average => {
            let mut sum = 0f64;
            for &i in a {
                for &j in b {
                    sum += dist[i][j] as f64;
                }
            }
            (sum / (a.len() * b.len()) as f64) as f32
        }
    }
}

/// Hard floor for the parallel scan: below this, even explicitly requested
/// workers fall back to serial (a scan this small cannot amortise one
/// spawn). The determinism suite exercises the parallel scan above it.
const PAR_MIN_CLUSTERS: usize = 24;

/// Best merge candidate under the deterministic tie-break: minimal linkage
/// distance, ties resolved toward the lexicographically smallest (a, b).
/// The serial scan realises exactly this rule via its strict `<` over
/// ascending (a, b), so any partition of the scan space that combines local
/// winners with the same rule reproduces the serial answer bit-for-bit.
fn best_pair(
    dist: &[Vec<f32>],
    clusters: &[Vec<usize>],
    linkage: Linkage,
    threads: usize,
) -> (usize, usize, f32) {
    let m = clusters.len();
    let scan = |rows: &mut dyn Iterator<Item = usize>| {
        let mut best = (0usize, 1usize, f32::INFINITY);
        for a in rows {
            for b in (a + 1)..m {
                let d = cluster_dist(dist, &clusters[a], &clusters[b], linkage);
                // strict < keeps the tie-break deterministic (lowest index pair)
                if d < best.2 {
                    best = (a, b, d);
                }
            }
        }
        best
    };
    if threads <= 1 || m < PAR_MIN_CLUSTERS {
        return scan(&mut (0..m));
    }
    // Round-robin rows across workers: row a costs m - a comparisons, so
    // contiguous chunks would leave the last worker nearly idle.
    let t = threads.min(m);
    let locals = parallel::par_map_chunks(t, t, |workers| {
        let mut local = (0usize, 1usize, f32::INFINITY);
        for w in workers {
            let candidate = scan(&mut (w..m).step_by(t));
            if candidate.2 < local.2
                || (candidate.2 == local.2 && (candidate.0, candidate.1) < (local.0, local.1))
            {
                local = candidate;
            }
        }
        local
    });
    let mut best = (0usize, 1usize, f32::INFINITY);
    for cand in locals {
        if cand.2 < best.2 || (cand.2 == best.2 && (cand.0, cand.1) < (best.0, best.1)) {
            best = cand;
        }
    }
    best
}

/// Cluster `n` experts into `r` groups from a pairwise distance matrix
/// (see [`hierarchical_with`]).
///
/// Auto dispatch scales the worker count to the per-step scan work — one
/// extra worker per [`parallel::PAR_AUTO_WORK`] element-ops in the O(n²/2)
/// scan, so each ~50µs spawn stays amortised. At paper scales (E ≤ 64,
/// microsecond scans) this resolves to the serial path; the parallel scan
/// engages from roughly 1450 clusters upward.
pub fn hierarchical(dist: &[Vec<f32>], r: usize, linkage: Linkage) -> Clustering {
    let n = dist.len();
    let max_useful = (n * n / 2) / parallel::PAR_AUTO_WORK;
    let threads = parallel::default_threads().min(max_useful.max(1));
    hierarchical_with(dist, r, linkage, threads)
}

/// [`hierarchical`] with an explicit worker count. `threads <= 1` is the
/// serial reference path; every thread count yields identical clusterings
/// (`rust/tests/determinism.rs`).
pub fn hierarchical_with(
    dist: &[Vec<f32>],
    r: usize,
    linkage: Linkage,
    threads: usize,
) -> Clustering {
    let n = dist.len();
    assert!(r >= 1 && r <= n, "need 1 <= r <= n (r={r}, n={n})");
    let mut clusters: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    while clusters.len() > r {
        let (a, b, _) = best_pair(dist, &clusters, linkage, threads);
        let merged = clusters.remove(b);
        clusters[a].extend(merged);
    }
    let mut assign = vec![0usize; n];
    // stable cluster ids: order clusters by smallest member index
    let mut order: Vec<usize> = (0..clusters.len()).collect();
    order.sort_by_key(|&c| *clusters[c].iter().min().unwrap());
    for (new_id, &c) in order.iter().enumerate() {
        for &e in &clusters[c] {
            assign[e] = new_id;
        }
    }
    Clustering::new(assign, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::similarity::{distance_matrix, Distance};
    use crate::util::{proptest, Rng};

    fn dist_of(points: &[Vec<f32>]) -> Vec<Vec<f32>> {
        distance_matrix(points, Distance::Euclidean)
    }

    #[test]
    fn recovers_obvious_groups() {
        // two tight groups far apart
        let pts = vec![
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![10.0, 10.0],
            vec![10.1, 10.0],
        ];
        for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
            let c = hierarchical(&dist_of(&pts), 2, linkage);
            assert_eq!(c.assign[0], c.assign[1], "{linkage:?}");
            assert_eq!(c.assign[2], c.assign[3], "{linkage:?}");
            assert_ne!(c.assign[0], c.assign[2], "{linkage:?}");
        }
    }

    #[test]
    fn r_equals_n_is_identity() {
        let pts: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let c = hierarchical(&dist_of(&pts), 5, Linkage::Average);
        assert_eq!(c.assign, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn r_equals_one_merges_all() {
        let pts: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let c = hierarchical(&dist_of(&pts), 1, Linkage::Single);
        assert!(c.assign.iter().all(|&a| a == 0));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut rng = Rng::new(5);
        let pts: Vec<Vec<f32>> = (0..12)
            .map(|_| (0..4).map(|_| rng.normal() as f32).collect())
            .collect();
        let d = dist_of(&pts);
        let a = hierarchical(&d, 4, Linkage::Average);
        let b = hierarchical(&d, 4, Linkage::Average);
        assert_eq!(a, b);
    }

    #[test]
    fn single_linkage_chains_complete_does_not() {
        // a chain of equally spaced points: single linkage merges the chain,
        // complete linkage prefers compact pairs
        let pts: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32]).collect();
        let d = dist_of(&pts);
        let single = hierarchical(&d, 2, Linkage::Single);
        // chain: split into contiguous prefix/suffix
        let mut groups = single.groups();
        groups.sort_by_key(|g| g[0]);
        for g in &groups {
            for w in g.windows(2) {
                assert_eq!(w[1], w[0] + 1, "single linkage keeps the chain contiguous");
            }
        }
        let complete = hierarchical(&d, 3, Linkage::Complete);
        complete.validate().unwrap();
    }

    #[test]
    fn partition_invariants_hold() {
        proptest::check("hc-partition", 17, 30, |rng| {
            let n = 2 + rng.below(14);
            let r = 1 + rng.below(n);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.normal() as f32).collect())
                .collect();
            let d = dist_of(&pts);
            for linkage in [Linkage::Single, Linkage::Complete, Linkage::Average] {
                let c = hierarchical(&d, r, linkage);
                c.validate().map_err(|e| e.to_string())?;
                proptest::ensure(c.r == r, "cluster count")?;
            }
            Ok(())
        });
    }

    #[test]
    fn average_linkage_minimizes_within_group_spread_on_blobs() {
        // sanity for the 3*OPT story: on well-separated blobs, HC-average
        // yields intra-cluster distances far below inter-cluster ones.
        let mut rng = Rng::new(42);
        let mut pts = Vec::new();
        for c in 0..3 {
            for _ in 0..5 {
                pts.push(vec![
                    10.0 * c as f32 + 0.1 * rng.normal() as f32,
                    0.1 * rng.normal() as f32,
                ]);
            }
        }
        let d = dist_of(&pts);
        let cl = hierarchical(&d, 3, Linkage::Average);
        for g in cl.groups() {
            let c0 = g[0] / 5;
            assert!(g.iter().all(|&e| e / 5 == c0), "blob split: {g:?}");
        }
    }
}
