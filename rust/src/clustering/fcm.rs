//! Fuzzy C-Means soft clustering (Appendix B.5, Bezdek et al. 1984).
//!
//! Minimises J_m = Σ_i Σ_j u_ij^m ||e_i - c_j||² (Eq. 13) with the standard
//! alternating updates (Eq. 14).  The membership matrix feeds the soft
//! merging path (Eq. 15) including the router-weight merge the paper shows
//! degrades accuracy — reproduced in Tables 16-17.

use crate::parallel;
use crate::tensor::l2_dist;
use crate::util::Rng;

/// Fuzzy C-Means output: soft memberships plus the final centers.
#[derive(Debug, Clone)]
pub struct FcmResult {
    /// u[i][j] = membership of expert i in cluster j; rows sum to 1.
    pub membership: Vec<Vec<f32>>,
    /// Cluster centers in feature space.
    pub centers: Vec<Vec<f32>>,
    /// Cluster count.
    pub r: usize,
}

impl FcmResult {
    /// Hard assignment by max membership (used for reporting only).
    pub fn hard_assign(&self) -> Vec<usize> {
        self.membership
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect()
    }
}

/// Center j under Eq. 14 (right): the membership-weighted mean, summing
/// members in ascending i — the single expression both sweeps evaluate.
fn fcm_center(j: usize, feats: &[Vec<f32>], u: &[Vec<f32>], fuzz: f32, dim: usize) -> Vec<f32> {
    let mut num = vec![0f32; dim];
    let mut den = 0f32;
    for (i, f) in feats.iter().enumerate() {
        let w = u[i][j].powf(fuzz);
        den += w;
        for k in 0..dim {
            num[k] += w * f[k];
        }
    }
    let mut center = vec![0f32; dim];
    for k in 0..dim {
        center[k] = if den > 0.0 { num[k] / den } else { feats[0][k] };
    }
    center
}

/// Membership row of point i under Eq. 14 (left), distances clamped as in
/// the serial reference.
fn fcm_membership_row(
    i: usize,
    feats: &[Vec<f32>],
    centers: &[Vec<f32>],
    expo: f32,
    r: usize,
) -> Vec<f32> {
    let dists: Vec<f32> = (0..r)
        .map(|j| l2_dist(&feats[i], &centers[j]).max(1e-9))
        .collect();
    let mut row = vec![0f32; r];
    for j in 0..r {
        let mut s = 0f32;
        for k in 0..r {
            s += (dists[j] / dists[k]).powf(expo);
        }
        row[j] = 1.0 / s;
    }
    row
}

/// Fuzzy C-Means with the auto-selected worker count: each iteration costs
/// O(n·r·dim), so parallelism engages only when that clears
/// [`parallel::PAR_AUTO_WORK`] (see [`fcm_with`]).
pub fn fcm(feats: &[Vec<f32>], r: usize, fuzz: f32, iters: usize, seed: u64) -> FcmResult {
    let n = feats.len();
    let dim = feats.first().map_or(0, |f| f.len());
    let threads = if n * r * dim >= parallel::PAR_AUTO_WORK {
        parallel::default_threads()
    } else {
        1
    };
    fcm_with(feats, r, fuzz, iters, seed, threads)
}

/// [`fcm`] with an explicit worker count. Center and membership updates are
/// independent per cluster / per point, so any thread count reproduces the
/// serial result bit-for-bit (`rust/tests/determinism.rs`).
pub fn fcm_with(
    feats: &[Vec<f32>],
    r: usize,
    fuzz: f32,
    iters: usize,
    seed: u64,
    threads: usize,
) -> FcmResult {
    let n = feats.len();
    let dim = feats[0].len();
    assert!(r >= 1 && r <= n);
    let mut rng = Rng::new(seed);
    // init memberships randomly (rows normalised)
    let mut u = vec![vec![0f32; r]; n];
    for row in &mut u {
        let mut s = 0.0;
        for x in row.iter_mut() {
            *x = rng.next_f32().max(1e-3);
            s += *x;
        }
        for x in row.iter_mut() {
            *x /= s;
        }
    }
    let mut centers = vec![vec![0f32; dim]; r];
    let expo = 2.0 / (fuzz - 1.0);
    for _ in 0..iters {
        // centers: c_j = Σ u_ij^m e_i / Σ u_ij^m  (Eq. 14 right)
        {
            let u = &u;
            parallel::par_chunks_mut(threads.min(r), &mut centers, |start, chunk| {
                for (off, c) in chunk.iter_mut().enumerate() {
                    *c = fcm_center(start + off, feats, u, fuzz, dim);
                }
            });
        }
        // memberships (Eq. 14 left)
        {
            let centers = &centers;
            parallel::par_chunks_mut(threads, &mut u, |start, chunk| {
                for (off, row) in chunk.iter_mut().enumerate() {
                    *row = fcm_membership_row(start + off, feats, centers, expo, r);
                }
            });
        }
    }
    FcmResult { membership: u, centers, r }
}

/// Objective J_m (Eq. 13) — used by tests to check monotone improvement.
pub fn objective(feats: &[Vec<f32>], res: &FcmResult, fuzz: f32) -> f64 {
    let mut j = 0f64;
    for (i, f) in feats.iter().enumerate() {
        for (c, center) in res.centers.iter().enumerate() {
            let d = l2_dist(f, center) as f64;
            j += (res.membership[i][c] as f64).powf(fuzz as f64) * d * d;
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_feats() -> Vec<Vec<f32>> {
        vec![
            vec![0.0, 0.0],
            vec![0.2, 0.0],
            vec![8.0, 8.0],
            vec![8.2, 8.0],
        ]
    }

    #[test]
    fn memberships_are_a_distribution() {
        let res = fcm(&blob_feats(), 2, 2.0, 30, 1);
        for row in &res.membership {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sums to {s}");
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn blobs_get_confident_memberships() {
        let res = fcm(&blob_feats(), 2, 2.0, 50, 2);
        let h = res.hard_assign();
        assert_eq!(h[0], h[1]);
        assert_eq!(h[2], h[3]);
        assert_ne!(h[0], h[2]);
        // confidence >> 0.5 for well-separated blobs
        assert!(res.membership[0][h[0]] > 0.9);
    }

    #[test]
    fn objective_improves_with_iterations() {
        let f = blob_feats();
        let early = objective(&f, &fcm(&f, 2, 2.0, 1, 3), 2.0);
        let late = objective(&f, &fcm(&f, 2, 2.0, 40, 3), 2.0);
        assert!(late <= early + 1e-6, "J_m should not increase: {early} -> {late}");
    }
}
