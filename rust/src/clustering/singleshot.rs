//! Single-shot (one-pass) grouping — the M-SMoE baseline (Li et al. 2024),
//! Section 2.2 / Table 6.
//!
//! Pick the `r` most frequently activated experts as *dominant* group
//! seeds, then assign every remaining expert to the most-similar dominant
//! expert in one pass — no iterative recalibration, which is exactly the
//! limitation HC-SMoE's dendrogram addresses (§3.2.2).

use super::Clustering;
use crate::tensor::l2_dist;

/// `freqs`: activation frequency per expert (group seeds = top-r);
/// `feats`: similarity features (router logits for M-SMoE proper; the
/// Table 6 ablation also runs weight / expert-output features).
pub fn single_shot(feats: &[Vec<f32>], freqs: &[f32], r: usize) -> Clustering {
    let n = feats.len();
    assert_eq!(freqs.len(), n);
    assert!(r >= 1 && r <= n);
    // dominant experts: top-r by frequency (stable tie-break by index)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        freqs[b].partial_cmp(&freqs[a]).unwrap().then(a.cmp(&b))
    });
    let dominants: Vec<usize> = {
        let mut d = order[..r].to_vec();
        d.sort_unstable();
        d
    };
    let mut assign = vec![usize::MAX; n];
    for (c, &d) in dominants.iter().enumerate() {
        assign[d] = c;
    }
    for e in 0..n {
        if assign[e] != usize::MAX {
            continue;
        }
        let mut best = (0usize, f32::INFINITY);
        for (c, &d) in dominants.iter().enumerate() {
            let dist = l2_dist(&feats[e], &feats[d]);
            if dist < best.1 {
                best = (c, dist);
            }
        }
        assign[e] = best.0;
    }
    Clustering::new(assign, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn dominants_seed_their_own_groups() {
        let feats = vec![vec![0.0], vec![1.0], vec![10.0], vec![11.0]];
        let freqs = vec![5.0, 1.0, 6.0, 1.0];
        let c = single_shot(&feats, &freqs, 2);
        // dominants are experts 0 and 2
        assert_ne!(c.assign[0], c.assign[2]);
        assert_eq!(c.assign[1], c.assign[0], "1 is nearest to dominant 0");
        assert_eq!(c.assign[3], c.assign[2], "3 is nearest to dominant 2");
    }

    #[test]
    fn high_frequency_experts_never_merge() {
        // the paper's critique: the top-r experts each form their own group,
        // even when functionally identical
        let feats = vec![vec![0.0], vec![0.0], vec![100.0]];
        let freqs = vec![9.0, 8.0, 1.0];
        let c = single_shot(&feats, &freqs, 2);
        assert_ne!(c.assign[0], c.assign[1], "identical dominants stay split");
    }

    #[test]
    fn partition_invariants() {
        proptest::check("singleshot-partition", 31, 30, |rng| {
            let n = 2 + rng.below(14);
            let r = 1 + rng.below(n);
            let feats: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.normal() as f32).collect())
                .collect();
            let freqs: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0).collect();
            let c = single_shot(&feats, &freqs, r);
            c.validate().map_err(|e| e.to_string())
        });
    }
}
