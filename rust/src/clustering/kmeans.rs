//! K-means baseline (Table 5): `K-fix` seeds centroids with the first `r`
//! experts, `K-rnd` with `r` random experts — reproducing the paper's
//! initialisation-sensitivity comparison against deterministic HC.

use super::Clustering;
use crate::parallel;
use crate::tensor::l2_dist;
use crate::util::Rng;

/// Centroid initialisation strategy (the paper's fix/rnd comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KmeansInit {
    /// First r experts as initial centers (paper's K-means-fix).
    Fixed,
    /// r random experts as initial centers (paper's K-means-rnd).
    Random {
        /// RNG seed for the center draw.
        seed: u64,
    },
}

/// Nearest center index under the serial tie-break (strict `<` over
/// ascending center index) — the single expression both the serial and the
/// parallel assignment sweeps evaluate per point.
#[inline]
fn nearest_center(point: &[f32], centers: &[Vec<f32>]) -> usize {
    let mut best = (0usize, f32::INFINITY);
    for (c, center) in centers.iter().enumerate() {
        let d = l2_dist(point, center);
        if d < best.1 {
            best = (c, d);
        }
    }
    best.0
}

/// K-means with the auto-selected worker count: the per-iteration
/// assignment sweep costs O(n·r·dim), so parallelism engages only when that
/// clears [`parallel::PAR_AUTO_WORK`] (see [`kmeans_with`]).
pub fn kmeans(feats: &[Vec<f32>], r: usize, init: KmeansInit, max_iter: usize) -> Clustering {
    let n = feats.len();
    let dim = feats.first().map_or(0, |f| f.len());
    let threads = if n * r * dim >= parallel::PAR_AUTO_WORK {
        parallel::default_threads()
    } else {
        1
    };
    kmeans_with(feats, r, init, max_iter, threads)
}

/// [`kmeans`] with an explicit worker count for the assignment sweep.
/// Every point's nearest center is an independent computation, so any
/// thread count produces the exact serial clustering
/// (`rust/tests/determinism.rs`).
pub fn kmeans_with(
    feats: &[Vec<f32>],
    r: usize,
    init: KmeansInit,
    max_iter: usize,
    threads: usize,
) -> Clustering {
    let n = feats.len();
    assert!(r >= 1 && r <= n);
    let dim = feats[0].len();
    let init_idx: Vec<usize> = match init {
        KmeansInit::Fixed => (0..r).collect(),
        KmeansInit::Random { seed } => {
            let mut rng = Rng::new(seed);
            rng.choose_distinct(n, r)
        }
    };
    let mut centers: Vec<Vec<f32>> = init_idx.iter().map(|&i| feats[i].clone()).collect();
    let mut assign = vec![0usize; n];
    let mut proposed = vec![0usize; n];
    for _ in 0..max_iter {
        // assignment step (parallel over disjoint point chunks)
        {
            let centers = &centers;
            parallel::par_chunks_mut(threads, &mut proposed, |start, chunk| {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = nearest_center(&feats[start + off], centers);
                }
            });
        }
        let mut changed = false;
        for e in 0..n {
            if assign[e] != proposed[e] {
                assign[e] = proposed[e];
                changed = true;
            }
        }
        // update step
        let mut sums = vec![vec![0f32; dim]; r];
        let mut cnt = vec![0usize; r];
        for e in 0..n {
            cnt[assign[e]] += 1;
            for j in 0..dim {
                sums[assign[e]][j] += feats[e][j];
            }
        }
        for c in 0..r {
            if cnt[c] == 0 {
                // empty cluster: steal the point farthest from its center
                let far = (0..n)
                    .max_by(|&a, &b| {
                        l2_dist(&feats[a], &centers[assign[a]])
                            .partial_cmp(&l2_dist(&feats[b], &centers[assign[b]]))
                            .unwrap()
                    })
                    .unwrap();
                assign[far] = c;
                centers[c] = feats[far].clone();
                continue;
            }
            for j in 0..dim {
                centers[c][j] = sums[c][j] / cnt[c] as f32;
            }
        }
        if !changed {
            break;
        }
    }
    // final repair: guarantee no empty cluster (validate() invariant)
    let mut groups = vec![Vec::new(); r];
    for (e, &c) in assign.iter().enumerate() {
        groups[c].push(e);
    }
    for c in 0..r {
        if groups[c].is_empty() {
            // take a member from the largest cluster
            let donor = (0..r).max_by_key(|&g| groups[g].len()).unwrap();
            let e = groups[donor].pop().unwrap();
            assign[e] = c;
            groups[c].push(e);
        }
    }
    Clustering::new(assign, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn recovers_blobs() {
        let pts = vec![
            vec![0.0, 0.0],
            vec![0.2, 0.1],
            vec![9.0, 9.0],
            vec![9.1, 9.2],
        ];
        let c = kmeans(&pts, 2, KmeansInit::Fixed, 50);
        assert_eq!(c.assign[0], c.assign[1]);
        assert_eq!(c.assign[2], c.assign[3]);
        assert_ne!(c.assign[0], c.assign[2]);
    }

    #[test]
    fn random_init_varies_but_fixed_does_not() {
        // a deliberately ambiguous configuration: equally spaced points
        let pts: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32]).collect();
        let a = kmeans(&pts, 3, KmeansInit::Fixed, 100);
        let b = kmeans(&pts, 3, KmeansInit::Fixed, 100);
        assert_eq!(a, b, "fixed init must be deterministic");
        // different seeds can produce different partitions (the paper's
        // instability point); we only require both remain valid
        let r1 = kmeans(&pts, 3, KmeansInit::Random { seed: 1 }, 100);
        let r2 = kmeans(&pts, 3, KmeansInit::Random { seed: 2 }, 100);
        r1.validate().unwrap();
        r2.validate().unwrap();
    }

    #[test]
    fn partition_invariants() {
        proptest::check("kmeans-partition", 23, 30, |rng| {
            let n = 2 + rng.below(14);
            let r = 1 + rng.below(n);
            let pts: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.normal() as f32).collect())
                .collect();
            for init in [KmeansInit::Fixed, KmeansInit::Random { seed: rng.next_u64() }] {
                let c = kmeans(&pts, r, init, 50);
                c.validate().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
