//! Variant lifecycle: identity, load, retirement and atomic hot-swap of
//! served model variants.
//!
//! Before this module, variant handling was scattered — the serving
//! executor built its primary/drafter variants inline, `CompressedModel`
//! owned loading, and the native backend hashed variant identity ad hoc
//! for KV prefix sharing. The [`VariantRegistry`] centralises the
//! lifecycle:
//!
//! * **Identity** — every registered variant carries a fingerprint
//!   derived from its weight content ([`crate::weights::Weights::content_hash`]),
//!   the same component the native backend folds into every KV-cache
//!   fingerprint. Two variants with different weights can therefore never
//!   alias prefix blocks, even across a hot swap at identical mask/remap.
//! * **Load** — [`build_primary`] / [`build_drafter`] own the startup
//!   builds that used to live inline in the executor loop, and
//!   [`recompress`] is the background path: live routing counts reweight
//!   the calibration statistics ([`crate::calib::CalibStats::reweighted`])
//!   and the ordinary cluster→merge/prune(→quantize) pipeline runs on a
//!   private [`ModelContext`] off the executor thread.
//! * **Retirement** — variants are held in [`Arc`]s; in-flight sequences
//!   pin the variant they started on, so a [`VariantRegistry::swap`]
//!   retires the old variant *logically* (new work routes to the new one)
//!   while its weights stay resident exactly until the last pin drops.
//!   [`VariantRegistry::resident`] counts what is still alive.
//!
//! The registry itself is single-threaded state owned by the serving
//! executor (see `SERVING.md` §"Adaptive compression & hot swap");
//! everything crossing threads is plain data ([`CompressedModel`]).

use anyhow::{anyhow, Result};
use std::sync::{Arc, Weak};

use crate::calib::CalibStats;
use crate::config::Artifacts;
use crate::model::{CompactModel, LoadedModel, ModelContext};
use crate::pipeline::{CompressedModel, Method, Pipeline};

/// One registered model variant: a backend-resident [`LoadedModel`] plus
/// its registry identity. Held in an [`Arc`] — clones pin the variant's
/// weights resident (retirement frees them when the last pin drops).
pub struct Variant {
    /// The runnable variant (resident weights + router mask + label).
    pub model: LoadedModel,
    /// Weight-content fingerprint ([`crate::weights::Weights::content_hash`]):
    /// the identity KV prefix sharing and swap deduplication key on.
    pub fingerprint: u64,
    /// Monotone swap generation: 0 for the startup variant, +1 per swap.
    pub generation: u64,
}

/// What a [`VariantRegistry::swap`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapOutcome {
    /// The new variant is now active; the previous one (fingerprint
    /// given) is retired and will free once its last pin drops.
    Swapped {
        /// Fingerprint of the variant that was retired.
        retired: u64,
    },
    /// The candidate had the active variant's fingerprint — identical
    /// weights, nothing to do (the candidate is dropped).
    Unchanged,
}

/// Owner of the active variant, the optional resident drafter, and the
/// retired-variant ledger.
pub struct VariantRegistry {
    active: Arc<Variant>,
    drafter: Option<Arc<CompactModel>>,
    /// Weak handles to retired variants: an upgradeable entry means some
    /// in-flight sequence still pins the old weights resident.
    retired: Vec<Weak<Variant>>,
    swaps: u64,
}

impl VariantRegistry {
    /// Register the startup variant (generation 0) and optional drafter.
    pub fn new(primary: Variant, drafter: Option<CompactModel>) -> Self {
        Self {
            active: Arc::new(primary),
            drafter: drafter.map(Arc::new),
            retired: Vec::new(),
            swaps: 0,
        }
    }

    /// Pin the active variant. New sequences bind to this handle at
    /// admission and keep it for their whole life, swaps notwithstanding.
    pub fn active(&self) -> Arc<Variant> {
        Arc::clone(&self.active)
    }

    /// Pin the resident drafter, if one was configured. The drafter is
    /// deliberately static across swaps: draft tokens are *proposals*
    /// verified by the (possibly swapped) full model, so a stale drafter
    /// costs acceptance rate, never correctness.
    pub fn drafter(&self) -> Option<Arc<CompactModel>> {
        self.drafter.as_ref().map(Arc::clone)
    }

    /// Atomically make `model` the active variant. A candidate whose
    /// fingerprint equals the active one is dropped ([`SwapOutcome::Unchanged`]);
    /// otherwise the old variant retires — still resident while pinned by
    /// in-flight sequences, freed when the last pin drops.
    pub fn swap(&mut self, model: LoadedModel, fingerprint: u64) -> SwapOutcome {
        if fingerprint == self.active.fingerprint {
            return SwapOutcome::Unchanged;
        }
        let next = Arc::new(Variant {
            model,
            fingerprint,
            generation: self.active.generation + 1,
        });
        let old = std::mem::replace(&mut self.active, next);
        let retired = old.fingerprint;
        self.retired.push(Arc::downgrade(&old));
        self.retired.retain(|w| w.strong_count() > 0);
        self.swaps += 1;
        SwapOutcome::Swapped { retired }
    }

    /// Swaps performed since startup.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Variants whose weights are currently resident: the active one plus
    /// every retired variant still pinned by an in-flight sequence.
    pub fn resident(&self) -> usize {
        1 + self.retired.iter().filter(|w| w.strong_count() > 0).count()
    }
}

/// Build the primary served variant: the original model, or the
/// `(method, r, calib domain)` compression the spec asked for — the
/// startup build that used to live inline in the serving executor loop.
pub fn build_primary(
    ctx: &ModelContext,
    compress: &Option<(Method, usize, String)>,
) -> Result<Variant> {
    let (model, fingerprint) = match compress {
        None => (ctx.load_original()?, ctx.base.content_hash()),
        Some((method, r, domain)) => {
            let stats: CalibStats = ctx.calibrate(domain)?;
            let plan = Pipeline::new(method.clone()).plan(ctx, &stats, *r)?;
            let cm = plan.apply(ctx, &stats)?;
            let fp = cm.weights.content_hash();
            (cm.load(ctx)?, fp)
        }
    };
    Ok(Variant { model, fingerprint, generation: 0 })
}

/// Build the resident speculative drafter: a TRUE r-expert compact export
/// (r physical slots + router remap), not a masked full layout — drafting
/// forwards must be cheaper than verify forwards.
pub fn build_drafter(
    ctx: &ModelContext,
    drafter: &Option<(Method, usize, String)>,
) -> Result<Option<CompactModel>> {
    let Some((method, r, domain)) = drafter else { return Ok(None) };
    let stats: CalibStats = ctx.calibrate(domain)?;
    let plan = Pipeline::new(method.clone()).plan(ctx, &stats, *r)?;
    let cm = plan.apply(ctx, &stats)?;
    let (cw, remap) = cm.to_compact(ctx)?;
    Ok(Some(ctx.load_compact(*r, &cw, remap, &format!("{} [drafter]", cm.label))?))
}

/// Background recompression: compress `model` under `method`/`r` with the
/// calibration statistics of `domain` reweighted by `live_counts` (one
/// `[n_exp]` dispatch row per layer — a live routing window), optionally
/// quantizing the result. Loads a **private** [`ModelContext`] so it can
/// run on a worker thread while the executor keeps serving; everything
/// returned is plain data for the executor to load and swap in.
/// Recompression always starts from the pristine base weights — variants
/// never compound.
pub fn recompress(
    artifacts_root: &str,
    model: &str,
    method: &Method,
    r: usize,
    domain: &str,
    quantize: bool,
    live_counts: &[Vec<u64>],
) -> Result<CompressedModel> {
    let arts = Artifacts::new(artifacts_root);
    let ctx = ModelContext::load(&arts, model)?;
    let stats = ctx
        .calibrate(domain)?
        .reweighted(live_counts)
        .map_err(|e| anyhow!("live routing window does not fit the model: {e}"))?;
    let plan = Pipeline::new(method.clone()).plan(&ctx, &stats, r)?;
    let cm = plan.apply(&ctx, &stats)?;
    if quantize {
        cm.quantize()
    } else {
        Ok(cm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelCfg;
    use crate::weights::Weights;

    fn cfg() -> ModelCfg {
        ModelCfg {
            name: "vr".into(),
            n_layer: 2,
            d: 8,
            m: 8,
            n_exp: 4,
            k: 2,
            heads: 2,
            vocab: 24,
            t_max: 32,
            shared: false,
            m_shared: 8,
            cap_factor: 4.0,
            block_c: 4,
        }
    }

    /// A registry lives entirely off plain loaded models, so it is
    /// testable without artifacts: swap semantics, dedup on identical
    /// fingerprints, and retirement tracking via pins.
    #[test]
    fn swap_retires_and_dedupes() {
        let cfg = cfg();
        let w1 = Weights::synthesize(&cfg, 1);
        let w2 = Weights::synthesize(&cfg, 2);
        let backend = crate::backend::native::NativeBackend::new(cfg.clone());
        let load = |w: &Weights, label: &str| {
            use crate::backend::Backend;
            let state = backend.load_model(w, cfg.n_exp).unwrap();
            LoadedModel::from_parts(state, vec![0.0; cfg.n_layer * cfg.n_exp], label)
        };
        let fp1 = w1.content_hash();
        let fp2 = w2.content_hash();
        let mut reg = VariantRegistry::new(
            Variant { model: load(&w1, "v1"), fingerprint: fp1, generation: 0 },
            None,
        );
        assert_eq!(reg.swaps(), 0);
        assert_eq!(reg.resident(), 1);

        // identical weights: the swap is a no-op
        assert_eq!(reg.swap(load(&w1, "v1b"), fp1), SwapOutcome::Unchanged);
        assert_eq!(reg.swaps(), 0);

        // a pinned old variant survives the swap; unpinning frees it
        let pin = reg.active();
        assert_eq!(reg.swap(load(&w2, "v2"), fp2), SwapOutcome::Swapped { retired: fp1 });
        assert_eq!(reg.swaps(), 1);
        assert_eq!(reg.active().fingerprint, fp2);
        assert_eq!(reg.active().generation, 1);
        assert_eq!(reg.resident(), 2, "in-flight pin keeps the old weights resident");
        drop(pin);
        assert_eq!(reg.resident(), 1, "last pin dropped frees the retired variant");
    }
}
