//! `hc-smoe` — command-line driver for the HC-SMoE compression toolchain.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//!   info                         artifact + model summary
//!   synth      [--out DIR] [--seed N]   write a synthetic artifact set
//!   calibrate  <model> [domain]  run the calibration pass, print stats
//!   compress   <model> <r> [--method M] [--domain D]   compress + report
//!   eval       <model> <r> [--method M] [--domain D] [--tasks a,b]
//!   serve      <model> [--r R --method M] [--requests N] [--adaptive]
//!              [--replicas N] [--http ADDR]
//!   generate   <model> [--prompt 1,4,20] [--max-tokens N] [--sample]
//!              [--top-k K --temperature T --seed S] [--r R --method M]
//!              [--compact] [--speculative --draft-k K]
//!                                       KV-cached autoregressive decode
//!   quality    <model> <r> [--method M]  cluster-quality metrics
//!
//! Methods: hc-avg (default), hc-single, hc-complete, kmeans-fix,
//! kmeans-rnd, fcm, single-shot, m-smoe, o-prune, s-prune, f-prune, hc-nu.
//!
//! Artifacts resolve through `bench_support::ensure_artifacts`: real AOT
//! output is used when present, otherwise a deterministic synthetic set is
//! generated so every command runs offline on the native backend.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use hc_smoe::clustering::{KmeansInit, Linkage};
use hc_smoe::config::Artifacts;
use hc_smoe::eval::Evaluator;
use hc_smoe::merging::MergeStrategy;
use hc_smoe::model::ModelContext;
use hc_smoe::pipeline::{compressed_params, Method, Pipeline};
use hc_smoe::report::Table;
use hc_smoe::serving::net::serve_http;
use hc_smoe::serving::{AdaptSpec, BatcherConfig, Dispatcher, ServeSpec};
use hc_smoe::similarity::Metric;
use hc_smoe::util::Timer;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: positional args + `--key value` pairs.
struct Args {
    pos: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut pos = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                // `--key value` pairs; a `--key` followed by another flag
                // (or nothing) is a bare boolean flag like --sample
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), String::new());
                        i += 1;
                    }
                }
            } else {
                pos.push(argv[i].clone());
                i += 1;
            }
        }
        Self { pos, flags }
    }

    fn flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

pub fn parse_method(name: &str, seed: u64) -> Result<Method> {
    let default_merge = MergeStrategy::Frequency;
    Ok(match name {
        "hc-avg" | "hc" => Method::HcSmoe {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "hc-single" => Method::HcSmoe {
            linkage: Linkage::Single,
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "hc-complete" => Method::HcSmoe {
            linkage: Linkage::Complete,
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "hc-nu" => Method::HcNonUniform {
            linkage: Linkage::Average,
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "kmeans-fix" => Method::KMeans {
            init: KmeansInit::Fixed,
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "kmeans-rnd" => Method::KMeans {
            init: KmeansInit::Random { seed },
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "fcm" => Method::Fcm { seed },
        "single-shot" => Method::SingleShot {
            metric: Metric::ExpertOutput,
            merge: default_merge,
        },
        "m-smoe" => Method::MSmoe,
        "o-prune" => Method::OPrune { samples: 10_000, seed },
        "s-prune" => Method::SPrune,
        "f-prune" => Method::FPrune,
        other => bail!("unknown method {other:?} (see --help)"),
    })
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    if cmd == "synth" {
        return synth(&args);
    }
    let arts = hc_smoe::bench_support::ensure_artifacts()?;
    match cmd.as_str() {
        "info" => info(&arts),
        "calibrate" => calibrate(&arts, &args),
        "compress" => compress(&arts, &args),
        "eval" => eval(&arts, &args),
        "serve" => serve_cmd(&arts, &args),
        "generate" => generate_cmd(&arts, &args),
        "quality" => quality(&arts, &args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}"),
    }
}

fn print_help() {
    println!(
        "hc-smoe {} — retraining-free SMoE expert merging (ICML 2025 reproduction)

USAGE: hc-smoe <command> [args]

COMMANDS:
  info                          artifact + model summary
  synth     [--out DIR] [--seed N]   write a synthetic artifact set
  calibrate <model> [--domain D]
  compress  <model> <r> [--method M] [--domain D]
  eval      <model> <r> [--method M] [--domain D] [--tasks a,b,..]
  serve     <model> [--r R] [--method M] [--requests N] [--adaptive]
            [--replicas N] [--http ADDR]
  generate  <model> [--prompt 1,4,20,3] [--max-tokens N] [--sample]
            [--top-k K] [--temperature T] [--seed S] [--eos TOK]
            [--r R] [--method M] [--domain D] [--compact]
            [--speculative] [--draft-k K]
  quality   <model> <r> [--method M]

METHODS: hc-avg hc-single hc-complete hc-nu kmeans-fix kmeans-rnd fcm
         single-shot m-smoe o-prune s-prune f-prune

ENV: HCSMOE_ARTIFACTS (default ./artifacts, falling back to a synthesized
     ./artifacts-synth), HCSMOE_BACKEND (native | pjrt, default native),
     HCSMOE_ADAPT_WINDOW / HCSMOE_ADAPT_MIN_TOKENS (serve --adaptive),
     HCSMOE_REPLICAS / HCSMOE_HTTP_ADDR (serve scale-out + front end),
     HCSMOE_EXPERT_SHARDS (native expert-parallel sharding)",
        hc_smoe::version()
    );
}

fn synth(args: &Args) -> Result<()> {
    let out = args.flag("out", hc_smoe::bench_support::synth::SYNTH_DIR);
    let seed: u64 = args
        .flag("seed", &hc_smoe::bench_support::synth::SYNTH_SEED.to_string())
        .parse()
        .context("parsing --seed")?;
    hc_smoe::bench_support::synthesize_artifacts(&out, seed)?;
    println!("wrote synthetic artifact set to {out} (seed {seed})");
    println!("use it with: HCSMOE_ARTIFACTS={out} hc-smoe info");
    Ok(())
}

fn info(arts: &Artifacts) -> Result<()> {
    let m = arts.manifest().context("artifacts unreadable")?;
    println!("artifacts: {}", arts.root.display());
    println!("tasks: {}", m.tasks.join(", "));
    for name in &m.models {
        let cfg = arts.model_cfg(name)?;
        println!(
            "model {name}: L={} d={} m={} n={} top-{} shared={} params={:.2}M reductions={:?}",
            cfg.n_layer,
            cfg.d,
            cfg.m,
            cfg.n_exp,
            cfg.k,
            cfg.shared,
            cfg.total_params(cfg.n_exp) as f64 / 1e6,
            m.reductions[name]
        );
    }
    Ok(())
}

fn calibrate(arts: &Artifacts, args: &Args) -> Result<()> {
    let model = args.pos.first().context("need <model>")?;
    let domain = args.flag("domain", "general");
    let ctx = ModelContext::load(arts, model)?;
    let t = Timer::start();
    let stats = ctx.calibrate(&domain)?;
    println!("calibrated {model} on {domain}: {} tokens in {:.1}s", stats.n_tokens, t.secs());
    let mut table = Table::new(
        &format!("Expert routing frequencies ({model}, {domain})"),
        &["layer", "top expert", "max freq", "min freq", "entropy"],
    );
    for (l, ls) in stats.layers.iter().enumerate() {
        let total: f32 = ls.counts.iter().sum();
        let probs: Vec<f64> = ls.counts.iter().map(|&c| (c / total) as f64).collect();
        let ent: f64 = -probs.iter().filter(|&&p| p > 0.0).map(|p| p * p.ln()).sum::<f64>();
        let (top, max) = ls
            .counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let min = ls.counts.iter().cloned().fold(f32::INFINITY, f32::min);
        table.row(vec![
            l.to_string(),
            top.to_string(),
            format!("{:.4}", max / total),
            format!("{:.4}", min / total),
            format!("{ent:.3}"),
        ]);
    }
    table.print();
    Ok(())
}

fn compress(arts: &Artifacts, args: &Args) -> Result<()> {
    let model = args.pos.first().context("need <model>")?;
    let r: usize = args.pos.get(1).context("need <r>")?.parse()?;
    let method = parse_method(&args.flag("method", "hc-avg"), 42)?;
    let domain = args.flag("domain", "general");
    let ctx = ModelContext::load(arts, model)?;
    let stats = ctx.calibrate(&domain)?;
    let t = Timer::start();
    let plan = Pipeline::new(method).plan(&ctx, &stats, r)?;
    let compressed = plan.apply(&ctx, &stats)?;
    println!(
        "{}: {} -> {} experts/layer in {:.2}s; params {:.2}M -> {:.2}M",
        compressed.label,
        ctx.cfg.n_exp,
        r,
        t.secs(),
        ctx.cfg.total_params(ctx.cfg.n_exp) as f64 / 1e6,
        compressed_params(&ctx.cfg, &plan.experts_per_layer()) as f64 / 1e6,
    );
    if let Some(out) = args.flags.get("out") {
        compressed.weights.save(out)?;
        println!("wrote merged weights to {out}");
    }
    Ok(())
}

fn eval(arts: &Artifacts, args: &Args) -> Result<()> {
    let model = args.pos.first().context("need <model>")?;
    let r: usize = args.pos.get(1).context("need <r>")?.parse()?;
    let domain = args.flag("domain", "general");
    let ctx = ModelContext::load(arts, model)?;
    let tasks: Vec<String> = match args.flags.get("tasks") {
        Some(t) => t.split(',').map(|s| s.trim().to_string()).collect(),
        None => ctx.manifest.tasks.clone(),
    };
    let ev = Evaluator::new(&ctx)?;
    let mut headers: Vec<String> = vec!["Method".into()];
    headers.extend(tasks.iter().cloned());
    headers.push("Average".into());
    let mut table = Table::new(
        &format!("Zero-shot accuracy ({model}, r={r}, calib={domain})"),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // original
    let orig = ctx.load_original()?;
    let (scores, avg) = ev.eval_suite(&orig, &tasks)?;
    let mut row: Vec<f64> = scores.iter().map(|(_, a)| *a).collect();
    row.push(avg);
    table.row_scores("None", &row);
    // compressed
    let method = parse_method(&args.flag("method", "hc-avg"), 42)?;
    let stats = ctx.calibrate(&domain)?;
    let plan = Pipeline::new(method).plan(&ctx, &stats, r)?;
    let compressed = plan.apply(&ctx, &stats)?;
    let loaded = compressed.load(&ctx)?;
    let (scores, avg) = ev.eval_suite(&loaded, &tasks)?;
    let mut row: Vec<f64> = scores.iter().map(|(_, a)| *a).collect();
    row.push(avg);
    table.row_scores(&compressed.label, &row);
    table.print();
    Ok(())
}

fn serve_cmd(arts: &Artifacts, args: &Args) -> Result<()> {
    let model = args.pos.first().context("need <model>")?;
    let n_requests: usize = args.flag("requests", "64").parse()?;
    let compress = match args.flags.get("r") {
        Some(r) => Some((
            parse_method(&args.flag("method", "hc-avg"), 42)?,
            r.parse::<usize>()?,
            args.flag("domain", "general"),
        )),
        None => None,
    };
    // --adaptive: recompress from live routing stats and hot-swap variants
    // while serving; the policy (method/r/domain) mirrors --r/--method,
    // defaulting to r = n_exp/2 when --r is absent. Window and warm-up
    // resolve HCSMOE_ADAPT_WINDOW / HCSMOE_ADAPT_MIN_TOKENS.
    let ctx = ModelContext::load(arts, model)?;
    let adapt = if args.flags.contains_key("adaptive") {
        let r = match args.flags.get("r") {
            Some(r) => r.parse::<usize>()?,
            None => (ctx.cfg.n_exp / 2).max(1),
        };
        Some(AdaptSpec {
            method: parse_method(&args.flag("method", "hc-avg"), 42)?,
            r,
            domain: args.flag("domain", "general"),
            quantize: false,
            window_tokens: None,
            min_tokens: None,
        })
    } else {
        None
    };
    let bench = hc_smoe::data::Benchmark::load(ctx.arts.benchmark("arc_e"))?;
    let spec = ServeSpec {
        artifacts_root: arts.root.to_string_lossy().into_owned(),
        model: model.clone(),
        compress,
        kv_budget_bytes: None,
        prefill_chunk: None,
        drafter: None,
        adapt,
    };
    // --replicas N launches N full executors behind the dispatcher
    // (falling back to HCSMOE_REPLICAS, default 1 — the historical
    // single-executor behaviour); --http ADDR (or HCSMOE_HTTP_ADDR)
    // additionally exposes the fleet over the streaming HTTP front end
    // for the duration of the run, then drains it gracefully.
    let replicas = match args.flags.get("replicas") {
        Some(v) => Some(v.parse::<usize>().context("parsing --replicas")?),
        None => None,
    };
    let dispatcher = std::sync::Arc::new(Dispatcher::launch(
        spec,
        BatcherConfig { max_rows: ctx.manifest.eval_b, max_wait: Duration::from_millis(5) },
        replicas,
    )?);
    let http = match hc_smoe::config::env::http_addr(args.flags.get("http").cloned())? {
        Some(addr) => {
            let s = serve_http(std::sync::Arc::clone(&dispatcher), &addr, 64)?;
            println!("http front end listening on {}", s.addr());
            Some(s)
        }
        None => None,
    };
    let t = Timer::start();
    let mut correct = 0usize;
    for item in bench.items.iter().cycle().take(n_requests) {
        let scores = dispatcher.score_item(&item.prompt, &item.choices)?;
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == item.answer {
            correct += 1;
        }
    }
    let wall = t.secs();
    let snap = dispatcher.merged();
    let per_replica = dispatcher.metrics();
    match http {
        // HttpServer::shutdown drains in-flight streams, then stops the
        // dispatcher it owns
        Some(s) => s.shutdown()?,
        None => dispatcher.shutdown()?,
    }
    println!(
        "served {n_requests} requests in {wall:.2}s ({:.1} req/s, {:.1} rows/s busy, \
         {} batches, fill {:.2}); acc {:.3}",
        n_requests as f64 / wall,
        snap.rows_per_sec(),
        snap.batches,
        snap.mean_batch_fill(ctx.manifest.eval_b),
        correct as f64 / n_requests as f64,
    );
    if per_replica.len() > 1 {
        for (i, r) in per_replica.iter().enumerate() {
            println!(
                "  replica {i}: {} rows, {} batches, {:.1} rows/s busy",
                r.rows,
                r.batches,
                r.rows_per_sec(),
            );
        }
    }
    if args.flags.contains_key("adaptive") {
        println!(
            "adaptive: {} swaps, active variant {:016x}, recompress {:.2}s, \
             window entropy {:.3} bits",
            snap.swaps, snap.active_variant, snap.recompress_s, snap.dispatch_entropy,
        );
    }
    Ok(())
}

/// `hc-smoe generate`: KV-cached autoregressive decode, offline.
///
/// Greedy by default; `--sample` (or any of `--top-k`/`--temperature`)
/// switches to seeded temperature/top-k sampling. The `generated` output
/// line depends only on (artifacts, prompt, sampling parameters) — running
/// the command twice prints the identical token sequence, which is the
/// self-verification hook the README quickstart uses.
///
/// `--speculative --r R` drafts `--draft-k` tokens per round on the compact
/// merged variant and verifies them on the original model in one
/// multi-position forward; the printed tokens are bit-identical to the
/// plain (non-speculative) run on the original model.
fn generate_cmd(arts: &Artifacts, args: &Args) -> Result<()> {
    use hc_smoe::generate::{generate, generate_compact, speculative, SamplingParams};

    let model = args.pos.first().context("need <model>")?;
    let ctx = ModelContext::load(arts, model)?;
    let prompt: Vec<i32> = args
        .flag("prompt", "1,4,20,50,3,5")
        .split(',')
        .map(|x| x.trim().parse::<i32>())
        .collect::<Result<_, _>>()
        .context("parsing --prompt (comma-separated token ids)")?;
    let max_tokens: usize = args.flag("max-tokens", "32").parse()?;
    let eos = match args.flags.get("eos") {
        Some(v) => Some(v.parse::<i32>().context("parsing --eos")?),
        None => None,
    };
    let sample = args.flags.contains_key("sample")
        || args.flags.contains_key("top-k")
        || args.flags.contains_key("temperature");
    let params = if sample {
        SamplingParams::top_k(
            args.flag("top-k", "8").parse()?,
            args.flag("temperature", "0.8").parse()?,
            args.flag("seed", "42").parse()?,
            max_tokens,
            eos,
        )
    } else {
        SamplingParams::greedy(max_tokens, eos)
    };

    let draft_k: usize = args.flag("draft-k", "4").parse().context("parsing --draft-k")?;
    let mut spec_stats: Option<(usize, usize, usize, f64)> = None;
    let (label, out) = match args.flags.get("r") {
        None => {
            if args.flags.contains_key("speculative") {
                bail!("--speculative needs --r R to build the compact drafter");
            }
            let loaded = ctx.load_original()?;
            ("original".to_string(), generate(&ctx, &loaded, &prompt, params)?)
        }
        Some(r) => {
            let r: usize = r.parse()?;
            let method = parse_method(&args.flag("method", "hc-avg"), 42)?;
            let domain = args.flag("domain", "general");
            let stats = ctx.calibrate(&domain)?;
            let plan = Pipeline::new(method).plan(&ctx, &stats, r)?;
            let cm = plan.apply(&ctx, &stats)?;
            if args.flags.contains_key("speculative") {
                let (cw, remap) = cm.to_compact(&ctx)?;
                let drafter = ctx.load_compact(r, &cw, remap, &cm.label)?;
                let full = ctx.load_original()?;
                let so = speculative(&ctx, &full, &drafter, &prompt, params, draft_k)?;
                spec_stats =
                    Some((so.drafted, so.accepted, so.verify_steps, so.acceptance_rate()));
                (format!("original + drafter {} [r={r}, k={draft_k}]", cm.label), so.gen)
            } else if args.flags.contains_key("compact") {
                let (cw, remap) = cm.to_compact(&ctx)?;
                let compact = ctx.load_compact(r, &cw, remap, &cm.label)?;
                let label = format!("{} [compact r={r}]", cm.label);
                (label, generate_compact(&ctx, &compact, &prompt, params)?)
            } else {
                let loaded = cm.load(&ctx)?;
                (cm.label.clone(), generate(&ctx, &loaded, &prompt, params)?)
            }
        }
    };

    println!(
        "model {model} ({} backend), variant {label}, {}",
        ctx.backend_name(),
        if sample { "seeded top-k sampling" } else { "greedy" },
    );
    let fmt = |ts: &[i32]| ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");
    println!("prompt    ({}): {}", prompt.len(), fmt(&prompt));
    println!(
        "generated ({}): {} [finish: {:?}]",
        out.tokens.len(),
        fmt(&out.tokens),
        out.finish
    );
    // the final sampled token is never fed back, so the cache ends at
    // prompt + tokens - 1 entries
    let cached = prompt.len() + out.tokens.len().saturating_sub(1);
    println!(
        "prefill {} tok in {:.2} ms ({:.0} tok/s); decode {} tok in {:.2} ms ({:.0} tok/s); \
         kv cache {} B/token ({} B resident at final length {cached})",
        prompt.len(),
        out.prefill_s * 1e3,
        prompt.len() as f64 / out.prefill_s.max(1e-9),
        out.tokens.len(),
        out.decode_s * 1e3,
        out.decode_tok_s(),
        ctx.cfg.kv_cache_bytes(1),
        ctx.cfg.kv_cache_bytes(cached),
    );
    if let Some((drafted, accepted, verify_steps, rate)) = spec_stats {
        println!(
            "speculative: {accepted}/{drafted} drafts accepted ({:.0}% acceptance) \
             over {verify_steps} verify rounds",
            rate * 100.0,
        );
    }
    Ok(())
}

fn quality(arts: &Artifacts, args: &Args) -> Result<()> {
    let model = args.pos.first().context("need <model>")?;
    let r: usize = args.pos.get(1).context("need <r>")?.parse()?;
    let method = parse_method(&args.flag("method", "hc-avg"), 42)?;
    let domain = args.flag("domain", "general");
    let ctx = ModelContext::load(arts, model)?;
    let stats = ctx.calibrate(&domain)?;
    let plan = Pipeline::new(method).plan(&ctx, &stats, r)?;
    let compressed = plan.apply(&ctx, &stats)?;
    let orig = ctx.load_original()?;
    let loaded = compressed.load(&ctx)?;
    let stream =
        hc_smoe::data::TokenStream::load(ctx.arts.calib_tokens_path("ppl_heldout"))?;
    let (l2, cos) = hc_smoe::quality::output_fidelity(&ctx, &orig, &loaded, &stream, 2)?;
    println!("{}: L2 error {l2:.2}, cosine similarity {cos:.4}", compressed.label);
    Ok(())
}
