//! Dataset loaders: benchmark files (HCEV) and token streams (HCTS) written
//! by `python/compile/data.py`, plus the vocabulary constants mirrored from
//! the Python side (single source of truth documented there).

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian, ReadBytesExt};

pub mod vocab {
    //! Token-class layout (mirror of python/compile/data.py).
    /// Full vocabulary size of the real artifact set.
    pub const VOCAB_SIZE: usize = 448;
    /// Padding token.
    pub const PAD: i32 = 0;
    /// Beginning-of-sequence token.
    pub const BOS: i32 = 1;
    /// End-of-sequence token.
    pub const EOS: i32 = 2;
    /// Separator token.
    pub const SEP: i32 = 3;
    /// Question marker.
    pub const Q: i32 = 4;
    /// Answer marker.
    pub const A: i32 = 5;
    /// `true` answer token (rte-style tasks).
    pub const TRUE_TOK: i32 = 6;
    /// `false` answer token.
    pub const FALSE_TOK: i32 = 7;
    /// `yes` answer token (boolq-style tasks).
    pub const YES_TOK: i32 = 8;
    /// `no` answer token.
    pub const NO_TOK: i32 = 9;
    /// Subject-entity token range `[lo, hi)`.
    pub const SUBJ: (i32, i32) = (16, 48);
    /// Relation token range `[lo, hi)`.
    pub const REL: (i32, i32) = (48, 56);
    /// Object-entity token range `[lo, hi)`.
    pub const OBJ: (i32, i32) = (56, 88);
    /// Digit token range `[lo, hi)`.
    pub const DIGIT: (i32, i32) = (88, 105);
    /// Filler-text token range `[lo, hi)`.
    pub const FILLER: (i32, i32) = (192, 448);
}

/// One multiple-choice item (prompt + per-choice completions).
#[derive(Debug, Clone)]
pub struct MCItem {
    /// Shared prompt tokens.
    pub prompt: Vec<i32>,
    /// Per-choice completion tokens.
    pub choices: Vec<Vec<i32>>,
    /// Gold choice index.
    pub answer: usize,
}

/// A loaded benchmark task.
#[derive(Debug, Clone)]
pub struct Benchmark {
    /// Task name (file stem).
    pub name: String,
    /// Items in file order.
    pub items: Vec<MCItem>,
    /// Choices per item (uniform across the task).
    pub n_choices: usize,
}

impl Benchmark {
    /// Load an HCEV benchmark file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let name = path
            .as_ref()
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut r = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"HCEV" {
            bail!("bad HCEV magic {magic:?}");
        }
        let ver = r.read_u32::<LittleEndian>()?;
        if ver != 1 {
            bail!("unsupported HCEV version {ver}");
        }
        let n_items = r.read_u32::<LittleEndian>()? as usize;
        let n_choices = r.read_u32::<LittleEndian>()? as usize;
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let plen = r.read_u32::<LittleEndian>()? as usize;
            let mut prompt = vec![0i32; plen];
            r.read_i32_into::<LittleEndian>(&mut prompt)?;
            let answer = r.read_u32::<LittleEndian>()? as usize;
            let mut choices = Vec::with_capacity(n_choices);
            for _ in 0..n_choices {
                let clen = r.read_u32::<LittleEndian>()? as usize;
                let mut ch = vec![0i32; clen];
                r.read_i32_into::<LittleEndian>(&mut ch)?;
                choices.push(ch);
            }
            if answer >= n_choices {
                bail!("answer {answer} out of range {n_choices}");
            }
            items.push(MCItem { prompt, choices, answer });
        }
        Ok(Self { name, items, n_choices })
    }

    /// Chance accuracy (random-guess floor, Appendix B.6).
    pub fn chance(&self) -> f64 {
        1.0 / self.n_choices as f64
    }
}

/// Calibration / analysis token stream.
#[derive(Debug, Clone)]
pub struct TokenStream {
    /// Raw token ids, in stream order.
    pub tokens: Vec<i32>,
}

impl TokenStream {
    /// Load an HCTS token-stream file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut r = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"HCTS" {
            bail!("bad HCTS magic {magic:?}");
        }
        let ver = r.read_u32::<LittleEndian>()?;
        if ver != 1 {
            bail!("unsupported HCTS version {ver}");
        }
        let n = r.read_u32::<LittleEndian>()? as usize;
        let mut tokens = vec![0i32; n];
        r.read_i32_into::<LittleEndian>(&mut tokens)?;
        Ok(Self { tokens })
    }

    /// Reshape into [b, t] batches (truncating the tail).
    pub fn batches(&self, b: usize, t: usize) -> Vec<Vec<i32>> {
        self.tokens
            .chunks_exact(b * t)
            .map(|c| c.to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use byteorder::WriteBytesExt;
    use std::io::Write;

    fn write_demo_benchmark(path: &std::path::Path) {
        let mut w = std::fs::File::create(path).unwrap();
        w.write_all(b"HCEV").unwrap();
        w.write_u32::<LittleEndian>(1).unwrap();
        w.write_u32::<LittleEndian>(1).unwrap(); // items
        w.write_u32::<LittleEndian>(2).unwrap(); // choices
        w.write_u32::<LittleEndian>(3).unwrap(); // prompt len
        for t in [4i32, 20, 3] {
            w.write_i32::<LittleEndian>(t).unwrap();
        }
        w.write_u32::<LittleEndian>(1).unwrap(); // answer
        for ch in [[60i32], [61i32]] {
            w.write_u32::<LittleEndian>(1).unwrap();
            w.write_i32::<LittleEndian>(ch[0]).unwrap();
        }
    }

    #[test]
    fn benchmark_roundtrip() {
        let tmp = std::env::temp_dir().join("hcev_test.bin");
        write_demo_benchmark(&tmp);
        let b = Benchmark::load(&tmp).unwrap();
        assert_eq!(b.items.len(), 1);
        assert_eq!(b.n_choices, 2);
        assert_eq!(b.items[0].prompt, vec![4, 20, 3]);
        assert_eq!(b.items[0].answer, 1);
        assert_eq!(b.chance(), 0.5);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn tokenstream_roundtrip() {
        let tmp = std::env::temp_dir().join("hcts_test.bin");
        let mut w = std::fs::File::create(&tmp).unwrap();
        w.write_all(b"HCTS").unwrap();
        w.write_u32::<LittleEndian>(1).unwrap();
        w.write_u32::<LittleEndian>(6).unwrap();
        for t in 0..6i32 {
            w.write_i32::<LittleEndian>(t).unwrap();
        }
        drop(w);
        let ts = TokenStream::load(&tmp).unwrap();
        assert_eq!(ts.tokens, vec![0, 1, 2, 3, 4, 5]);
        let b = ts.batches(1, 3);
        assert_eq!(b.len(), 2);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let tmp = std::env::temp_dir().join("bad_magic.bin");
        std::fs::write(&tmp, b"XXXX0000").unwrap();
        assert!(Benchmark::load(&tmp).is_err());
        assert!(TokenStream::load(&tmp).is_err());
        std::fs::remove_file(tmp).ok();
    }
}
