//! Execution backends: where model forwards actually run.
//!
//! Every consumer of model execution ([`crate::model::ModelContext`], and
//! through it the evaluator, the calibration pass, the serving layer and
//! the bench harness) talks to a [`Backend`] trait object. Two
//! implementations ship:
//!
//! * [`native::NativeBackend`] — a pure-Rust CPU interpreter of the
//!   simulated SMoE transformer family (`qwensim`, `mixsim`, `dssim`). It
//!   executes directly from [`crate::weights::Weights`] + a
//!   [`crate::config::ModelCfg`], needs no HLO artifacts, no PJRT plugin
//!   and no Python, and is the **default**. Its dense matmuls run through
//!   [`crate::tensor::matmul_blocked_with`], so it inherits the
//!   [`crate::parallel`] scoped-pool determinism contract.
//! * [`pjrt::PjrtBackend`] — the original path: compiles the AOT-lowered
//!   HLO text artifacts with the `xla` PJRT bindings and keeps weights
//!   resident as device buffers. Offline builds link the vendored stub, so
//!   this backend constructs but errors on execution until real bindings
//!   are swapped in (see `DESIGN.md`, "Offline-environment notes").
//!
//! Selection is at runtime via the `HCSMOE_BACKEND` environment variable
//! (`native` | `pjrt`, default `native`); no call site changes between
//! them. Model variants are opaque [`ModelState`] handles so each backend
//! can keep whatever resident form it wants (a weight copy for native,
//! device buffers for PJRT).
//!
//! Besides the batched scoring/calibration entry points, the trait exposes
//! an **incremental** pair — [`Backend::run_prefill`] /
//! [`Backend::run_decode`] — for autoregressive generation: prefill runs
//! prompt tokens and hands back an opaque per-sequence [`KvCache`];
//! decode then appends one token at O(t) cost instead of the O(t²) of
//! re-running the full forward per emitted token. `run_prefill` is the
//! **single** prefill entry point: a [`PrefillOpts`] value selects the
//! cache flavor ([`CacheMode::Flat`] buffers or a [`CacheMode::Paged`]
//! block pool) and can resume an existing cache with further prompt
//! tokens (`resume_from`) — the chunked-prefill path the serving
//! scheduler interleaves with decode steps. The native backend implements
//! all of it with per-layer K/V caching; the PJRT backend reports the
//! incremental path as unsupported until incremental HLO entry points are
//! lowered (see `SERVING.md`).

pub mod native;
pub mod pjrt;

use std::any::Any;

use anyhow::{anyhow, Result};

use crate::config::{Artifacts, ModelCfg};
use crate::tensor::Tensor;
use crate::weights::Weights;

/// An opaque, backend-specific resident model variant.
///
/// Created by [`Backend::load_model`] and only meaningful to the backend
/// that produced it; backends downcast via [`ModelState::as_any`].
pub trait ModelState {
    /// Downcast support (each backend recovers its own concrete state).
    fn as_any(&self) -> &dyn Any;
}

/// Opaque per-sequence decode state: one sequence's cached attention K/V
/// (plus whatever bookkeeping the backend needs, e.g. the native backend's
/// cumulative expert-dispatch counts).
///
/// Created by [`Backend::run_prefill`], advanced one token at a time by
/// [`Backend::run_decode`], and owned by the *caller* (the generation loop
/// or the serving executor) — the backend holds no reference between
/// calls, so any number of sequences can be in flight against one
/// [`ModelState`]. The cache is in-memory only and is never serialized
/// (there is deliberately no on-disk format for it — see `FORMATS.md`).
pub trait KvCache {
    /// Downcast support (each backend recovers its own concrete cache).
    fn as_any(&self) -> &dyn Any;
    /// Mutable downcast support ([`Backend::run_decode`] appends in place).
    fn as_any_mut(&mut self) -> &mut dyn Any;
    /// Tokens currently cached (prompt + decoded so far).
    fn seq_len(&self) -> usize;
    /// Resident bytes of the cached K/V tensors (the per-sequence memory
    /// cost documented in `SERVING.md`; matches
    /// [`crate::config::ModelCfg::kv_cache_bytes`] at [`Self::seq_len`]
    /// for the flat cache, and whole-block granularity for the paged one).
    fn byte_size(&self) -> usize;
    /// Bytes actually allocated for the cache (>= [`Self::byte_size`]):
    /// buffer capacity for the flat cache, whole blocks for the paged one.
    /// A decode step that leaves this unchanged did not reallocate — the
    /// `kv_cache_sweep` microbench counts changes to pin the steady-state
    /// no-realloc property.
    fn capacity_bytes(&self) -> usize {
        self.byte_size()
    }
}

/// Where a fresh prefill stores its K/V rows (ignored when
/// [`PrefillOpts::resume_from`] continues an existing cache, which keeps
/// its own storage).
pub enum CacheMode<'a> {
    /// Per-sequence `Vec` buffers, pre-reserved to `t_max` so steady-state
    /// decode never reallocates. The standalone-generation default.
    Flat,
    /// Fixed-size blocks allocated from a shared [`crate::kvpool::KvPool`]
    /// — the memory-budgeted serving path (see `SERVING.md`, "KV memory
    /// model"). `reserve_tokens` is the total sequence length (prompt +
    /// planned decode) whose blocks are reserved up front, so an admitted
    /// sequence can never fail an allocation mid-decode; pass the prompt
    /// length for best-effort decoding.
    Paged {
        /// Pool the sequence's blocks are drawn from.
        pool: &'a crate::kvpool::PoolHandle,
        /// Sequence length (tokens) to reserve blocks for up front.
        reserve_tokens: usize,
    },
}

/// Options for [`Backend::run_prefill`]: router mask/remap, the cache
/// flavor for a fresh sequence, and the optional resume handle that turns
/// the call into a chunk-append on an existing cache.
///
/// Built chainable-style:
///
/// ```ignore
/// let opts = PrefillOpts::new(&mask).remap(&remap).paged(&pool, 40);
/// ```
pub struct PrefillOpts<'a> {
    /// Additive `[n_layer * n_exp]` router mask (same meaning as in
    /// [`Backend::run_logits`]).
    pub mask: &'a [f32],
    /// Optional `[n_layer * n_exp]` expert→slot table for compact
    /// variants.
    pub remap: Option<&'a [i32]>,
    /// Storage for a **fresh** sequence; ignored when `resume_from` is
    /// set.
    pub cache: CacheMode<'a>,
    /// When set, the call appends `ids` to this existing cache (flat or
    /// paged — whatever flavor it was created with) instead of starting a
    /// new sequence, and returns `None` in the cache slot.
    pub resume_from: Option<&'a mut dyn KvCache>,
}

impl<'a> PrefillOpts<'a> {
    /// Flat-cache, full-layout, fresh-sequence options for `mask`.
    pub fn new(mask: &'a [f32]) -> Self {
        Self { mask, remap: None, cache: CacheMode::Flat, resume_from: None }
    }

    /// Route through the compact expert→slot table `remap`.
    pub fn remap(mut self, remap: &'a [i32]) -> Self {
        self.remap = Some(remap);
        self
    }

    /// Store the fresh sequence in `pool` blocks, reserving
    /// `reserve_tokens` tokens' worth up front (see [`CacheMode::Paged`]).
    pub fn paged(mut self, pool: &'a crate::kvpool::PoolHandle, reserve_tokens: usize) -> Self {
        self.cache = CacheMode::Paged { pool, reserve_tokens };
        self
    }

    /// Append `ids` to an existing cache instead of starting a new
    /// sequence (chunked prefill; see [`Backend::run_prefill`]).
    pub fn resume(mut self, cache: &'a mut dyn KvCache) -> Self {
        self.resume_from = Some(cache);
        self
    }
}

/// An opaque point-in-time marker of a [`KvCache`]'s logical state: the
/// sequence length plus the backend's per-layer dispatch bookkeeping at
/// that length. Taken by [`Backend::snapshot_cache`] (or returned per
/// verified position in [`VerifyOut::checkpoints`]) and applied by
/// [`Backend::rollback_cache`] — the speculative-decoding rollback
/// primitive. A snapshot is only valid for the cache it was taken from,
/// and only for rolling *backwards* (`len <= seq_len`); the backend
/// rejects anything else.
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    len: usize,
    /// Per-layer cumulative expert-dispatch counts (`[n_layer][n_slots]`)
    /// at `len` — the native backend's capacity-queue state, which decode
    /// mutates and a rollback must restore exactly.
    counts: Vec<Vec<usize>>,
}

impl CacheSnapshot {
    /// Construct from raw parts (backend-internal; callers obtain
    /// snapshots from [`Backend::snapshot_cache`] / [`VerifyOut`]).
    pub(crate) fn new(len: usize, counts: Vec<Vec<usize>>) -> Self {
        Self { len, counts }
    }

    /// Sequence length the snapshot restores to.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the snapshot marks an empty sequence.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub(crate) fn counts(&self) -> &[Vec<usize>] {
        &self.counts
    }
}

/// Result of a multi-position verify ([`Backend::run_verify`]) for one
/// sequence: the next-token logits after each fed position, plus a cache
/// snapshot *after* each fed position so the caller can roll the cache
/// back to exactly the accepted prefix when a draft token is rejected.
#[derive(Debug)]
pub struct VerifyOut {
    /// `logits[i]` is the `[vocab]` next-token distribution after feeding
    /// `tokens[i]` — bit-identical to what the i-th of k sequential
    /// [`Backend::run_decode`] calls would return.
    pub logits: Vec<Vec<f32>>,
    /// `checkpoints[i]` marks the cache state with `tokens[..=i]` fed
    /// (length = pre-verify length + i + 1). Rolling back to
    /// `checkpoints[i]` leaves the cache exactly as if only the first
    /// `i + 1` tokens had ever been decoded.
    pub checkpoints: Vec<CacheSnapshot>,
}

/// A point-in-time copy of a model variant's live routing statistics:
/// how many token-dispatches each expert slot received per layer since
/// the variant was loaded (see [`Backend::routing_stats`]).
///
/// Deliberately **in-memory only** — there is no on-disk format for it
/// (see `FORMATS.md`): the counts describe one resident variant's
/// traffic window and are meaningless outside the process that observed
/// them. The serving layer converts a windowed snapshot difference into
/// [`crate::calib::CalibStats`]-compatible frequency weights for
/// background recompression (`SERVING.md` §"Adaptive compression & hot
/// swap").
#[derive(Debug, Clone, Default)]
pub struct RoutingSnapshot {
    /// `counts[layer][slot]` = cumulative token-dispatches routed to that
    /// expert slot (post-capacity admissions, so exactly the work the
    /// grouped SwiGLU kernels executed).
    pub counts: Vec<Vec<u64>>,
    /// Cumulative routed **tokens** (layer-0 dispatches ÷ top-k): the
    /// window clock adaptive recompression ticks on.
    pub tokens: u64,
}

impl RoutingSnapshot {
    /// Per-slot dispatch difference `self - earlier` (saturating, so a
    /// mismatched or reset baseline degrades to the full counts instead
    /// of panicking), with `tokens` differenced the same way — the
    /// windowed view between two observation points.
    pub fn since(&self, earlier: &RoutingSnapshot) -> RoutingSnapshot {
        let counts = self
            .counts
            .iter()
            .enumerate()
            .map(|(l, row)| {
                row.iter()
                    .enumerate()
                    .map(|(s, &c)| {
                        c.saturating_sub(
                            earlier.counts.get(l).and_then(|r| r.get(s)).copied().unwrap_or(0),
                        )
                    })
                    .collect()
            })
            .collect();
        RoutingSnapshot { counts, tokens: self.tokens.saturating_sub(earlier.tokens) }
    }

    /// Shannon entropy (bits) of the layer-0 dispatch distribution — the
    /// per-window concentration readout reported by the `adapt_sweep`
    /// bench: `log2(n_slots)` for uniform traffic, approaching 0 as
    /// traffic concentrates on few experts. `0.0` when nothing was
    /// routed.
    pub fn dispatch_entropy(&self) -> f64 {
        let Some(row) = self.counts.first() else { return 0.0 };
        let total: u64 = row.iter().sum();
        if total == 0 {
            return 0.0;
        }
        -row.iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total as f64;
                p * p.log2()
            })
            .sum::<f64>()
    }
}

/// A model-execution engine.
///
/// One backend instance is bound to one model configuration (the
/// [`ModelCfg`] passed at construction). All tensor interfaces mirror the
/// AOT-lowered HLO entry points so the two implementations are
/// interchangeable:
///
/// * `run_logits` is the `lm_logits_*` scoring forward: token ids and an
///   additive router mask in, next-token logits out;
/// * `run_calib` is the `calib_*` statistics pass returning the 8-tuple
///   of per-layer tensors described in [`crate::calib`].
pub trait Backend {
    /// Short backend identifier (`"native"` / `"pjrt"`), used in logs.
    fn name(&self) -> &'static str;

    /// Prepare a weight set for repeated execution.
    ///
    /// `n_slots` is the number of physical expert slots per layer:
    /// `cfg.n_exp` for the full layout (merging duplicates merged experts
    /// into every member slot), or `r < n_exp` for a compact variant
    /// produced by [`crate::weights::Weights::to_compact`].
    fn load_model(&self, weights: &Weights, n_slots: usize) -> Result<Box<dyn ModelState>>;

    /// One scoring forward: `ids` is a flattened `[b, t]` i32 batch,
    /// `mask` the additive `[n_layer * n_exp]` router mask, and `remap`
    /// the optional `[n_layer * n_exp]` expert→slot table used by compact
    /// variants. Returns logits `[b, t, vocab]`.
    fn run_logits(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Tensor>;

    /// One calibration pass over a flattened `[b, t]` batch; returns the
    /// 8 stacked statistics tensors (`mean_out`, `counts`, `probs_sum`,
    /// `gate_sum`, `rl_sub`, `raw_sub`, `act_sub`, `hid_sub` — see
    /// [`crate::calib::LayerStats`]). `t_sub`/`t_act` size the subsampled
    /// profiles.
    fn run_calib(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        t_sub: usize,
        t_act: usize,
    ) -> Result<Vec<Tensor>>;

    /// Incremental inference, part 1 — the **single** prefill entry point
    /// for every cache flavor. Forward `ids` (one sequence) and return the
    /// **last position's** next-token logits (`[vocab]`) plus, for a fresh
    /// sequence, its [`KvCache`]:
    ///
    /// * `opts.resume_from: None` → start a new sequence over the whole
    ///   `ids` prompt, storing K/V per `opts.cache`
    ///   ([`CacheMode::Flat`] buffers or [`CacheMode::Paged`] pool
    ///   blocks), and return `(Some(cache), logits)`.
    /// * `opts.resume_from: Some(cache)` → treat `ids` as the **next
    ///   chunk** of a longer prompt: append its K/V rows to the existing
    ///   cache (whatever flavor it was created with — `opts.cache` is
    ///   ignored) via the decode-path append machinery and return
    ///   `(None, logits)`. This is what the serving scheduler uses to
    ///   interleave long prefills with decode steps (`HCSMOE_PREFILL_CHUNK`).
    ///
    /// Contract (native backend): the logits after prefilling a prompt in
    /// any chunking — whole-prompt, or a fresh call plus any sequence of
    /// resumed chunks — are **bit-identical** to each other, to the flat
    /// vs paged storage choice, and to the last row of
    /// [`Backend::run_logits`] over the same tokens, under the same
    /// drop-free proviso as [`Backend::run_decode`] (each position's
    /// expert-capacity cut is taken at its own sequence length; the
    /// synthesized artifact sets are drop-free, making the equivalence
    /// exact there — `rust/tests/scheduler.rs` pins it). Paged caches
    /// additionally prefix-share their first chunk's full blocks and
    /// release everything on drop, exactly as before
    /// (`rust/tests/kvpool.rs`).
    ///
    /// # Examples
    ///
    /// ```
    /// use hc_smoe::backend::{native::NativeBackend, Backend, KvCache, PrefillOpts};
    /// use hc_smoe::config::ModelCfg;
    /// use hc_smoe::weights::Weights;
    ///
    /// let cfg = ModelCfg {
    ///     name: "demo".into(), n_layer: 1, d: 8, m: 8, n_exp: 2, k: 1,
    ///     heads: 2, vocab: 16, t_max: 8, shared: false, m_shared: 8,
    ///     cap_factor: 4.0, block_c: 1,
    /// };
    /// let w = Weights::synthesize(&cfg, 7);
    /// let backend = NativeBackend::new(cfg.clone());
    /// let state = backend.load_model(&w, cfg.n_exp).unwrap();
    /// let mask = vec![0.0; cfg.n_layer * cfg.n_exp];
    ///
    /// let (cache, logits) = backend
    ///     .run_prefill(state.as_ref(), &[1, 4, 9], PrefillOpts::new(&mask))
    ///     .unwrap();
    /// let cache = cache.expect("fresh prefill returns a cache");
    /// assert_eq!(cache.seq_len(), 3);
    /// assert_eq!(logits.len(), cfg.vocab);
    ///
    /// // bit-identical to the last row of the full scoring forward
    /// let full = backend.run_logits(state.as_ref(), &[1, 4, 9], 1, 3, &mask, None).unwrap();
    /// assert_eq!(&full.data()[2 * cfg.vocab..], &logits[..]);
    ///
    /// // ... and to prefilling the same prompt in two chunks
    /// let (chunk_cache, _) = backend
    ///     .run_prefill(state.as_ref(), &[1, 4], PrefillOpts::new(&mask))
    ///     .unwrap();
    /// let mut chunk_cache = chunk_cache.unwrap();
    /// let resumed = backend
    ///     .run_prefill(state.as_ref(), &[9], PrefillOpts::new(&mask).resume(chunk_cache.as_mut()))
    ///     .unwrap();
    /// assert!(resumed.0.is_none());
    /// assert_eq!(chunk_cache.seq_len(), 3);
    /// assert_eq!(resumed.1, logits);
    /// ```
    fn run_prefill(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        opts: PrefillOpts<'_>,
    ) -> Result<(Option<Box<dyn KvCache>>, Vec<f32>)>;

    /// Incremental inference, part 2: append **one** token to a sequence
    /// and return the next-token logits (`[vocab]`) at the new position.
    /// Cost is O(t) in the sequence length (one attention row against the
    /// cached K/V) instead of the O(t²) a full re-forward pays.
    ///
    /// Contract (native backend): feeding the same token sequence through
    /// `run_prefill` + repeated `run_decode` yields, at every position,
    /// logits bit-identical to `run_logits` over that prefix — provided no
    /// expert capacity drop occurs on an *earlier* position (capacity
    /// grows with sequence length, so a previously dropped token could be
    /// re-admitted by a longer forward; the cache stores earlier positions
    /// as computed at their own step). The synthesized artifact sets are
    /// dispatch-drop-free by construction, making the equivalence exact
    /// there; `rust/tests/generate.rs` pins it.
    ///
    /// # Examples
    ///
    /// ```
    /// use hc_smoe::backend::{native::NativeBackend, Backend, KvCache, PrefillOpts};
    /// use hc_smoe::config::ModelCfg;
    /// use hc_smoe::weights::Weights;
    ///
    /// let cfg = ModelCfg {
    ///     name: "demo".into(), n_layer: 1, d: 8, m: 8, n_exp: 2, k: 1,
    ///     heads: 2, vocab: 16, t_max: 8, shared: false, m_shared: 8,
    ///     cap_factor: 4.0, block_c: 1,
    /// };
    /// let w = Weights::synthesize(&cfg, 7);
    /// let backend = NativeBackend::new(cfg.clone());
    /// let state = backend.load_model(&w, cfg.n_exp).unwrap();
    /// let mask = vec![0.0; cfg.n_layer * cfg.n_exp];
    ///
    /// let (cache, _) = backend
    ///     .run_prefill(state.as_ref(), &[1, 4], PrefillOpts::new(&mask))
    ///     .unwrap();
    /// let mut cache = cache.unwrap();
    /// let step = backend.run_decode(state.as_ref(), cache.as_mut(), 9, &mask, None).unwrap();
    /// assert_eq!(cache.seq_len(), 3);
    ///
    /// // identical to scoring the extended sequence from scratch
    /// let full = backend.run_logits(state.as_ref(), &[1, 4, 9], 1, 3, &mask, None).unwrap();
    /// assert_eq!(&full.data()[2 * cfg.vocab..], &step[..]);
    /// ```
    fn run_decode(
        &self,
        state: &dyn ModelState,
        cache: &mut dyn KvCache,
        token: i32,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<f32>>;

    /// Batched incremental inference: advance **every** sequence in
    /// `caches` by one token in a single call, returning one `[vocab]`
    /// logits row per sequence (index-aligned with `caches`/`tokens`).
    /// This is the continuous-batching hot path: a decode step over N
    /// active sequences must cost less than N independent
    /// [`Backend::run_decode`] calls for the batcher to scale.
    ///
    /// The native backend shares every weight-side GEMM across the batch
    /// (one `[B, d] × [d, ·]` product per attention/router/head
    /// projection) and gathers routed tokens across sequences into
    /// per-expert row blocks (one SwiGLU GEMM per expert per step), while
    /// attention scores and the capacity-dispatch queue stay per-sequence
    /// against each cache. Sequences may have different lengths.
    ///
    /// Contract (native backend): the returned row for sequence `i` is
    /// **bit-identical** to what a standalone `run_decode` on that cache
    /// would produce — batching changes wall-clock, never results
    /// (`rust/tests/decode_batch.rs` pins this across layouts, mixed
    /// lengths and join/leave patterns).
    ///
    /// # Examples
    ///
    /// ```
    /// use hc_smoe::backend::{native::NativeBackend, Backend, KvCache, PrefillOpts};
    /// use hc_smoe::config::ModelCfg;
    /// use hc_smoe::weights::Weights;
    ///
    /// let cfg = ModelCfg {
    ///     name: "demo".into(), n_layer: 1, d: 8, m: 8, n_exp: 2, k: 1,
    ///     heads: 2, vocab: 16, t_max: 8, shared: false, m_shared: 8,
    ///     cap_factor: 4.0, block_c: 1,
    /// };
    /// let w = Weights::synthesize(&cfg, 7);
    /// let backend = NativeBackend::new(cfg.clone());
    /// let state = backend.load_model(&w, cfg.n_exp).unwrap();
    /// let mask = vec![0.0; cfg.n_layer * cfg.n_exp];
    ///
    /// // two sequences of different lengths decode together
    /// let prefill = |ids: &[i32]| {
    ///     let (c, _) = backend.run_prefill(state.as_ref(), ids, PrefillOpts::new(&mask)).unwrap();
    ///     c.unwrap()
    /// };
    /// let (mut ca, mut cb) = (prefill(&[1, 4]), prefill(&[2, 7, 9]));
    /// let mut caches: Vec<&mut dyn KvCache> = vec![ca.as_mut(), cb.as_mut()];
    /// let rows = backend
    ///     .run_decode_batch(state.as_ref(), &mut caches, &[5, 3], &mask, None)
    ///     .unwrap();
    /// assert_eq!(rows.len(), 2);
    /// assert_eq!((ca.seq_len(), cb.seq_len()), (3, 4));
    ///
    /// // each row equals the full forward over that sequence's prefix
    /// let full = backend.run_logits(state.as_ref(), &[1, 4, 5], 1, 3, &mask, None).unwrap();
    /// assert_eq!(&full.data()[2 * cfg.vocab..], &rows[0][..]);
    /// ```
    fn run_decode_batch(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[i32],
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<Vec<f32>>>;

    /// Multi-position verify — the speculative-decoding scoring step:
    /// feed `tokens[i]` (a short run of k_i proposed tokens, k_i ≥ 1) to
    /// sequence `i` in **one** batched forward and return the next-token
    /// logits after every fed position, with a [`CacheSnapshot`] per
    /// position so the caller can roll back past the first rejected
    /// draft. Sequences may have different run lengths; a plain decode
    /// step is just `k_i = 1`, so speculative and non-speculative
    /// sequences interleave in the same call.
    ///
    /// All fed positions land in the cache (the cache ends k_i tokens
    /// longer); acceptance is the *caller's* decision, enacted by
    /// [`Backend::rollback_cache`] with the checkpoint of the last
    /// accepted position.
    ///
    /// Contract (native backend): `out[i].logits[j]` is **bit-identical**
    /// to the j-th of k_i sequential [`Backend::run_decode`] calls
    /// feeding the same tokens to the same cache — batching across
    /// sequences and positions changes wall-clock, never results
    /// (`rust/tests/spec_decode.rs` pins this).
    ///
    /// # Examples
    ///
    /// ```
    /// use hc_smoe::backend::{native::NativeBackend, Backend, KvCache, PrefillOpts};
    /// use hc_smoe::config::ModelCfg;
    /// use hc_smoe::weights::Weights;
    ///
    /// let cfg = ModelCfg {
    ///     name: "demo".into(), n_layer: 1, d: 8, m: 8, n_exp: 2, k: 1,
    ///     heads: 2, vocab: 16, t_max: 8, shared: false, m_shared: 8,
    ///     cap_factor: 4.0, block_c: 1,
    /// };
    /// let w = Weights::synthesize(&cfg, 7);
    /// let backend = NativeBackend::new(cfg.clone());
    /// let state = backend.load_model(&w, cfg.n_exp).unwrap();
    /// let mask = vec![0.0; cfg.n_layer * cfg.n_exp];
    ///
    /// let (cache, _) = backend
    ///     .run_prefill(state.as_ref(), &[1, 4], PrefillOpts::new(&mask))
    ///     .unwrap();
    /// let mut cache = cache.unwrap();
    /// let before = backend.snapshot_cache(cache.as_ref()).unwrap();
    ///
    /// // verify two proposed tokens in one call
    /// let mut caches: Vec<&mut dyn KvCache> = vec![cache.as_mut()];
    /// let out = backend
    ///     .run_verify(state.as_ref(), &mut caches, &[&[9, 3]], &mask, None)
    ///     .unwrap();
    /// assert_eq!(out[0].logits.len(), 2);
    /// assert_eq!(cache.seq_len(), 4);
    ///
    /// // position 0's logits equal a plain decode of the same token
    /// backend.rollback_cache(cache.as_mut(), &before).unwrap();
    /// let plain = backend.run_decode(state.as_ref(), cache.as_mut(), 9, &mask, None).unwrap();
    /// assert_eq!(plain, out[0].logits[0]);
    /// assert_eq!(cache.seq_len(), 3);
    /// ```
    fn run_verify(
        &self,
        state: &dyn ModelState,
        caches: &mut [&mut dyn KvCache],
        tokens: &[&[i32]],
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Vec<VerifyOut>>;

    /// Capture the cache's current logical state (length + dispatch
    /// bookkeeping) for a later [`Backend::rollback_cache`]. O(n_layer ·
    /// n_slots) — no K/V rows are copied; rollback truncates in place.
    fn snapshot_cache(&self, cache: &dyn KvCache) -> Result<CacheSnapshot>;

    /// Shrink `cache` back to `snap`'s length, restoring the dispatch
    /// bookkeeping captured in the snapshot and releasing any now-unused
    /// paged blocks (with their reservation — see
    /// `crate::kvpool::PagedSeq::truncate_to`). After the rollback the
    /// cache is functionally identical to one that never decoded past the
    /// snapshot: subsequent decodes produce bit-identical logits
    /// (`rust/tests/spec_decode.rs` pins byte-equality of the live K/V
    /// region against a freshly prefilled prefix). Errors if `snap` is
    /// *ahead* of the cache (snapshots only roll backwards).
    fn rollback_cache(&self, cache: &mut dyn KvCache, snap: &CacheSnapshot) -> Result<()>;

    /// The variant's cumulative live routing statistics, or `None` when
    /// this backend does not record them (the default — only the native
    /// backend's serving entry points feed the accumulator today).
    /// Recording costs one relaxed atomic add per (expert, dispatch
    /// group) inside `moe_execute`, so reads are cheap point-in-time
    /// copies and never perturb execution. Offline scoring
    /// (`run_logits`) deliberately does NOT record — the accumulator
    /// reflects *served* traffic only.
    fn routing_stats(&self, _state: &dyn ModelState) -> Option<RoutingSnapshot> {
        None
    }
}

/// Environment variable selecting the execution backend (re-exported from
/// [`crate::config::env`], where every runtime knob parses).
pub use crate::config::env::BACKEND_ENV;

/// Construct the backend selected by [`BACKEND_ENV`] (default: native).
/// Parsing/validation lives in [`crate::config::env::backend_kind`];
/// [`crate::config::env::EXPERT_SHARDS_ENV`] configures expert-parallel
/// sharding on the native backend (and is a startup error on `pjrt`,
/// which owns its own intra-op parallelism).
pub fn from_env(arts: &Artifacts, cfg: &ModelCfg) -> Result<Box<dyn Backend>> {
    let shards = crate::config::env::expert_shards(None)?;
    match crate::config::env::backend_kind()? {
        crate::config::env::BackendKind::Native => {
            Ok(Box::new(native::NativeBackend::new(cfg.clone()).with_expert_shards(shards)))
        }
        crate::config::env::BackendKind::Pjrt => {
            anyhow::ensure!(
                shards == 1,
                "{}={} is expert-parallel sharding for the native backend; \
                 the pjrt backend partitions work through its own compiler",
                crate::config::env::EXPERT_SHARDS_ENV,
                shards
            );
            Ok(Box::new(pjrt::PjrtBackend::new(arts.clone(), cfg.clone())?))
        }
    }
}

/// Downcast a [`ModelState`] to the concrete type `T` a backend expects.
pub(crate) fn downcast_state<'a, T: 'static>(
    state: &'a dyn ModelState,
    backend: &str,
) -> Result<&'a T> {
    state
        .as_any()
        .downcast_ref::<T>()
        .ok_or_else(|| anyhow!("model state was not created by the {backend} backend"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_is_the_default_selection() {
        // from_env is driven by the process environment; rather than mutate
        // it (racy across test threads), check the default construction
        // path directly.
        let cfg = crate::config::ModelCfg {
            name: "t".into(),
            n_layer: 1,
            d: 4,
            m: 4,
            n_exp: 2,
            k: 1,
            heads: 1,
            vocab: 8,
            t_max: 8,
            shared: false,
            m_shared: 4,
            cap_factor: 2.0,
            block_c: 1,
        };
        let b = native::NativeBackend::new(cfg);
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn routing_snapshot_windows_and_entropy() {
        let a = RoutingSnapshot { counts: vec![vec![8, 8, 0, 0]], tokens: 8 };
        let b = RoutingSnapshot { counts: vec![vec![24, 8, 0, 0]], tokens: 16 };
        let w = b.since(&a);
        assert_eq!(w.counts, vec![vec![16, 0, 0, 0]]);
        assert_eq!(w.tokens, 8);
        // all traffic on one expert => zero entropy; uniform => log2(n)
        assert_eq!(w.dispatch_entropy(), 0.0);
        assert!((a.dispatch_entropy() - 1.0).abs() < 1e-12);
        let uniform = RoutingSnapshot { counts: vec![vec![5, 5, 5, 5]], tokens: 20 };
        assert!((uniform.dispatch_entropy() - 2.0).abs() < 1e-12);
        // a mismatched baseline degrades to the full counts, not a panic
        let w2 = b.since(&RoutingSnapshot::default());
        assert_eq!(w2.counts, b.counts);
        assert_eq!(RoutingSnapshot::default().dispatch_entropy(), 0.0);
    }
}
