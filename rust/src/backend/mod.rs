//! Execution backends: where model forwards actually run.
//!
//! Every consumer of model execution ([`crate::model::ModelContext`], and
//! through it the evaluator, the calibration pass, the serving layer and
//! the bench harness) talks to a [`Backend`] trait object. Two
//! implementations ship:
//!
//! * [`native::NativeBackend`] — a pure-Rust CPU interpreter of the
//!   simulated SMoE transformer family (`qwensim`, `mixsim`, `dssim`). It
//!   executes directly from [`crate::weights::Weights`] + a
//!   [`crate::config::ModelCfg`], needs no HLO artifacts, no PJRT plugin
//!   and no Python, and is the **default**. Its dense matmuls run through
//!   [`crate::tensor::matmul_blocked_with`], so it inherits the
//!   [`crate::parallel`] scoped-pool determinism contract.
//! * [`pjrt::PjrtBackend`] — the original path: compiles the AOT-lowered
//!   HLO text artifacts with the `xla` PJRT bindings and keeps weights
//!   resident as device buffers. Offline builds link the vendored stub, so
//!   this backend constructs but errors on execution until real bindings
//!   are swapped in (see `DESIGN.md`, "Offline-environment notes").
//!
//! Selection is at runtime via the `HCSMOE_BACKEND` environment variable
//! (`native` | `pjrt`, default `native`); no call site changes between
//! them. Model variants are opaque [`ModelState`] handles so each backend
//! can keep whatever resident form it wants (a weight copy for native,
//! device buffers for PJRT).

pub mod native;
pub mod pjrt;

use std::any::Any;

use anyhow::{anyhow, Result};

use crate::config::{Artifacts, ModelCfg};
use crate::tensor::Tensor;
use crate::weights::Weights;

/// An opaque, backend-specific resident model variant.
///
/// Created by [`Backend::load_model`] and only meaningful to the backend
/// that produced it; backends downcast via [`ModelState::as_any`].
pub trait ModelState {
    /// Downcast support (each backend recovers its own concrete state).
    fn as_any(&self) -> &dyn Any;
}

/// A model-execution engine.
///
/// One backend instance is bound to one model configuration (the
/// [`ModelCfg`] passed at construction). All tensor interfaces mirror the
/// AOT-lowered HLO entry points so the two implementations are
/// interchangeable:
///
/// * `run_logits` is the `lm_logits_*` scoring forward: token ids and an
///   additive router mask in, next-token logits out;
/// * `run_calib` is the `calib_*` statistics pass returning the 8-tuple
///   of per-layer tensors described in [`crate::calib`].
pub trait Backend {
    /// Short backend identifier (`"native"` / `"pjrt"`), used in logs.
    fn name(&self) -> &'static str;

    /// Prepare a weight set for repeated execution.
    ///
    /// `n_slots` is the number of physical expert slots per layer:
    /// `cfg.n_exp` for the full layout (merging duplicates merged experts
    /// into every member slot), or `r < n_exp` for a compact variant
    /// produced by [`crate::weights::Weights::to_compact`].
    fn load_model(&self, weights: &Weights, n_slots: usize) -> Result<Box<dyn ModelState>>;

    /// One scoring forward: `ids` is a flattened `[b, t]` i32 batch,
    /// `mask` the additive `[n_layer * n_exp]` router mask, and `remap`
    /// the optional `[n_layer * n_exp]` expert→slot table used by compact
    /// variants. Returns logits `[b, t, vocab]`.
    fn run_logits(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        mask: &[f32],
        remap: Option<&[i32]>,
    ) -> Result<Tensor>;

    /// One calibration pass over a flattened `[b, t]` batch; returns the
    /// 8 stacked statistics tensors (`mean_out`, `counts`, `probs_sum`,
    /// `gate_sum`, `rl_sub`, `raw_sub`, `act_sub`, `hid_sub` — see
    /// [`crate::calib::LayerStats`]). `t_sub`/`t_act` size the subsampled
    /// profiles.
    fn run_calib(
        &self,
        state: &dyn ModelState,
        ids: &[i32],
        b: usize,
        t: usize,
        t_sub: usize,
        t_act: usize,
    ) -> Result<Vec<Tensor>>;
}

/// Environment variable selecting the execution backend.
pub const BACKEND_ENV: &str = "HCSMOE_BACKEND";

/// Construct the backend selected by [`BACKEND_ENV`] (default: native).
pub fn from_env(arts: &Artifacts, cfg: &ModelCfg) -> Result<Box<dyn Backend>> {
    let choice = std::env::var(BACKEND_ENV).unwrap_or_else(|_| "native".into());
    match choice.as_str() {
        "native" | "" => Ok(Box::new(native::NativeBackend::new(cfg.clone()))),
        "pjrt" => Ok(Box::new(pjrt::PjrtBackend::new(arts.clone(), cfg.clone())?)),
        other => Err(anyhow!(
            "unknown {BACKEND_ENV}={other:?} (expected \"native\" or \"pjrt\")"
        )),
    }
}

/// Downcast a [`ModelState`] to the concrete type `T` a backend expects.
pub(crate) fn downcast_state<'a, T: 'static>(
    state: &'a dyn ModelState,
    backend: &str,
) -> Result<&'a T> {
    state
        .as_any()
        .downcast_ref::<T>()
        .ok_or_else(|| anyhow!("model state was not created by the {backend} backend"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_is_the_default_selection() {
        // from_env is driven by the process environment; rather than mutate
        // it (racy across test threads), check the default construction
        // path directly.
        let cfg = crate::config::ModelCfg {
            name: "t".into(),
            n_layer: 1,
            d: 4,
            m: 4,
            n_exp: 2,
            k: 1,
            heads: 1,
            vocab: 8,
            t_max: 8,
            shared: false,
            m_shared: 4,
            cap_factor: 2.0,
            block_c: 1,
        };
        let b = native::NativeBackend::new(cfg);
        assert_eq!(b.name(), "native");
    }
}
